"""Serve a quantized model with continuous batching (paper §5.2's future
work, built): submit a mixed stream of requests, watch slots recycle.

Run:  PYTHONPATH=src python examples/serve_quantized.py [--kv-int8] [--q4]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import QuantPolicy
from repro.models import build_model
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kv-int8", action="store_true",
                    help="also quantize the KV cache (beyond-paper)")
    ap.add_argument("--q4", action="store_true",
                    help="4-bit weights (paper §5.1 future work)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--n-samples", type=int, default=1,
                    help="best-of-n parallel sampling per request: the "
                         "prompt prefills once, n siblings fork its KV "
                         "blocks and diverge via copy-on-write")
    args = ap.parse_args()

    cfg = reduced(get_config("llama2-110m"))
    if args.kv_int8:
        cfg = cfg.with_(kv_cache_dtype="int8")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    bits = 4 if args.q4 else 8
    qparams = model.quantize(params, QuantPolicy(bits=bits, min_size=512))
    print(f"serving Q{bits}_0 weights"
          + (", int8 KV cache" if args.kv_int8 else ", bf16 KV cache"))

    eng = Engine(model, qparams, max_slots=4, max_seq=128)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        eng.submit(rng.integers(4, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=int(rng.integers(8, 24)),
                   temperature=1.0, top_p=0.9, seed=i,
                   n_samples=args.n_samples)
    done = eng.run()
    wall = time.perf_counter() - t0

    for r in sorted(done, key=lambda r: r.uid)[:4]:
        if r.error is not None:
            print(f"  req {r.uid}: rejected — {r.error}")
            continue
        lens = "/".join(str(len(o)) for o in r.outputs)
        print(f"  req {r.uid}: prompt {len(r.prompt)} tok -> "
              f"{lens} new tok across {len(r.outputs)} sample(s), "
              f"TTFT {1e3*(r.t_first_token-r.t_enqueue):.0f} ms")
    print(f"{len(done)} requests, {eng.metrics['tokens_out']} tokens, "
          f"{eng.metrics['tokens_out']/wall:.1f} tok/s wall "
          f"({eng.throughput_tok_s():.1f} tok/s decode-only)")
    if args.n_samples > 1:
        print(f"fork sharing: {eng.metrics['fanouts']} fanouts, peak "
              f"{eng.metrics['blocks_live_peak']} live blocks, "
              f"{eng.metrics['blocks_saved_by_sharing_peak']} blocks "
              f"saved by shared prompt KV, "
              f"{eng.metrics['cow_copies']} COW copies")


if __name__ == "__main__":
    main()
