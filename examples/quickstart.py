"""Quickstart: the complete HLSTransform flow in ~60 lines.

1. Build a Llama-2-family model (the paper's 110M config, reduced for CPU).
2. Train briefly on the synthetic TinyStories stream.
3. Post-training-quantize to Q8_0 (the paper's §3.2).
4. Generate text tokens with the quantized model and compare quality.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import QuantPolicy, count_bytes
from repro.data.pipeline import DataConfig, SyntheticTinyStories
from repro.launch import steps as steplib
from repro.models import build_model, count_params
from repro.optim import adamw


def main():
    # 1. model ------------------------------------------------------------
    cfg = reduced(get_config("llama2-110m"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.arch_id} (reduced) — {count_params(params)/1e6:.2f}M "
          f"params, {count_bytes(params)['total']/1e6:.1f} MB fp32")

    # 2. train ------------------------------------------------------------
    ocfg = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=10, decay_steps=60)
    state = {"params": params, "opt": adamw.init_state(params)}
    step = jax.jit(steplib.make_train_step(model, ocfg), donate_argnums=(0,))
    data = SyntheticTinyStories(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=128, batch_size=4))
    it = data.batches()
    for s in range(60):
        state, metrics = step(state, next(it))
        if s % 20 == 0:
            print(f"  step {s:3d}  loss {float(metrics['loss']):.4f}")
    params = state["params"]

    # 3. quantize (Q8_0, groups of 64, norms stay fp32 — paper §3.2) ------
    qparams = model.quantize(params, QuantPolicy(min_size=512))
    qb = count_bytes(qparams)
    print(f"quantized: {qb['quantized']/1e6:.1f} MB int8 + "
          f"{qb['float']/1e6:.2f} MB fp32 (norms) "
          f"= {qb['total']/1e6:.1f} MB total")

    # 4. generate with both and compare -----------------------------------
    prompt = {"tokens": next(it)["tokens"][:1, :16]}
    lf, cf = model.prefill(params, prompt, max_seq=48)
    lq, cq = model.prefill(qparams, prompt, max_seq=48)
    out_f, out_q = [], []
    for _ in range(16):
        tf, tq = jnp.argmax(lf, -1), jnp.argmax(lq, -1)
        out_f.append(int(tf[0])); out_q.append(int(tq[0]))
        lf, cf = model.decode_step(params, cf, tf)
        lq, cq = model.decode_step(qparams, cq, tq)
    agree = np.mean([a == b for a, b in zip(out_f, out_q)])
    print(f"greedy tokens fp32: {out_f}")
    print(f"greedy tokens q8_0: {out_q}")
    print(f"agreement: {agree:.0%} (paper: quantization costs 0.04% ppl)")


if __name__ == "__main__":
    main()
