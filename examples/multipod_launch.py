"""Multi-pod launch walkthrough: what runs on a real 512-chip cluster.

On hardware, each host executes this file via the cluster scheduler with
COORDINATOR/NUM_PROCESSES/PROCESS_ID set; jax.distributed wires the pods
together and the SAME step functions from the dry-run execute for real.
On this container it prints the launch plan and validates the mesh +
sharding construction end-to-end with abstract values (no allocation).

Run:  PYTHONPATH=src python examples/multipod_launch.py --arch glm4-9b
"""

import argparse
import os

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    if "COORDINATOR_ADDRESS" in os.environ:
        # real cluster path: one process per host
        jax.distributed.initialize(
            coordinator_address=os.environ["COORDINATOR_ADDRESS"],
            num_processes=int(os.environ["NUM_PROCESSES"]),
            process_id=int(os.environ["PROCESS_ID"]))
        print(f"process {jax.process_index()}/{jax.process_count()} up, "
              f"{jax.local_device_count()} local devices")
    else:
        # container path: placeholder devices, identical program
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        print("no cluster env: using 512 placeholder host devices "
              "(same code path as the dry-run)")

    from repro.configs.base import get_config, shapes_for
    from repro.launch import mesh as meshlib
    from repro.launch import steps as steplib
    from repro.models.model import build_model
    from repro.optim import adamw

    cfg = get_config(args.arch)
    cell = {c.name: c for c in shapes_for(cfg)}[args.shape]
    model = build_model(cfg)
    mesh = meshlib.make_production_mesh(multi_pod=True)
    print(f"mesh: {dict(mesh.shape)}  (pod x data x model)")

    with mesh:
        step, state_s, batch_s, _ = steplib.jit_train_step(
            model, mesh, adamw.AdamWConfig(), cell)
        lowered = step.lower(state_s, batch_s)
        compiled = lowered.compile()
        print("lower+compile OK — per-device memory:")
        m = compiled.memory_analysis()
        print(f"  arguments {m.argument_size_in_bytes/1e9:.2f} GB, "
              f"temps {m.temp_size_in_bytes/1e9:.2f} GB")
        print("on hardware, the next line would be: "
              "state = jax.device_put(host_state, shardings); "
              "then the train loop from repro.launch.train.")


if __name__ == "__main__":
    main()
