"""Train any of the 10 assigned architectures end-to-end (reduced configs)
with checkpoint/restart — demonstrates the config system + fault tolerance.

Run:  PYTHONPATH=src python examples/train_multiarch.py --arch zamba2-1.2b
      PYTHONPATH=src python examples/train_multiarch.py --arch qwen3-moe-30b-a3b
(then re-run the same command: it resumes from the checkpoint)
"""

import argparse
import tempfile
from pathlib import Path

from repro.configs import list_configs
from repro.launch import train as trainlib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b",
                    choices=list_configs())
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    ckpt = args.ckpt_dir or str(
        Path(tempfile.gettempdir()) / f"repro_ckpt_{args.arch}")
    print(f"arch={args.arch}  checkpoints -> {ckpt}")
    losses = trainlib.run(arch=args.arch, steps=args.steps, batch=4,
                          seq=128, use_reduced=True, ckpt_dir=ckpt,
                          ckpt_every=20, log_every=10)
    if losses:
        print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    else:
        print("nothing to do (already past --steps; bump it to continue)")


if __name__ == "__main__":
    main()
