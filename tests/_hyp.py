"""Optional-hypothesis shim shared by the test suite.

``hypothesis`` is not part of the runtime image.  Importing from here
keeps the property tests defined (they self-skip when the library is
missing) without taking the rest of their module down with them:

    from _hyp import HAVE_HYPOTHESIS, given, settings, st
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def settings(**kw):
        return lambda f: f

    def given(*a, **kw):
        return lambda f: pytest.mark.skip("hypothesis not installed")(f)

    class _AnyStrategy:
        """Stands in for ``strategies`` so decorator args still evaluate."""

        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _AnyStrategy()
