"""Tensor-parallel sharded serving: the multi-device bit-exactness tier.

The sharded engine's contract is *bitwise stream identity*: an
``Engine(mesh=...)`` over any mesh size must emit exactly the token
streams of the unsharded engine, greedy and sampled, f32 and int8 KV,
through every serving feature (prefix-cache warm hits, fork/COW parallel
sampling, preemption-resume).  The scheme that makes this possible is
storage-sharded / compute-replicated (see
``transformer._serve_mesh_helpers``): the paged pool shards its KV-heads
dim, weights are stored sharded but gathered whole at use, and the only
collectives are all-gathers — pure data movement, never arithmetic — so
no floating-point reduction is ever reassociated across devices.

Mesh sizes above the local device count self-skip; the CI multi-device
lane re-runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the
launch/dryrun.py idiom) where sizes 1/2/4 all execute for real.

The ``sanitize`` / ``paged_cache_specs`` unit tests at the bottom pin
the degrade-never-raise contract: paged-pool dims that don't divide the
model axis (odd KV-head counts, tiny block sizes) fall back to
replication mid-admission instead of raising.
"""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.distribution import sharding as sh
from repro.launch.mesh import make_serve_mesh
from repro.models import build_model
from repro.serving.engine import Engine

MESH_SIZES = (1, 2, 4)
PROMPT_SIZES = (5, 9, 17, 12)


def _mesh_or_skip(n):
    if jax.device_count() < n:
        pytest.skip(f"needs {n} devices (CI multi-device lane)")
    return make_serve_mesh(n)


@pytest.fixture(scope="module", params=["f32", "int8"])
def model_params(request):
    """f32: float params + float KV pool.  int8: quantized params with
    fused decode weights + int8 KV pool with f32 scale pools — the
    layout where the pool's per-(position, kv-head) scale buffers shard
    alongside the codes."""
    cfg = reduced(get_config("llama2-110m")).with_(compute_dtype="float32")
    if request.param == "int8":
        cfg = cfg.with_(kv_cache_dtype="int8")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    if request.param == "int8":
        params = m.quantize(params)
    return m, params


def _prompts(seed=0, sizes=PROMPT_SIZES):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 300, size=n).astype(np.int32) for n in sizes]


def _serve(model, params, mesh, *, greedy=True, n_samples=1, n_pages=48,
           max_new=8, prompts=None, repeats=1):
    eng = Engine(model, params, max_slots=4, max_seq=64, page_size=4,
                 n_pages=n_pages, prefill_chunk_tokens=8, mesh=mesh)
    uids, done = [], {}
    for rep in range(repeats):
        for i, pr in enumerate(prompts or _prompts()):
            uids.append(eng.submit(
                pr, max_new_tokens=max_new,
                temperature=0.0 if greedy else 0.9,
                top_p=1.0 if greedy else 0.95,
                seed=7 + i, n_samples=n_samples))
        # drain between repeats so warm resubmissions actually hit the
        # prefix index (registration happens at prefill completion)
        done.update({r.uid: r for r in eng.run()})
    streams = []
    for u in uids:
        r = done[u]
        assert r.error is None, r.error
        streams.append(tuple(tuple(o) for o in r.outputs))
    return streams, eng


class TestBitIdenticalStreams:
    @pytest.mark.parametrize("msize", MESH_SIZES)
    @pytest.mark.parametrize("greedy", (True, False),
                             ids=("greedy", "sampled"))
    def test_streams_match_unsharded(self, model_params, msize, greedy):
        mesh = _mesh_or_skip(msize)
        model, params = model_params
        ref, _ = _serve(model, params, None, greedy=greedy)
        got, eng = _serve(model, params, mesh, greedy=greedy)
        assert got == ref
        # zero leaks: every lease back, whole pool reclaimable
        assert all(rc == 0 for rc in eng.pager.refcount)
        assert eng.pager.n_free() == eng.pager.cfg.n_blocks
        assert eng.pager.audit().clean

    @pytest.mark.parametrize("msize", MESH_SIZES)
    def test_prefix_cache_warm_hit_sharded(self, model_params, msize):
        """A warm resubmission of the same prompt must hit the prefix
        index under a mesh (registration hashes host-side tokens, never
        device bytes) and still stream bit-identically."""
        mesh = _mesh_or_skip(msize)
        model, params = model_params
        prompts = _prompts(sizes=(16, 12))
        ref, reng = _serve(model, params, None, prompts=prompts,
                           repeats=2)
        got, eng = _serve(model, params, mesh, prompts=prompts,
                          repeats=2)
        assert got == ref
        assert eng.metrics["prefix_hits"] > 0
        assert eng.metrics["prefix_hits"] == reng.metrics["prefix_hits"]
        assert (eng.metrics["prefix_cached_tokens"]
                == reng.metrics["prefix_cached_tokens"])

    @pytest.mark.parametrize("msize", MESH_SIZES)
    def test_fork_cow_parallel_sampling_sharded(self, model_params,
                                                msize):
        """n_samples fanout over fork/COW: the device half of COW is a
        donated copy on the *sharded* pool — sibling streams must match
        the unsharded engine's exactly."""
        mesh = _mesh_or_skip(msize)
        model, params = model_params
        prompts = _prompts(sizes=(7, 11))
        ref, reng = _serve(model, params, None, greedy=False,
                           n_samples=3, n_pages=64, prompts=prompts)
        got, eng = _serve(model, params, mesh, greedy=False,
                          n_samples=3, n_pages=64, prompts=prompts)
        assert got == ref
        assert eng.metrics["fanouts"] > 0
        assert eng.metrics["cow_copies"] == reng.metrics["cow_copies"]

    @pytest.mark.parametrize("msize", MESH_SIZES)
    def test_preemption_resume_sharded(self, model_params, msize):
        """A pool far below demand forces preemption + recompute-on-
        resume; the resumed KV is rebuilt through the sharded prefill
        path and the streams must still match unsharded serving."""
        mesh = _mesh_or_skip(msize)
        model, params = model_params
        prompts = _prompts(sizes=(9, 13, 11, 8))
        ref, reng = _serve(model, params, None, n_pages=12,
                           max_new=6, prompts=prompts)
        got, eng = _serve(model, params, mesh, n_pages=12,
                          max_new=6, prompts=prompts)
        assert got == ref
        assert eng.metrics["preemptions"] > 0, \
            "pool sizing no longer forces preemption; test is vacuous"
        assert eng.metrics["preemptions"] == reng.metrics["preemptions"]


class TestCompileBoundSharded:
    @pytest.mark.parametrize("msize", MESH_SIZES)
    def test_one_executable_per_mesh(self, model_params, msize):
        """Traffic mixing chunk lengths, offsets and decode composition
        stays at ONE chunk executable per (pool key, mesh shape).  The
        probe counts jit entries for this (cfg, mesh) pair across ALL
        pool keys served so far in the process, so the assertion is a
        delta: this pool key costs at most one entry, and a second
        engine on the same pool key costs zero."""
        mesh = _mesh_or_skip(msize)
        model, params = model_params
        probe = Engine(model, params, max_slots=4, max_seq=64,
                       page_size=4, n_pages=48, prefill_chunk_tokens=8,
                       mesh=mesh)
        c0 = probe.prefill_compile_count()
        _, eng = _serve(model, params, mesh)
        grew = eng.prefill_compile_count() - c0
        assert grew <= 1, f"{grew} chunk executables for one pool key"
        # fresh engine, same pool key, different traffic: fully warm
        _, eng2 = _serve(model, params, mesh, greedy=False,
                         prompts=_prompts(seed=5, sizes=(3, 21, 8)))
        assert eng2.prefill_compile_count() == c0 + grew, \
            "same (pool key, mesh shape) must not compile again"


class _FakeMesh:
    """Duck-typed mesh for spec-rule unit tests (axis sizes only)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


class TestSanitizeDegrades:
    """sanitize / paged_cache_specs must degrade, never raise — they run
    mid-admission where an exception would fail a request."""

    MESH2 = _FakeMesh({"data": 1, "model": 2})
    MESH4 = _FakeMesh({"data": 1, "model": 4})

    def test_nondivisible_dim_degrades(self):
        assert sh.sanitize(P(None, "model"), (8, 3), self.MESH2) \
            == P(None, None)

    def test_overlong_spec_truncates(self):
        assert sh.sanitize(P("model", None, None), (8,), self.MESH2) \
            == P("model")
        assert sh.sanitize(P("model", None, None), (3,), self.MESH2) \
            == P(None)

    def test_unknown_axis_degrades(self):
        assert sh.sanitize(P("tp", None), (8, 8), self.MESH2) \
            == P(None, None)

    def test_pool_model_axis_odd_heads(self):
        cfg = get_config("llama2-110m").with_(n_heads=6, n_kv_heads=3)
        assert sh.pool_model_axis(cfg, self.MESH2) is None
        assert sh.pool_model_axis(cfg, self.MESH4) is None
        cfg4 = get_config("llama2-110m").with_(n_kv_heads=4)
        assert sh.pool_model_axis(cfg4, self.MESH2) == "model"
        assert sh.pool_model_axis(cfg4, self.MESH4) == "model"

    def test_pool_model_axis_size1_mesh_replicates(self):
        cfg = get_config("llama2-110m")
        assert sh.pool_model_axis(
            cfg, _FakeMesh({"data": 1, "model": 1})) is None

    def test_paged_pool_odd_heads_replicate(self):
        """KVH=3 on a model-2 axis: every pool buffer degrades to
        replication — including tiny block_s — without raising."""
        cfg = get_config("llama2-110m").with_(n_heads=6, n_kv_heads=3)
        i32 = jax.ShapeDtypeStruct((4, 12), np.int32)
        cache = {
            "lens": jax.ShapeDtypeStruct((4,), np.int32),
            "page_table": i32,
            "attn": {
                # tiny block_s=2, odd KVH=3
                "k": jax.ShapeDtypeStruct((2, 48, 2, 3, 32), np.float32),
                "v": jax.ShapeDtypeStruct((2, 48, 2, 3, 32), np.float32),
                "ks": jax.ShapeDtypeStruct((2, 48, 2, 3), np.float32),
                "vs": jax.ShapeDtypeStruct((2, 48, 2, 3), np.float32),
            },
        }
        specs = sh.paged_cache_specs(cfg, cache, self.MESH2)
        assert specs["attn"]["k"] == P()
        assert specs["attn"]["ks"] == P()
        assert specs["lens"] == P()
        assert specs["page_table"] == P()

    def test_paged_pool_divisible_heads_shard(self):
        """KVH=4 on model-2/model-4: the pool's KV-heads dim shards,
        scale pools follow, control state stays replicated, and specs
        are canonical (no trailing Nones — the donated-cache jit-key
        contract)."""
        cfg = get_config("llama2-110m").with_(n_kv_heads=4)
        cache = {
            "lens": jax.ShapeDtypeStruct((4,), np.int32),
            "page_table": jax.ShapeDtypeStruct((4, 12), np.int32),
            "attn": {
                "k": jax.ShapeDtypeStruct((2, 48, 4, 4, 32), np.float32),
                "v": jax.ShapeDtypeStruct((2, 48, 4, 4, 32), np.float32),
                "ks": jax.ShapeDtypeStruct((2, 48, 4, 4), np.float32),
                "vs": jax.ShapeDtypeStruct((2, 48, 4, 4), np.float32),
            },
        }
        for mesh in (self.MESH2, self.MESH4):
            specs = sh.paged_cache_specs(cfg, cache, mesh)
            assert specs["attn"]["k"] == P(None, None, None, "model")
            assert specs["attn"]["ks"] == P(None, None, None, "model")
            assert specs["lens"] == P()

    def test_cache_specs_dispatches_paged(self):
        """cache_specs routes a page_table-carrying cache to the paged
        layout (KVH axis) instead of the dense decode layout."""
        cfg = get_config("llama2-110m").with_(n_kv_heads=4)
        cache = {
            "lens": jax.ShapeDtypeStruct((4,), np.int32),
            "page_table": jax.ShapeDtypeStruct((4, 12), np.int32),
            "attn": {
                "k": jax.ShapeDtypeStruct((2, 48, 4, 4, 32), np.float32),
                "v": jax.ShapeDtypeStruct((2, 48, 4, 4, 32), np.float32),
            },
        }
        specs = sh.cache_specs(cfg, cache, self.MESH2)
        assert specs["attn"]["k"] == P(None, None, None, "model")


class TestShardedEngineGuards:
    def test_mesh_requires_paged_cache(self, model_params):
        model, params = model_params
        mesh = _mesh_or_skip(1)
        with pytest.raises(ValueError, match="paged"):
            Engine(model, params, cache_kind="dense", mesh=mesh)

    def test_serve_mesh_validates_size(self):
        with pytest.raises(ValueError):
            make_serve_mesh(0)
        with pytest.raises(ValueError):
            make_serve_mesh(jax.device_count() + 1)
