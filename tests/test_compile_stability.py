"""Shape-stable chunked prefill: bounded compile counts + padding
bit-exactness.

The serving tentpole this file gates: ``prefill_chunk_batch`` used to
recompile per distinct ``(B, chunk_len, pos_offset)`` triple and
``flash_prefill``'s ``q_offset`` was a static kernel argument, so
production traffic with varied prompt lengths paid unbounded XLA
compiles.  Now every extent is traced data and the engine pads each
step's chunks to one fixed ``(max_slots, prefill_chunk_tokens)`` extent:

  * compile count is O(pool keys) — ONE executable per pool
    configuration however traffic churns lengths/offsets/batch width,
    counted two ways (the jit lowering cache via
    ``transformer.prefill_chunk_compiles`` and a ``jax.monitoring``
    backend-compile listener);
  * a valid row's results do not depend on the padding around it —
    batch-composition invariance is asserted *bitwise*, and padded vs
    unpadded calls agree to the same reassociation tolerance class the
    multi-chunk-vs-one-shot contract already carries (greedy argmax
    identical; int8 pools code-for-code within the +-1 rounding step);
  * the flash-prefill kernel accepts per-row offsets/valid extents via
    scalar prefetch and matches the jnp oracle row for row.
"""

import jax
import jax.monitoring
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.paged_cache import BlockAllocator, PagedConfig

# -- jax.monitoring cross-check: one process-wide listener, gated -----------
_BACKEND_COMPILES = {"n": 0, "armed": False}


def _on_event(name, secs, **kw):
    if _BACKEND_COMPILES["armed"] and \
            name == "/jax/core/compile/backend_compile_duration":
        _BACKEND_COMPILES["n"] += 1


jax.monitoring.register_event_duration_secs_listener(_on_event)


class _count_backend_compiles:
    def __enter__(self):
        _BACKEND_COMPILES["n"] = 0
        _BACKEND_COMPILES["armed"] = True
        return self

    def __exit__(self, *exc):
        _BACKEND_COMPILES["armed"] = False
        self.n = _BACKEND_COMPILES["n"]
        return False

    @property
    def so_far(self):
        return _BACKEND_COMPILES["n"]


def _model(kv_dtype=None):
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m")).with_(compute_dtype="float32")
    if kv_dtype:
        cfg = cfg.with_(kv_cache_dtype=kv_dtype)
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _paged(m, bs=8, n_blocks=32, slots=4, mb=8):
    alloc = BlockAllocator(PagedConfig(
        n_layers=m.cfg.n_layers, n_kv_heads=m.cfg.n_kv_heads,
        head_dim=m.cfg.hd(), block_size=bs, n_blocks=n_blocks,
        max_slots=slots, max_blocks_per_seq=mb))
    cache = m.init_paged_cache(slots, block_size=bs, n_blocks=n_blocks,
                               max_blocks_per_seq=mb)
    return alloc, cache


def _run_batch(m, params, cache, rows, pad_rows=0, pad_width=0):
    """Execute one prefill_chunk_batch call for ``rows`` of
    (slot, tokens, off), optionally padded out to a larger fixed extent
    (the engine's shape-stable form)."""
    from repro.models import transformer
    width = max(max(len(t) for _, t, _ in rows), pad_width)
    nrows = max(len(rows), pad_rows)
    toks = np.zeros((nrows, width), np.int32)
    lens = np.zeros((nrows,), np.int32)
    offs = np.zeros((nrows,), np.int32)
    slots = np.full((nrows,), -1, np.int32)
    for i, (slot, t, off) in enumerate(rows):
        toks[i, :len(t)] = t
        lens[i] = len(t)
        offs[i] = off
        slots[i] = slot
    return transformer.prefill_chunk_batch(
        params, m.cfg, toks, cache, slots, offs, chunk_lens=lens)


def _fill(alloc, cache, slot, upto):
    alloc.ensure(slot, upto)
    cache = dict(cache)
    cache["page_table"] = jnp.asarray(alloc.page_table())
    return cache


def _pool_rows(cache, alloc, slot, upto, key):
    blocks = alloc.owned[slot]
    pool = np.asarray(cache["attn"][key])
    nl, _, bs = pool.shape[:3]
    return pool[:, blocks].reshape(nl, len(blocks) * bs,
                                   *pool.shape[3:])[:, :upto]


# ---------------------------------------------------------------------------
# padding invariance (function level)
# ---------------------------------------------------------------------------


def test_batch_composition_invariance_is_bitwise_f32():
    """A valid row's logits and written KV are BITWISE independent of
    what else shares the padded batch — the property that lets the
    engine batch arbitrary chunk mixes (and pad with dead rows) without
    perturbing any stream."""
    m, params = _model()
    rng = np.random.default_rng(0)
    ta = rng.integers(4, 500, size=13).astype(np.int32)
    tb = rng.integers(4, 500, size=9).astype(np.int32)

    alloc1, cache1 = _paged(m)
    cache1 = _fill(alloc1, cache1, 0, 13)
    l_alone, cache1 = _run_batch(m, params, cache1, [(0, ta, 0)],
                                 pad_rows=4, pad_width=16)

    alloc2, cache2 = _paged(m)
    alloc2.ensure(0, 13)
    cache2 = _fill(alloc2, cache2, 2, 9)
    l_both, cache2 = _run_batch(m, params, cache2,
                                [(0, ta, 0), (2, tb, 0)],
                                pad_rows=4, pad_width=16)

    np.testing.assert_array_equal(np.asarray(l_alone[0]),
                                  np.asarray(l_both[0]))
    for kk in ("k", "v"):
        np.testing.assert_array_equal(
            _pool_rows(cache1, alloc1, 0, 13, kk),
            _pool_rows(cache2, alloc2, 0, 13, kk))


@pytest.mark.parametrize("kv", [None, "int8"])
def test_padded_call_matches_unpadded_per_shape_calls(kv):
    """The engine's padded single call vs the legacy per-shape-grouped
    unpadded calls, identical chunk boundaries: greedy argmax identical,
    logits/KV within the reassociation tolerance (int8 pools store the
    same codes up to the +-1 step a last-ulp difference can tip)."""
    m, params = _model(kv)
    rng = np.random.default_rng(1)
    ta = rng.integers(4, 500, size=16).astype(np.int32)   # 2 full blocks
    tb = rng.integers(4, 500, size=11).astype(np.int32)

    def serve(pad_rows, pad_width):
        alloc, cache = _paged(m)
        # step 1: first chunks at offset 0, different lengths
        alloc.ensure(0, 8)
        cache = _fill(alloc, cache, 2, 11)
        (_, cache) = _run_batch(m, params, cache,
                                [(0, ta[:8], 0), (2, tb, 0)],
                                pad_rows=pad_rows, pad_width=pad_width)
        # step 2: ta's second chunk at offset 8
        cache = _fill(alloc, cache, 0, 16)
        logits, cache = _run_batch(m, params, cache, [(0, ta[8:], 8)],
                                   pad_rows=pad_rows, pad_width=pad_width)
        return logits[0], cache, alloc

    # unpadded "legacy grouping": every call exactly its natural extent
    l_ref, cache_ref, alloc_ref = serve(pad_rows=0, pad_width=0)
    # padded shape-stable form: every call (4 rows, 24 tokens)
    l_pad, cache_pad, alloc_pad = serve(pad_rows=4, pad_width=24)

    assert int(jnp.argmax(l_ref)) == int(jnp.argmax(l_pad)), \
        "padding must not change the greedy token"
    np.testing.assert_allclose(np.asarray(l_pad), np.asarray(l_ref),
                               rtol=1e-5, atol=5e-6)
    for slot, upto in ((0, 16), (2, 11)):
        for kk in ("k", "v"):
            got = _pool_rows(cache_pad, alloc_pad, slot, upto, kk)
            want = _pool_rows(cache_ref, alloc_ref, slot, upto, kk)
            if kv == "int8":
                assert np.abs(got.astype(np.int32)
                              - want.astype(np.int32)).max() <= 1
            else:
                np.testing.assert_allclose(got, want, rtol=1e-5, atol=5e-6)
        if kv == "int8":
            for kk in ("ks", "vs"):
                np.testing.assert_allclose(
                    _pool_rows(cache_pad, alloc_pad, slot, upto, kk),
                    _pool_rows(cache_ref, alloc_ref, slot, upto, kk),
                    rtol=1e-4)


def test_padding_rows_write_nothing():
    """Dead rows (slot -1) and positions past a row's valid length must
    not touch the pool or the device lens — padding is invisible."""
    m, params = _model()
    rng = np.random.default_rng(2)
    toks = rng.integers(4, 500, size=5).astype(np.int32)
    alloc, cache = _paged(m)
    cache = _fill(alloc, cache, 1, 5)
    before_free = {kk: np.asarray(cache["attn"][kk]).copy()
                   for kk in ("k", "v")}
    used = alloc.owned[1]
    _, cache = _run_batch(m, params, cache, [(1, toks, 0)],
                          pad_rows=4, pad_width=16)
    assert np.asarray(cache["lens"]).tolist() == [0, 5, 0, 0]
    for kk in ("k", "v"):
        after = np.asarray(cache["attn"][kk])
        untouched = np.ones(after.shape[1], bool)
        untouched[used] = False
        np.testing.assert_array_equal(after[:, untouched],
                                      before_free[kk][:, untouched])


# ---------------------------------------------------------------------------
# compile-count regression (engine level)
# ---------------------------------------------------------------------------


def test_engine_compile_count_bounded_under_shape_churn():
    """Mixed prompt lengths produce many distinct legacy compile keys
    ``(B, chunk_len, pos_offset)`` but at most ONE fresh executable for
    the chunk step (one pool key), counted via the jit lowering cache
    AND a jax.monitoring backend-compile listener; the engine's
    ``metrics``/``plan_log`` carry the probe."""
    from repro.serving.engine import Engine, legacy_chunk_shape_keys
    m, params = _model()
    rng = np.random.default_rng(3)

    eng = Engine(m, params, max_slots=3, max_seq=64, page_size=8,
                 prefill_chunk_tokens=16)
    c0 = eng.prefill_compile_count()
    # wave 1: compiles everything once (chunk step, decode step, and the
    # small eager-op executables around them)
    for n in (5, 19, 11):
        eng.submit(rng.integers(4, 500, size=n).astype(np.int32),
                   max_new_tokens=3, temperature=0.0)
    assert all(r.error is None for r in eng.run())
    wave1_plans = len(eng.plan_log)
    grew = eng.prefill_compile_count() - c0
    assert grew <= 1, \
        f"chunk step compiled {grew}x in one pool key (bound: 1)"

    # wave 2: entirely new prompt lengths -> new legacy shape keys, but
    # ZERO fresh chunk-step executables and ~zero backend compiles
    with _count_backend_compiles() as probe:
        for n in (30, 7, 23):
            eng.submit(rng.integers(4, 500, size=n).astype(np.int32),
                       max_new_tokens=3, temperature=0.0)
        assert all(r.error is None for r in eng.run())
    k1 = legacy_chunk_shape_keys(eng.plan_log[:wave1_plans])
    k2 = legacy_chunk_shape_keys(eng.plan_log[wave1_plans:])
    assert k2 - k1, "wave 2 must introduce new legacy shape keys"
    assert len(k1 | k2) > 3, f"workload too uniform: {k1 | k2}"
    assert eng.prefill_compile_count() == c0 + grew, \
        "new chunk shapes must not compile new chunk-step executables"
    assert probe.n <= 2, \
        f"{probe.n} backend compiles for {len(k2 - k1)} new shape keys"

    assert eng.metrics["prefill_compiles"] >= 1
    probed = [p["prefill_compiles"] for p in eng.plan_log
              if "prefill_compiles" in p]
    assert probed and probed[-1] == eng.prefill_compile_count()


def test_engine_reuses_compile_across_engines_same_pool_key():
    """A second engine with the same pool configuration serves entirely
    from the first engine's executable — zero new chunk-step compiles."""
    from repro.serving.engine import Engine
    m, params = _model()
    rng = np.random.default_rng(4)

    def serve():
        eng = Engine(m, params, max_slots=3, max_seq=64, page_size=8,
                     prefill_chunk_tokens=16)
        for n in (6, 17, 9):
            eng.submit(rng.integers(4, 500, size=n).astype(np.int32),
                       max_new_tokens=2, temperature=0.0)
        assert all(r.error is None for r in eng.run())
        return eng

    serve()                                   # warm (may compile)
    eng = serve()
    c0 = eng.prefill_compile_count()
    serve()
    assert eng.prefill_compile_count() == c0, \
        "same pool key must not compile again"


# ---------------------------------------------------------------------------
# flash-prefill kernel: per-row offsets/extents as data
# ---------------------------------------------------------------------------


def test_flash_prefill_per_row_offsets_match_oracle():
    """Per-row q_offset/q_lens/k_lens (scalar prefetch) vs the jnp
    oracle applied row by row on each valid rectangle."""
    from repro.kernels import ops
    from repro.models.layers import AttnConfig, attention_scores_blockwise
    b, sq, sk, h, kvh, d = 3, 128, 256, 4, 2, 64
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (b, sq, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, sk, kvh, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, sk, kvh, d))
    offs = np.array([0, 64, 128], np.int32)
    qlens = np.array([128, 96, 128], np.int32)
    klens = np.array([128, 160, 256], np.int32)

    out = ops.flash_prefill(q, k, v, causal=True, q_offset=offs,
                            q_lens=qlens, k_lens=klens, interpret=True)
    acfg = AttnConfig(h, kvh, d, q_chunk=64)
    for i in range(b):
        ql, kl, off = int(qlens[i]), int(klens[i]), int(offs[i])
        want = attention_scores_blockwise(
            q[i:i + 1, :ql] * d ** -0.5, k[i:i + 1, :kl], v[i:i + 1, :kl],
            acfg, q_offset=off)
        np.testing.assert_allclose(np.asarray(out[i, :ql]),
                                   np.asarray(want[0]),
                                   rtol=1e-4, atol=1e-4)


def test_flash_prefill_one_compile_across_offsets():
    """Offsets/extents are data, not compile keys: after the first call
    at a shape, different offset/length mixes add ZERO jit-cache
    entries."""
    from repro.kernels import ops
    b, sq, sk, h, kvh, d = 2, 64, 128, 2, 1, 32
    key = jax.random.PRNGKey(12)
    q = jax.random.normal(key, (b, sq, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, sk, kvh, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, sk, kvh, d))
    ops.flash_prefill(q, k, v, causal=True,
                      q_offset=np.zeros(b, np.int32),
                      q_lens=np.full(b, sq, np.int32),
                      k_lens=np.full(b, sk, np.int32), interpret=True)
    c0 = ops.flash_prefill._cache_size()
    for offs in ([0, 64], [32, 0], [64, 64]):
        ops.flash_prefill(q, k, v, causal=True,
                          q_offset=np.asarray(offs, np.int32),
                          q_lens=np.asarray([sq, sq // 2], np.int32),
                          k_lens=np.asarray([sk, sk // 2], np.int32),
                          interpret=True)
    assert ops.flash_prefill._cache_size() == c0, \
        "q_offset/q_lens/k_lens leaked into the compile key"


# ---------------------------------------------------------------------------
# fused paged prefix kernel path (REPRO_FUSED_PREFILL)
# ---------------------------------------------------------------------------


def test_fused_prefill_matches_oracle_and_compiles_once(monkeypatch):
    """The fused kernel path (interpret mode — the Pallas kernel really
    executes) vs the jnp gather oracle over the SAME padded batched
    calls: logits within the reassociation tolerance class, and the
    fused executable count stays at one per pool key while lengths,
    offsets and batch composition churn."""
    from repro.models import transformer
    m, params = _model()
    rng = np.random.default_rng(7)
    ta = rng.integers(4, 500, size=16).astype(np.int32)
    tb = rng.integers(4, 500, size=11).astype(np.int32)

    def serve():
        alloc, cache = _paged(m)
        alloc.ensure(0, 8)
        cache = _fill(alloc, cache, 2, 11)
        _, cache = _run_batch(m, params, cache,
                              [(0, ta[:8], 0), (2, tb, 0)],
                              pad_rows=4, pad_width=24)
        cache = _fill(alloc, cache, 0, 16)
        logits, cache = _run_batch(m, params, cache, [(0, ta[8:], 8)],
                                   pad_rows=4, pad_width=24)
        return np.asarray(logits[0])

    monkeypatch.setenv("REPRO_FUSED_PREFILL", "oracle")
    l_oracle = serve()
    monkeypatch.setenv("REPRO_FUSED_PREFILL", "interpret")
    assert transformer.prefill_fused_mode() == "interpret"
    c0 = transformer.prefill_chunk_compiles(m.cfg)
    l_fused = serve()
    np.testing.assert_allclose(l_fused, l_oracle, rtol=1e-5, atol=5e-6)

    # churn lengths/offsets/composition at the same padded extent:
    # zero fresh fused executables
    for rows in ([(1, tb[:7], 0)],
                 [(0, ta[:5], 0), (1, tb[7:], 7)],
                 [(3, ta[5:9], 0), (0, ta[:8], 0), (2, tb[:6], 0)]):
        alloc, cache = _paged(m)
        for slot, t, off in rows:
            alloc.ensure(slot, off + len(t))
        cache = dict(cache)
        cache["page_table"] = jnp.asarray(alloc.page_table())
        _run_batch(m, params, cache, rows, pad_rows=4, pad_width=24)
    grew = transformer.prefill_chunk_compiles(m.cfg) - c0
    assert grew <= 1, \
        f"fused chunk step compiled {grew}x in one pool key (bound: 1)"


def test_fused_prefill_batch_composition_invariance_bitwise(monkeypatch):
    """Under the fused kernel a valid row's logits and written KV remain
    BITWISE independent of what else shares the padded batch — the
    kernel's grid rows share nothing, so the oracle-path invariance
    carries over exactly."""
    monkeypatch.setenv("REPRO_FUSED_PREFILL", "interpret")
    m, params = _model()
    rng = np.random.default_rng(8)
    ta = rng.integers(4, 500, size=13).astype(np.int32)
    tb = rng.integers(4, 500, size=9).astype(np.int32)

    alloc1, cache1 = _paged(m)
    cache1 = _fill(alloc1, cache1, 0, 13)
    l_alone, cache1 = _run_batch(m, params, cache1, [(0, ta, 0)],
                                 pad_rows=4, pad_width=16)

    alloc2, cache2 = _paged(m)
    alloc2.ensure(0, 13)
    cache2 = _fill(alloc2, cache2, 2, 9)
    l_both, cache2 = _run_batch(m, params, cache2,
                                [(0, ta, 0), (2, tb, 0)],
                                pad_rows=4, pad_width=16)

    np.testing.assert_array_equal(np.asarray(l_alone[0]),
                                  np.asarray(l_both[0]))
    for kk in ("k", "v"):
        np.testing.assert_array_equal(
            _pool_rows(cache1, alloc1, 0, 13, kk),
            _pool_rows(cache2, alloc2, 0, 13, kk))


@pytest.mark.parametrize("kv", [None, "int8"])
def test_fused_whole_prompt_bitexact_vs_oneshot(monkeypatch, kv):
    """A whole prompt served as ONE natural-extent chunk through the
    fused kernel is bit-identical to one-shot prefill (f32) — the
    kernel's empty-prefix state merges with weight exactly zero.  int8
    pools agree to the oracle tolerance (one-shot uses a float cache, so
    code-for-code identity is covered by the multi-chunk tests)."""
    monkeypatch.setenv("REPRO_FUSED_PREFILL", "interpret")
    m, params = _model(kv)
    rng = np.random.default_rng(9)
    prompt = rng.integers(4, 500, size=21).astype(np.int32)
    l_one, _ = m.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                         max_seq=21)
    alloc, cache = _paged(m)
    cache = _fill(alloc, cache, 1, 21)
    l_chunk, _ = m.prefill_chunk(params, jnp.asarray(prompt), cache, 1, 0)
    if kv is None:
        np.testing.assert_array_equal(np.asarray(l_chunk),
                                      np.asarray(l_one))
    else:
        np.testing.assert_allclose(np.asarray(l_chunk),
                                   np.asarray(l_one),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# tensor-parallel meshes: one executable per (pool key, mesh shape)
# ---------------------------------------------------------------------------


def _avail_mesh_sizes():
    return [n for n in (1, 2, 4) if jax.device_count() >= n]


def _serve_traffic(m, params, mesh, lens, seed, kv=None):
    from repro.serving.engine import Engine
    rng = np.random.default_rng(seed)
    eng = Engine(m, params, max_slots=3, max_seq=64, page_size=8,
                 prefill_chunk_tokens=16, mesh=mesh)
    for n in lens:
        eng.submit(rng.integers(4, 500, size=n).astype(np.int32),
                   max_new_tokens=2, temperature=0.0)
    assert all(r.error is None for r in eng.run())
    return eng


def _chunk_count(m, mesh):
    # The compile probe is process-global per (cfg, mesh): earlier tests
    # in the suite may already have warmed this fn with OTHER pool keys,
    # so mesh tests below assert deltas against this snapshot, never
    # absolute counts.
    return m.prefill_compile_count(mesh=mesh)


def test_sharded_engine_one_executable_per_mesh_shape():
    """Under a mesh, the chunk step stays at ONE executable per
    (pool key, mesh shape): traffic churn in prompt lengths, offsets
    and batch composition never grows the count.  Mesh sizes beyond the
    local device count are skipped here and exercised by the CI
    multi-device lane."""
    from repro.launch.mesh import make_serve_mesh
    m, params = _model()
    for msize in _avail_mesh_sizes():
        mesh = make_serve_mesh(msize)
        c0 = _chunk_count(m, mesh)
        eng = _serve_traffic(m, params, mesh, (5, 19, 11), seed=11)
        grew = eng.prefill_compile_count() - c0
        # <= 1, not == 1: an earlier test serving this same (pool key,
        # mesh) already paid the one executable, leaving zero to grow
        assert grew <= 1, \
            f"mesh={msize}: {grew} fresh chunk executables (bound: 1)"
        # wave 2: all-new lengths on a FRESH engine -> zero fresh
        # executables (reuse holds across engines, per mesh)
        eng2 = _serve_traffic(m, params, mesh, (30, 7, 23), seed=12)
        assert eng2.prefill_compile_count() == c0 + grew, \
            f"mesh={msize}: new traffic shapes recompiled the chunk step"


def test_mesh_switch_never_recompiles_other_mesh():
    """Each mesh shape owns an isolated jit entry: serving over mesh B
    must not invalidate or grow mesh A's executable, and returning to A
    serves fully warm.  Requires >=2 devices (the CI multi-device
    lane); on one device the mesh-1-vs-unsharded half still runs."""
    from repro.launch.mesh import make_serve_mesh
    m, params = _model()
    mesh1 = make_serve_mesh(1)

    c_un0 = _chunk_count(m, None)
    c1_0 = _chunk_count(m, mesh1)
    e0 = _serve_traffic(m, params, None, (6, 17, 9), seed=13)
    c_unsharded = e0.prefill_compile_count()
    assert c_unsharded - c_un0 <= 1
    # unsharded serving never touches the mesh-1 entry...
    assert _chunk_count(m, mesh1) == c1_0
    e1 = _serve_traffic(m, params, mesh1, (6, 17, 9), seed=13)
    c1 = e1.prefill_compile_count()
    # ...and mesh-1 serving pays at most its own one executable while
    # leaving the unsharded entry untouched (distinct jit entries)
    assert c1 - c1_0 <= 1
    assert e0.prefill_compile_count() == c_unsharded

    if jax.device_count() >= 2:
        mesh2 = make_serve_mesh(2)
        c2_0 = _chunk_count(m, mesh2)
        e2 = _serve_traffic(m, params, mesh2, (6, 17, 9), seed=13)
        assert e2.prefill_compile_count() - c2_0 <= 1
        c2 = e2.prefill_compile_count()
        # mesh-2 serving left mesh-1's (and unsharded's) entries alone
        assert e1.prefill_compile_count() == c1
        assert e0.prefill_compile_count() == c_unsharded
        # switch back: mesh-1 serves warm, count pinned
        e1b = _serve_traffic(m, params, mesh1, (6, 17, 9), seed=13)
        assert e1b.prefill_compile_count() == c1
        assert e2.prefill_compile_count() == c2


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >=2 devices "
                    "(run under the CI multi-device lane)")
def test_sharded_pool_key_compiles_are_per_quantization(monkeypatch):
    """int8 KV is a different pool key: serving it over the same mesh
    adds exactly one more executable to that mesh's entry and leaves
    the f32 count alone."""
    from repro.launch.mesh import make_serve_mesh
    mesh = make_serve_mesh(2)
    m, params = _model()
    e_f32 = _serve_traffic(m, params, mesh, (5, 19), seed=14)
    c_f32 = e_f32.prefill_compile_count()
    mq, pq = _model("int8")
    pq = mq.quantize(pq)
    cq_0 = _chunk_count(mq, mesh)
    e_q = _serve_traffic(mq, pq, mesh, (5, 19), seed=14)
    # the quantized cfg is its own lru entry; serving it pays at most
    # its own one-per-pool-key executable and leaves f32's count alone
    assert e_q.prefill_compile_count() - cq_0 <= 1
    assert e_f32.prefill_compile_count() == c_f32
