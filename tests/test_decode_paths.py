"""Decode hot path: length-pruned attention, paged attention, fused
weights, and the engine on the paged cache.

Covers the PR-1 acceptance criteria:
  * the pruned kernel provably skips KV tiles beyond each row's length
    (tile-count output in interpret mode) and is bit-exact vs. the full
    scan,
  * paged decode attention matches the dense reference to <=1e-5 (f32 KV)
    / <=1e-2 (int8 KV) for ragged lens including len=0 dead slots,
  * fused QKV / gate-up weights leave model outputs unchanged and drop
    per-layer decode weight GEMVs from 7 to 4,
  * the engine produces identical greedy streams on paged vs. dense
    caches and honors per-request sampling params.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

I = dict(interpret=True)


def _rand_kv(key, b, s, kvh, d):
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, d))
    return k, v


def _quant_kv(kf, vf):
    absk = jnp.max(jnp.abs(kf), -1, keepdims=True)
    absv = jnp.max(jnp.abs(vf), -1, keepdims=True)
    kq = jnp.round(kf / jnp.where(absk > 0, absk, 1.0) * 127).astype(jnp.int8)
    vq = jnp.round(vf / jnp.where(absv > 0, absv, 1.0) * 127).astype(jnp.int8)
    return kq, vq, absk[..., 0] / 127.0, absv[..., 0] / 127.0


# ---------------------------------------------------------------------------
# length pruning
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lens", [[1, 300, 511], [0, 512, 64], [512, 0, 1]])
def test_pruned_bit_exact_vs_full_scan(lens):
    b, s, kvh, hq, d = 3, 512, 2, 2, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, kvh * hq, d)) / np.sqrt(d)
    k, v = _rand_kv(key, b, s, kvh, d)
    lens = jnp.asarray(lens, jnp.int32)
    full = ops.decode_attention(q, k, v, lens, prune=False, block_s=128, **I)
    pruned = ops.decode_attention(q, k, v, lens, prune=True, block_s=128, **I)
    assert bool(jnp.all(full == pruned)), "pruning must be bit-exact"


def test_pruned_tile_counts_skip_dead_tiles():
    """The kernel must execute exactly ceil(len/block_s) of the n_s grid
    tiles per (batch, kv_head) — everything past the length is skipped."""
    b, s, kvh, hq, d = 4, 2048, 2, 2, 64
    block_s = 256
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (b, kvh * hq, d)) / np.sqrt(d)
    k, v = _rand_kv(key, b, s, kvh, d)
    lens = jnp.asarray([1, 200, 2048, 0], jnp.int32)
    _, counts = ops.decode_attention(q, k, v, lens, block_s=block_s,
                                     return_tile_counts=True, **I)
    expect = np.array([-(-int(l) // block_s) for l in [1, 200, 2048, 0]])
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.broadcast_to(expect[:, None], (b, kvh)))
    # and the skip is real: 1+1+8+0 tiles ran out of a 4*8 tile grid
    assert int(counts.sum()) == kvh * int(expect.sum()) < b * kvh * (s // block_s)


def test_pruned_int8_kv_matches_reference():
    b, s, kvh, hq, d = 2, 512, 2, 4, 64
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (b, kvh * hq, d)) / np.sqrt(d)
    kf, vf = _rand_kv(key, b, s, kvh, d)
    kq, vq, ks, vs = _quant_kv(kf, vf)
    lens = jnp.asarray([37, 512], jnp.int32)
    out = ops.decode_attention(q, kq, vq, lens, ks, vs, block_s=128, **I)
    want = ref.ref_decode_attention(q.reshape(b, kvh, hq, d), kq, vq,
                                    lens.reshape(b, 1), ks, vs
                                    ).reshape(b, kvh * hq, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# paged attention kernel
# ---------------------------------------------------------------------------


def _paged_setup(key, nb, bs, kvh, d, b, mb, hq, lens):
    """Build a pool + page table with slot block lists packed arbitrarily."""
    kp = jax.random.normal(key, (nb, bs, kvh, d))
    vp = jax.random.normal(jax.random.fold_in(key, 1), (nb, bs, kvh, d))
    q = jax.random.normal(jax.random.fold_in(key, 2), (b, kvh * hq, d)) / 8
    rng = np.random.default_rng(int(jax.random.fold_in(key, 3)[0]))
    free = list(rng.permutation(nb))
    pt = np.full((b, mb), -1, np.int32)
    for row, ln in enumerate(lens):
        for i in range(-(-ln // bs)):
            pt[row, i] = free.pop()
    return q, kp, vp, jnp.asarray(pt), jnp.asarray(lens, jnp.int32)


@pytest.mark.parametrize("lens", [[170, 20, 0], [0, 0, 0], [256, 1, 64]])
def test_paged_matches_dense_reference_f32(lens):
    nb, bs, kvh, d, b, mb, hq = 16, 64, 2, 64, 3, 4, 4
    q, kp, vp, pt, lens = _paged_setup(jax.random.PRNGKey(3), nb, bs, kvh, d,
                                       b, mb, hq, lens)
    out = ops.paged_decode_attention(q, kp, vp, pt, lens, **I)
    want = ref.ref_paged_decode_attention(
        q.reshape(b, kvh, hq, d), kp, vp, pt, lens).reshape(b, kvh * hq, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_paged_matches_dense_reference_int8():
    nb, bs, kvh, d, b, mb, hq = 12, 32, 2, 64, 3, 4, 2
    q, kp, vp, pt, lens = _paged_setup(jax.random.PRNGKey(4), nb, bs, kvh, d,
                                       b, mb, hq, [100, 128, 0])
    kq, vq, ks, vs = _quant_kv(kp, vp)
    out = ops.paged_decode_attention(q, kq, vq, pt, lens, ks, vs, **I)
    # dense reference on the DEQUANTIZED gathered view
    safe = jnp.maximum(pt, 0)
    kd = (kq.astype(jnp.float32) * ks[..., None])[safe].reshape(
        b, mb * bs, kvh, d)
    vd = (vq.astype(jnp.float32) * vs[..., None])[safe].reshape(
        b, mb * bs, kvh, d)
    want = ref.ref_decode_attention(q.reshape(b, kvh, hq, d), kd, vd,
                                    lens.reshape(b, 1)).reshape(b, kvh * hq, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-2)


def test_paged_tile_counts_skip_unowned_blocks():
    nb, bs, kvh, d, b, mb, hq = 16, 64, 2, 64, 3, 8, 2
    lens = [130, 64, 0]
    q, kp, vp, pt, lens_j = _paged_setup(jax.random.PRNGKey(5), nb, bs, kvh,
                                         d, b, mb, hq, lens)
    _, counts = ops.paged_decode_attention(q, kp, vp, pt, lens_j,
                                           return_tile_counts=True, **I)
    expect = np.array([-(-l // bs) for l in lens])
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.broadcast_to(expect[:, None], (b, kvh)))


def test_paged_ignores_other_slots_blocks():
    """Perturbing blocks owned by OTHER slots must not change a row."""
    nb, bs, kvh, d, b, mb, hq = 8, 32, 1, 64, 2, 4, 2
    q, kp, vp, pt, lens = _paged_setup(jax.random.PRNGKey(6), nb, bs, kvh, d,
                                       b, mb, hq, [64, 32])
    out0 = ops.paged_decode_attention(q, kp, vp, pt, lens, **I)
    owned0 = set(int(x) for x in np.asarray(pt[0]) if x >= 0)
    victim = next(i for i in range(nb) if i not in owned0)
    kp2 = kp.at[victim].set(99.0)
    vp2 = vp.at[victim].set(-99.0)
    out1 = ops.paged_decode_attention(q, kp2, vp2, pt, lens, **I)
    np.testing.assert_array_equal(np.asarray(out0[0]), np.asarray(out1[0]))


# ---------------------------------------------------------------------------
# fused decode weights
# ---------------------------------------------------------------------------


def _tiny_model():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return m, params


def test_fused_weights_identical_outputs_quantized():
    m, params = _tiny_model()
    toks = jnp.array([5, 9], jnp.int32)
    l_unf, _ = m.decode_step(m.quantize(params, fuse_decode=False),
                             m.init_cache(2, 32), toks)
    l_fus, _ = m.decode_step(m.quantize(params, fuse_decode=True),
                             m.init_cache(2, 32), toks)
    np.testing.assert_allclose(np.asarray(l_unf), np.asarray(l_fus),
                               rtol=1e-5, atol=1e-5)


def test_fused_weights_identical_outputs_float():
    from repro.models import transformer as T
    m, params = _tiny_model()
    toks = jnp.array([1, 2], jnp.int32)
    l_unf, _ = m.decode_step(params, m.init_cache(2, 32), toks)
    l_fus, _ = m.decode_step(T.fuse_decode_weights(params, m.cfg),
                             m.init_cache(2, 32), toks)
    np.testing.assert_allclose(np.asarray(l_unf), np.asarray(l_fus),
                               rtol=1e-5, atol=1e-5)


def test_fused_drops_gemvs_from_7_to_4_per_layer(monkeypatch):
    """Count quantized weight GEMV/einsum calls in one decode-step trace.

    The layer stack is a lax.scan, so its body traces once regardless of
    depth: unfused = 7 weight matmuls (q/k/v/o + w1/w3/w2) + 1 lm_head;
    fused = 4 (wqkv / wo_f / w13 / w2) + 1 lm_head.
    """
    from repro.core.quantization import QuantizedTensor
    from repro.models import layers as L
    from repro.models import transformer as T

    m, params = _tiny_model()
    counts = {"n": 0}

    def counting(fn):
        def wrapped(*args):
            if isinstance(args[-1], QuantizedTensor):
                counts["n"] += 1
            return fn(*args)
        return wrapped

    from repro.core.qlinear import qdot, qeinsum
    monkeypatch.setattr(T, "qdot", counting(qdot))
    monkeypatch.setattr(T, "qeinsum", counting(qeinsum))
    monkeypatch.setattr(L, "qdot", counting(qdot))

    toks = jnp.array([3, 4], jnp.int32)

    counts["n"] = 0
    m.decode_step(m.quantize(params, fuse_decode=False),
                  m.init_cache(2, 32), toks)
    unfused = counts["n"]

    counts["n"] = 0
    m.decode_step(m.quantize(params, fuse_decode=True),
                  m.init_cache(2, 32), toks)
    fused = counts["n"]

    assert unfused == 7 + 1, f"unfused traced {unfused} weight GEMVs"
    assert fused == 4 + 1, f"fused traced {fused} weight GEMVs"


def test_fusion_preserves_quantized_values_exactly():
    """wqkv rows must dequantize to exactly wq/wk/wv rows (structural
    concat, no requantization)."""
    from repro.models import transformer as T
    m, params = _tiny_model()
    qp = m.quantize(params, fuse_decode=True)
    blk = jax.tree_util.tree_map(lambda x: x, qp["blocks"])  # stacked (L,…)
    attn = blk["attn"]
    cfg = m.cfg
    hd, nh, kvh = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    l = 0
    fused = jax.tree_util.tree_map(lambda x: x[l], attn["wqkv"]).dequantize()
    wq = jax.tree_util.tree_map(lambda x: x[l], attn["wq"]).dequantize()
    wk = jax.tree_util.tree_map(lambda x: x[l], attn["wk"]).dequantize()
    np.testing.assert_array_equal(
        np.asarray(fused[: nh * hd]),
        np.asarray(wq.reshape(nh * hd, -1)))
    np.testing.assert_array_equal(
        np.asarray(fused[nh * hd: (nh + kvh) * hd]),
        np.asarray(wk.reshape(kvh * hd, -1)))


# ---------------------------------------------------------------------------
# engine on the paged cache
# ---------------------------------------------------------------------------


def _serve_model():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m"))
    m = build_model(cfg)
    return m, m.quantize(m.init(jax.random.PRNGKey(0)))


def _greedy_outputs(m, params, kind, prompts, **eng_kw):
    from repro.serving.engine import Engine
    eng = Engine(m, params, max_slots=2, max_seq=64, cache_kind=kind,
                 page_size=8, **eng_kw)
    for p in prompts:
        eng.submit(p, max_new_tokens=5, temperature=0.0)
    done = sorted(eng.run(), key=lambda r: r.uid)
    return [r.output for r in done], eng


def test_engine_paged_matches_dense_greedy():
    m, params = _serve_model()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, 500, size=n).astype(np.int32)
               for n in (8, 3, 17, 5)]
    paged, eng = _greedy_outputs(m, params, "paged", prompts)
    dense, _ = _greedy_outputs(m, params, "dense", prompts)
    assert eng.paged
    assert paged == dense
    # all slots drained -> every block back in the pool
    assert eng.cache_utilization() == 0.0


def test_engine_paged_small_pool_recycles_blocks():
    """A pool far smaller than max_slots*max_seq serves sequential traffic
    by recycling released blocks — the memory win paging exists for."""
    m, params = _serve_model()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(4, 500, size=6).astype(np.int32)
               for _ in range(6)]
    outs, eng = _greedy_outputs(m, params, "paged", prompts, n_pages=4)
    assert len(outs) == 6 and all(len(o) == 5 for o in outs)
    dense, _ = _greedy_outputs(m, params, "dense", prompts)
    assert outs == dense


def test_engine_per_request_sampling_params():
    """temperature=0 rows must be argmax even when batched with hot rows
    (the seed engine silently sampled everyone at defaults)."""
    from repro.serving.engine import Engine
    m, params = _serve_model()
    rng = np.random.default_rng(2)
    prompt = rng.integers(4, 500, size=6).astype(np.int32)

    greedy_ref, _ = _greedy_outputs(m, params, "paged", [prompt])

    eng = Engine(m, params, max_slots=2, max_seq=64, page_size=8, seed=123)
    eng.submit(prompt, max_new_tokens=5, temperature=0.0)
    eng.submit(prompt, max_new_tokens=5, temperature=5.0, top_p=1.0)
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert done[0].output == greedy_ref[0]


def test_sample_logits_vectorized_params():
    from repro.serving.engine import sample_logits
    logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05],
                                  [0.05, 0.15, 0.3, 0.5]]))
    t = jnp.asarray([1.0, 0.0])
    p = jnp.asarray([0.6, 1.0])
    seen0 = set()
    for i in range(64):
        tok = sample_logits(jax.random.PRNGKey(i), logits, t, p)
        seen0.add(int(tok[0]))
        assert int(tok[1]) == 3          # greedy row: always argmax
    assert seen0 <= {0, 1}               # nucleus of row 0 at top_p=0.6


def test_engine_int8_kv_paged():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m")).with_(kv_cache_dtype="int8")
    m = build_model(cfg)
    params = m.quantize(m.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(4, 500, size=n).astype(np.int32) for n in (9, 4)]
    paged, _ = _greedy_outputs(m, params, "paged", prompts)
    dense, _ = _greedy_outputs(m, params, "dense", prompts)
    assert paged == dense
