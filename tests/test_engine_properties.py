"""Engine-level property tests: random mixed-``n_samples`` traffic on a
deliberately tiny pool, with the serving stack's global invariants
asserted after EVERY step.

Where tests/test_paged_cache.py drives the *allocator* with random op
sequences, this harness drives the whole engine (scheduler + allocator +
device pool + sampling groups) with random *traffic* — prompt lengths,
``n_samples`` in 1..4, ``max_new_tokens``, greedy and seeded-sampled
requests — over pools small enough that admission deferral, preemption,
group fanout, COW un-sharing and LRU eviction all trigger constantly.
After every ``run(max_steps=1)``:

  * ``BlockAllocator.debug_check`` — refcounts == page-table
    multiplicity, every block in exactly one of {free, LRU, leased},
    index coherent;
  * **registered blocks are immutable**: a block's pool content (layer-0
    K rows) must be bit-identical across steps for as long as its
    registration epoch lasts (epoch tracked by wrapping
    ``register_block``; eviction + re-registration starts a new epoch);
  * **COW never writes a registered or shared block**: the engine's
    device-copy entry point is wrapped so every executed (src, dst) pair
    asserts dst is an unregistered ref-1 exclusive block at copy time;

and at drain: every lease is released (all refcounts zero, the whole
pool reclaimable — no leak), and every request came back exactly once
with ``outputs`` of the right arity.

The hypothesis variants self-skip when the library is missing (CI image)
— the seeded deterministic twins below them run everywhere and are what
ci/run_ci.sh pins.
"""

import jax
import numpy as np
import pytest

from _hyp import given, settings, st

import repro.serving.engine as engine_mod
from repro.serving.engine import Engine
from repro.serving.faults import FaultPlan

# few distinct prompt lengths -> few (B, chunk_len, pos_offset) compile
# triples; the allocator-level variety comes from the pool being tiny
PROMPT_LENS = (3, 4, 7, 8, 12, 16)


@pytest.fixture(scope="module")
def model_params():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m")).with_(compute_dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _serve_and_check(model, params, specs, n_pages, max_slots=4,
                     page_size=4, max_seq=48, chunk=8, faults=None,
                     audit_interval=0, spec_tokens=0, draft_proposer=None,
                     mesh=None):
    """Serve ``specs`` step-by-step, asserting the invariants above.

    Each spec is (prompt_len_index, n_samples, max_new_tokens, greedy,
    seed); prompts are deterministic in the seed.  ``faults`` threads a
    FaultPlan through (with ``audit_interval=1`` so injected page-table
    corruption is caught and repaired before this harness's own per-step
    ``debug_check`` sees it) — faulted requests come back errored but
    every drain invariant must hold regardless.
    """
    eng = Engine(model, params, max_slots=max_slots, max_seq=max_seq,
                 page_size=page_size, n_pages=n_pages,
                 prefill_chunk_tokens=chunk, faults=faults,
                 audit_interval=audit_interval, spec_tokens=spec_tokens,
                 draft_proposer=draft_proposer, mesh=mesh)
    pager = eng.pager

    # -- instrumentation ------------------------------------------------
    # registration epochs: eviction + re-fill may legitimately rebuild a
    # block (recompute-on-resume can produce last-ulp-different KV for
    # the same token prefix), so immutability is asserted per epoch
    reg_epoch = {}
    orig_register = pager.register_block

    def register_epoch(slot, block_index, h, tokens):
        # no speculative KV ever reaches the prefix index: a block may
        # only register when it sits entirely below the sequence's
        # (already rolled-back) kv_len and holds exactly the committed
        # stream's token ids — a registration attempted before a verify
        # rollback would trip both assertions
        seq = eng.scheduler.running.get(slot)
        if seq is not None:
            bs = pager.cfg.block_size
            lo = block_index * bs
            assert lo + bs <= seq.kv_len, \
                f"block {block_index} registers past kv_len {seq.kv_len}"
            ids = np.concatenate(
                [seq.prompt, np.asarray(seq.output or [], np.int32)])
            assert np.array_equal(np.asarray(tokens), ids[lo:lo + bs]), \
                "registered block content is not the committed stream"
        orig_register(slot, block_index, h, tokens)
        bid = pager.owned[slot][block_index]
        if pager.block_hash[bid] is not None:
            reg_epoch[bid] = reg_epoch.get(bid, 0) + 1

    pager.register_block = register_epoch
    orig_copy = engine_mod._copy_pool_blocks

    def checked_copy(attn, src, dst):
        for d in np.asarray(dst):
            d = int(d)
            assert pager.block_hash[d] is None, \
                f"COW pair writes registered block {d}"
            assert pager.refcount[d] == 1, \
                f"COW dst {d} is shared (ref {pager.refcount[d]})"
        return orig_copy(attn, src, dst)

    engine_mod._copy_pool_blocks = checked_copy

    uids = {}
    try:
        for (pi, n_samples, max_new, greedy, seed) in specs:
            plen = PROMPT_LENS[pi % len(PROMPT_LENS)]
            prompt = (np.random.default_rng(seed)
                      .integers(4, 500, size=plen).astype(np.int32))
            uid = eng.submit(prompt, max_new_tokens=max_new,
                             temperature=0.0 if greedy else 1.0,
                             seed=seed, n_samples=n_samples)
            uids[uid] = (plen, n_samples, max_new)

        done = []
        reg_seen = {}           # bid -> (hash, epoch, content bytes)
        steps = 0
        while eng.scheduler.has_work():
            steps += 1
            assert steps <= 2000, "engine failed to drain the traffic"
            done += eng.run(max_steps=1)
            pager.debug_check()
            kpool = np.asarray(eng.cache["attn"]["k"])   # (L, NB, BS, ...)
            cur = {}
            for bid in range(pager.cfg.n_blocks):
                h = pager.block_hash[bid]
                if h is not None:
                    cur[bid] = (h, reg_epoch.get(bid, 0),
                                kpool[0, bid].tobytes())
            for bid, (h, epoch, blob) in cur.items():
                prev = reg_seen.get(bid)
                if prev is not None and prev[:2] == (h, epoch):
                    assert prev[2] == blob, \
                        f"registered block {bid} rewritten in place"
            reg_seen = cur
    finally:
        engine_mod._copy_pool_blocks = orig_copy
        pager.register_block = orig_register

    # -- drain: nothing leaked ------------------------------------------
    assert all(rc == 0 for rc in pager.refcount), \
        "refcounts not fully released at drain"
    assert pager.n_free() == pager.cfg.n_blocks, "blocks leaked at drain"
    assert pager.utilization() == 0.0

    by_uid = {r.uid: r for r in done}
    assert sorted(by_uid) == sorted(uids), "requests lost or duplicated"
    for uid, (plen, n, max_new) in uids.items():
        r = by_uid[uid]
        if r.error is not None:
            continue            # tiny pool: never-fits rejections are fine
        assert r.outputs is not None and len(r.outputs) == n
        assert r.output is r.outputs[0]
        for o in r.outputs:
            assert 1 <= len(o) <= max_new
    return eng, by_uid


SPEC = st.tuples(st.integers(0, len(PROMPT_LENS) - 1),   # prompt length
                 st.integers(1, 4),                      # n_samples
                 st.integers(2, 6),                      # max_new_tokens
                 st.booleans(),                          # greedy?
                 st.integers(0, 99))                     # PRNG seed


class TestEngineInvariantProperties:
    @settings(max_examples=5, deadline=None, derandomize=True)
    @given(specs=st.lists(SPEC, min_size=1, max_size=5),
           pool=st.integers(8, 16))
    def test_random_group_traffic_prop(self, model_params, specs, pool):
        model, params = model_params
        _serve_and_check(model, params, specs, n_pages=pool)

    @pytest.mark.parametrize("seed", range(4))
    def test_random_group_traffic_seeded(self, model_params, seed):
        """Deterministic twin of the hypothesis property (the CI image
        has no hypothesis — ci/run_ci.sh pins exactly these seeds)."""
        model, params = model_params
        rng = np.random.default_rng(seed)
        specs = [(int(rng.integers(0, len(PROMPT_LENS))),
                  int(rng.integers(1, 5)), int(rng.integers(2, 7)),
                  bool(rng.integers(0, 2)), int(rng.integers(0, 100)))
                 for _ in range(5)]
        # pool of 8-14 blocks of 4 tokens: far below the 5-request demand
        pool = 8 + int(rng.integers(0, 7))
        eng, _ = _serve_and_check(model, params, specs, n_pages=pool)
        assert eng.metrics["decode_steps"] > 0

    def test_seeded_fault_schedule_traffic(self, model_params):
        """Random group traffic under a seeded fault schedule hitting
        every injection class: a transient blip, a persistent per-request
        fault, a NaN row, page-table corruption and a stall.  Implicated
        requests come back with typed errors, everyone returns exactly
        once, and the per-step + drain invariants (immutable registered
        blocks, clean audits, zero leaked refcounts) hold throughout."""
        model, params = model_params
        rng = np.random.default_rng(11)
        specs = [(int(rng.integers(0, len(PROMPT_LENS))),
                  int(rng.integers(1, 5)), int(rng.integers(2, 7)),
                  bool(rng.integers(0, 2)), int(rng.integers(0, 100)))
                 for _ in range(5)]
        plan = (FaultPlan(seed=11)
                .step_exception(step=2, times=1)           # transient
                .step_exception(step=4, uid=2, times=10**6)
                .nan_logits(step=6, uid=3)
                .corrupt_pages(step=8, uid=1)
                .stall(step=3))
        eng, by_uid = _serve_and_check(model, params, specs, n_pages=12,
                                       faults=plan, audit_interval=1)
        assert eng.metrics["step_retries"] >= 1
        # uid-targeted persistent/NaN faults fire whenever their target
        # is dispatched past the armed step; on this seed all three
        # implicated requests are in flight then
        failed = {u: r.error_kind for u, r in by_uid.items()
                  if r.error is not None}
        assert failed, "the fault schedule must implicate someone"
        assert eng.metrics["requests_failed"] >= 1

    def test_midrun_injection_matches_upfront(self, model_params):
        """Continuous-arrival coverage: the same specs served (a) all
        submitted up front and drained closed-loop, (b) injected one at
        a time BETWEEN steps — with one reserved until right after the
        first preemption is observed — must stream bit-identically in
        the same arrival order, with the allocator audit clean after
        every step and zero leaks at drain."""
        model, params = model_params
        # singles + one fanout on a pool far below total demand: the
        # midrun run must see preemption while injections are pending
        specs = [(2, 1, 5, False, 31), (4, 2, 4, True, 32),
                 (3, 1, 6, False, 33), (2, 2, 4, False, 34),
                 (1, 1, 5, True, 35), (3, 1, 4, False, 36)]
        pool = 10

        def prompt_of(spec):
            plen = PROMPT_LENS[spec[0] % len(PROMPT_LENS)]
            return (np.random.default_rng(spec[4])
                    .integers(4, 500, size=plen).astype(np.int32))

        def submit(eng, spec):
            _, n_samples, max_new, greedy, seed = spec
            return eng.submit(prompt_of(spec), max_new_tokens=max_new,
                              temperature=0.0 if greedy else 1.0,
                              seed=seed, n_samples=n_samples)

        # (a) reference: everything up front, full invariant sweep
        _, ref = _serve_and_check(model, params, specs, n_pages=pool)

        # (b) same arrival order, injected mid-run
        eng = Engine(model, params, max_slots=4, max_seq=48,
                     page_size=4, n_pages=pool, prefill_chunk_tokens=8)
        pager = eng.pager
        for spec in specs[:2]:
            submit(eng, spec)
        nxt = 2
        post_preempt_spec = specs[-1]   # held back for the preemption
        injected_after_preempt = False
        done, steps = [], 0
        while eng.scheduler.has_work():
            steps += 1
            assert steps <= 2000, "engine failed to drain the traffic"
            done += eng.run(max_steps=1)
            pager.debug_check()
            if not injected_after_preempt and eng.scheduler.n_preempted:
                submit(eng, post_preempt_spec)
                injected_after_preempt = True
            elif nxt < len(specs) - 1 and steps % 2 == 0:
                submit(eng, specs[nxt])
                nxt += 1
        assert injected_after_preempt, \
            "traffic never preempted; the scenario is vacuous"
        assert nxt == len(specs) - 1, "not every spec was injected"

        assert all(rc == 0 for rc in pager.refcount)
        assert pager.n_free() == pager.cfg.n_blocks
        # arrival order is (specs[0], specs[1], specs[2], ..., with the
        # reserved spec's position depending on when preemption hit) —
        # but uids map 1:1 to submission order in BOTH runs only for
        # the first len(specs)-1... compare by uid of submission index:
        # upfront run uids are 1..6 in specs order; midrun uids follow
        # ITS submission order.  Match streams by the spec each uid
        # served, which is unambiguous because seeds differ per spec.
        by_seed_ref = {r.seed: r for r in ref.values()}
        assert len(by_seed_ref) == len(specs)
        for r in done:
            want = by_seed_ref[r.seed]
            assert (r.error is None) == (want.error is None)
            if r.error is None:
                got = tuple(tuple(o) for o in (r.outputs or [r.output]))
                exp = tuple(tuple(o)
                            for o in (want.outputs or [want.output]))
                assert got == exp, \
                    f"midrun stream diverged for seed {r.seed}"

    def test_oversubscribed_group_heavy_traffic_preempts(self, model_params):
        """All-groups traffic on a pool that cannot hold two fanned
        groups at once: fanout, COW, unit preemption and resume all fire,
        and the invariant sweep still holds at every step."""
        model, params = model_params
        # prompt lens 7 and 12: the 7-token prompts end mid-block, so
        # their fanouts COW the shared partial tail
        specs = [(2, 4, 4, False, 7), (4, 3, 4, False, 8),
                 (2, 4, 5, True, 9)]
        eng, by_uid = _serve_and_check(model, params, specs, n_pages=10)
        assert eng.metrics["fanouts"] >= 2
        assert eng.metrics["cow_copies"] > 0
        ok = [r for r in by_uid.values() if r.error is None]
        assert ok, "at least some groups must complete on 10 blocks"


class _FlakyProposer:
    """Deterministically random-quality drafts: per call, nothing,
    garbage token ids (always rejected — maximal rollback), or n-gram
    self-speculation (sometimes accepted once the untrained model starts
    looping).  The point is a random accept/reject schedule, not draft
    quality."""

    def __init__(self, seed):
        self.rng = np.random.default_rng(seed)
        from repro.serving.spec_decode import NgramProposer
        self.ngram = NgramProposer()

    def propose(self, prompt, output, k):
        r = int(self.rng.integers(0, 4))
        if r == 0:
            return []
        if r == 1:
            return [int(t) for t in self.rng.integers(4, 500, size=k)]
        return self.ngram.propose(prompt, output, k)


class TestSpecDecodeRollbackProperties:
    """Rollback-as-truncation under random accept/reject schedules on
    tiny pools: every step's ``audit().clean`` (via ``debug_check``),
    registered blocks hold only committed tokens (the wrapped
    ``register_block`` above — speculative KV can never reach the prefix
    index), and the drained pool leaks nothing — while speculation
    interleaves with admission deferral, preemption, fanout and COW."""

    @settings(max_examples=5, deadline=None, derandomize=True)
    @given(specs=st.lists(SPEC, min_size=1, max_size=4),
           pool=st.integers(8, 16), k=st.integers(1, 3),
           pseed=st.integers(0, 9))
    def test_random_accept_reject_prop(self, model_params, specs, pool,
                                       k, pseed):
        model, params = model_params
        _serve_and_check(model, params, specs, n_pages=pool,
                         spec_tokens=k,
                         draft_proposer=_FlakyProposer(pseed))

    @pytest.mark.parametrize("seed", range(3))
    def test_random_accept_reject_seeded(self, model_params, seed):
        """Deterministic twin (pinned by ci/run_ci.sh on the
        hypothesis-less CI image) — asserts the traffic genuinely
        speculated and rolled back, so the property is not vacuous."""
        model, params = model_params
        rng = np.random.default_rng(100 + seed)
        specs = [(int(rng.integers(0, len(PROMPT_LENS))),
                  int(rng.integers(1, 4)), int(rng.integers(3, 7)),
                  bool(rng.integers(0, 2)), int(rng.integers(0, 100)))
                 for _ in range(4)]
        pool = 9 + int(rng.integers(0, 6))
        eng, _ = _serve_and_check(model, params, specs, n_pages=pool,
                                  spec_tokens=2,
                                  draft_proposer=_FlakyProposer(seed))
        assert eng.metrics["draft_tokens"] > 0
        assert eng.metrics["spec_rollbacks"] > 0
        assert eng.metrics["verify_steps"] > 0


class TestShardedEngineProperties:
    """The full invariant sweep on a mesh-sharded engine: the allocator
    must not be able to tell how many devices sit under the pool.  Same
    harness as above (per-step ``debug_check``, registered-block
    immutability over the *gathered* pool bytes, COW dst exclusivity,
    zero leaked refcounts at drain) plus an explicit ``audit().clean``
    and a host-state comparison against the unsharded engine serving
    identical traffic.

    Derandomized (no hypothesis dependency): mesh sizes above the local
    device count self-skip — the CI multi-device lane re-runs this file
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` where
    all three sizes execute for real."""

    MESH_SIZES = (1, 2, 4)

    @staticmethod
    def _specs(seed):
        rng = np.random.default_rng(seed)
        return [(int(rng.integers(0, len(PROMPT_LENS))),
                 int(rng.integers(1, 5)), int(rng.integers(2, 7)),
                 bool(rng.integers(0, 2)), int(rng.integers(0, 100)))
                for _ in range(5)], 8 + int(rng.integers(0, 7))

    @pytest.mark.parametrize("msize", MESH_SIZES)
    def test_sharded_traffic_invariants(self, model_params, msize):
        if jax.device_count() < msize:
            pytest.skip(f"needs {msize} devices (CI multi-device lane)")
        from repro.launch.mesh import make_serve_mesh
        model, params = model_params
        specs, pool = self._specs(3)
        eng, by_uid = _serve_and_check(model, params, specs, n_pages=pool,
                                       mesh=make_serve_mesh(msize))
        assert eng.pager.audit().clean
        assert eng.metrics["decode_steps"] > 0

        # device-count agnosticism: the unsharded engine on identical
        # traffic must leave identical host-side state — same plans,
        # same preemption/COW/prefix traffic, same streams.  Only array
        # placement may differ.
        ref, ref_uid = _serve_and_check(model, params, specs,
                                        n_pages=pool)
        for key in ("preemptions", "cow_copies", "fanouts",
                    "prefix_hits", "chunk_batch_calls", "decode_steps",
                    "tokens_out"):
            assert eng.metrics[key] == ref.metrics[key], key
        assert len(eng.plan_log) == len(ref.plan_log)
        assert sorted(by_uid) == sorted(ref_uid)
        for uid, r in by_uid.items():
            w = ref_uid[uid]
            got = tuple(tuple(o) for o in (r.outputs or []))
            exp = tuple(tuple(o) for o in (w.outputs or []))
            assert got == exp, f"sharded stream diverged for uid {uid}"
