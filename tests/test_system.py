"""End-to-end behaviour tests: train->checkpoint->resume->quantize->serve
— the full HLSTransform lifecycle on a reduced model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch import train as trainlib
from repro.models import build_model


def test_train_loss_decreases(tmp_path):
    losses = trainlib.run(arch="llama2-110m", steps=30, batch=4, seq=128,
                          use_reduced=True, ckpt_dir=str(tmp_path),
                          ckpt_every=15, log_every=100)
    assert len(losses) == 30
    assert losses[-1] < losses[0]          # synthetic language is learnable
    assert all(np.isfinite(l) for l in losses)


def test_train_resume_continues(tmp_path):
    l1 = trainlib.run(arch="llama2-110m", steps=20, batch=2, seq=64,
                      ckpt_dir=str(tmp_path), ckpt_every=10, log_every=100)
    l2 = trainlib.run(arch="llama2-110m", steps=30, batch=2, seq=64,
                      ckpt_dir=str(tmp_path), ckpt_every=10, log_every=100)
    # resumed run starts at step 20 and only runs 10 more
    assert len(l2) == 10


def test_grad_compression_trains(tmp_path):
    losses = trainlib.run(arch="llama2-110m", steps=20, batch=2, seq=64,
                          log_every=100, grad_compress=True)
    assert losses[-1] < losses[0] + 0.05


def test_microbatched_matches_full_batch():
    """Grad accumulation must give the same first-step update direction."""
    from repro.configs.base import ShapeCell
    from repro.launch import steps as steplib
    from repro.optim import adamw
    cfg = reduced(get_config("llama2-110m")).with_(compute_dtype="float32",
                                                   remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32),
                                          0, cfg.vocab_size)}
    ocfg = adamw.AdamWConfig()
    state = {"params": params, "opt": adamw.init_state(params)}
    s1, m1 = steplib.make_train_step(model, ocfg, microbatches=1)(state, batch)
    state2 = {"params": params, "opt": adamw.init_state(params)}
    s2, m2 = steplib.make_train_step(model, ocfg, microbatches=4)(state2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    w1 = np.asarray(jax.tree_util.tree_leaves(s1["params"])[0])
    w2 = np.asarray(jax.tree_util.tree_leaves(s2["params"])[0])
    np.testing.assert_allclose(w1, w2, rtol=1e-4, atol=1e-5)


def test_full_lifecycle_quantize_serve(tmp_path):
    """Train a tiny model, quantize per the paper, serve, check output."""
    from repro.core import QuantPolicy
    from repro.serving.engine import Engine
    trainlib.run(arch="llama2-110m", steps=10, batch=2, seq=64,
                 ckpt_dir=str(tmp_path), ckpt_every=10, log_every=100)
    from repro.checkpoint import store
    cfg = reduced(get_config("llama2-110m"))
    model = build_model(cfg)
    params0 = model.init(jax.random.PRNGKey(0))
    restored, step, _ = store.restore(tmp_path, {"params": params0})
    qparams = model.quantize(restored["params"], QuantPolicy(min_size=256))
    eng = Engine(model, qparams, max_slots=2, max_seq=96)
    eng.submit(np.arange(4, 12, dtype=np.int32), max_new_tokens=4)
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) >= 1
