"""Per-kernel interpret-mode validation vs the pure-jnp oracles.

Each Pallas kernel is swept across shapes/dtypes and assert_allclose'd
against ref.py (the system prompt's per-kernel requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import quantize
from repro.kernels import ops, ref

I = dict(interpret=True)


@pytest.mark.parametrize("m,n,k", [
    (1, 64, 128), (4, 256, 512), (8, 512, 256), (2, 96, 192),
])
def test_q8_matvec_shapes(m, n, k):
    key = jax.random.PRNGKey(m * 1000 + n + k)
    x = jax.random.normal(key, (m, k))
    w = quantize(jax.random.normal(jax.random.fold_in(key, 1), (n, k)))
    out = ops.q8_matmul(x, w, **I)
    xq = quantize(x)
    want = ref.ref_q8_matmul(xq.q, xq.scale, w.q, w.scale, w.group_size)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (128, 256, 512, 64, 128, 256),
    (256, 512, 1024, 128, 256, 512),
    (64, 128, 128, 64, 128, 128),
])
def test_q8_gemm_blocks(m, n, k, bm, bn, bk):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k))
    w = quantize(jax.random.normal(jax.random.fold_in(key, 1), (n, k)))
    out = ops.q8_matmul(x, w, block_m=bm, block_n=bn, block_k=bk, **I)
    xq = quantize(x)
    want = ref.ref_q8_matmul(xq.q, xq.scale, w.q, w.scale, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("gs", [32, 64, 128])
def test_q8_group_sizes(gs):
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 256))
    w = quantize(jax.random.normal(jax.random.PRNGKey(3), (128, 256)),
                 group_size=gs)
    out = ops.q8_matmul(x, w, **I)
    xq = quantize(x, group_size=gs)
    want = ref.ref_q8_matmul(xq.q, xq.scale, w.q, w.scale, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k", [(4, 256), (16, 512), (256, 1024), (3, 192)])
def test_rmsnorm_quant(m, k):
    x = jax.random.normal(jax.random.PRNGKey(m + k), (m, k)) * 3.0
    g = jax.random.normal(jax.random.PRNGKey(1), (k,))
    qk, sk = ops.rmsnorm_quant(x, g, **I)
    qr, sr = ref.ref_rmsnorm_quant(x, g)
    assert int(jnp.sum(qk != qr)) == 0
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,d", [(4, 8, 128), (2, 12, 64), (16, 2, 128)])
def test_rope_kernel(b, h, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(b * h), (b, h, d)).astype(dtype)
    ang = jax.random.uniform(jax.random.PRNGKey(5), (b, d // 2)) * 6.28
    cos = jnp.concatenate([jnp.cos(ang)] * 2, -1)
    sin = jnp.concatenate([jnp.sin(ang)] * 2, -1)
    out = ops.rope(x, cos, sin, **I)
    want = ref.ref_rope(x, cos[:, None, :], sin[:, None, :])
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-2)


@pytest.mark.parametrize("n,k", [(128, 256), (512, 512), (96, 128)])
def test_q4_matvec(n, k):
    x = jax.random.normal(jax.random.PRNGKey(n), (4, k))
    w = quantize(jax.random.normal(jax.random.PRNGKey(k), (n, k)), bits=4)
    out = ops.q8_matmul(x, w, **I)
    xq = quantize(x)
    want = ref.ref_q4_matvec(xq.q, xq.scale, w.q, w.scale, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,s,kvh,hq,d", [
    (2, 1024, 4, 4, 128), (1, 512, 2, 8, 64), (4, 2048, 1, 4, 128),
])
def test_decode_attention_fp(b, s, kvh, hq, d):
    key = jax.random.PRNGKey(b * s)
    q = jax.random.normal(key, (b, kvh * hq, d)) / np.sqrt(d)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, d))
    lens = jnp.asarray(np.random.default_rng(0).integers(1, s, b), jnp.int32)
    out = ops.decode_attention(q, k, v, lens, **I)
    want = ref.ref_decode_attention(
        q.reshape(b, kvh, hq, d), k, v, lens.reshape(b, 1)
    ).reshape(b, kvh * hq, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_decode_attention_int8_kv():
    """Beyond-paper int8 KV cache: kernel matches dequantized reference."""
    b, s, kvh, hq, d = 2, 512, 2, 4, 64
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (b, kvh * hq, d)) / np.sqrt(d)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, d))
    vf = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, d))
    absk = jnp.max(jnp.abs(kf), -1, keepdims=True)
    absv = jnp.max(jnp.abs(vf), -1, keepdims=True)
    kq = jnp.round(kf / absk * 127).astype(jnp.int8)
    vq = jnp.round(vf / absv * 127).astype(jnp.int8)
    ks = (absk[..., 0] / 127.0)
    vs = (absv[..., 0] / 127.0)
    lens = jnp.array([300, 512], jnp.int32)
    out = ops.decode_attention(q, kq, vq, lens, ks, vs, **I)
    want = ref.ref_decode_attention(q.reshape(b, kvh, hq, d), kq, vq,
                                    lens.reshape(b, 1), ks, vs
                                    ).reshape(b, kvh * hq, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_decode_attention_matches_fp_within_quant_error():
    b, s, kvh, hq, d = 1, 256, 2, 2, 64
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (b, kvh * hq, d)) / np.sqrt(d)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, d))
    vf = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, d))
    lens = jnp.array([s], jnp.int32)
    fp = ops.decode_attention(q, kf, vf, lens, **I)
    absk = jnp.max(jnp.abs(kf), -1, keepdims=True)
    absv = jnp.max(jnp.abs(vf), -1, keepdims=True)
    kq = jnp.round(kf / absk * 127).astype(jnp.int8)
    vq = jnp.round(vf / absv * 127).astype(jnp.int8)
    i8 = ops.decode_attention(q, kq, vq, lens,
                              absk[..., 0] / 127, absv[..., 0] / 127, **I)
    np.testing.assert_allclose(np.asarray(i8), np.asarray(fp),
                               rtol=0.1, atol=0.05)


# ---------------------------------------------------------------------------
# paged prefill attention (fused chunked-prefill prefix read)
# ---------------------------------------------------------------------------


def _paged_prefill_case(seed, b, c, kvh, hq, d, bs, mb, offs, lens):
    """Random pool + a page table whose live prefix blocks are a
    permutation (shared nothing), with entries past each row's prefix
    extent left unassigned (-1) — the kernel must never read them."""
    rng = np.random.default_rng(seed)
    h = kvh * hq
    nb = b * mb + 1
    q = jnp.asarray(rng.standard_normal((b, c, h, d)),
                    jnp.float32) * d ** -0.5
    k_pool = jnp.asarray(rng.standard_normal((nb, bs, kvh, d)), jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((nb, bs, kvh, d)), jnp.float32)
    ids = rng.permutation(nb - 1)[:b * mb].reshape(b, mb) + 1
    pt = np.full((b, mb), -1, np.int32)
    for i in range(b):
        nlive = -(-int(offs[i]) // bs)
        pt[i, :nlive] = ids[i, :nlive]
    return q, k_pool, v_pool, jnp.asarray(pt), \
        jnp.asarray(offs, dtype=jnp.int32), jnp.asarray(lens,
                                                        dtype=jnp.int32)


@pytest.mark.parametrize("b,c,kvh,hq,d,bs,mb", [
    (3, 32, 2, 2, 32, 8, 8),     # GQA, prefix crossing block boundaries
    (2, 48, 1, 4, 32, 16, 6),    # single kv head, wide fanout
    (4, 16, 4, 1, 64, 8, 12),    # MHA, many small blocks
])
def test_paged_prefill_attention_fp(b, c, kvh, hq, d, bs, mb):
    """Kernel vs gather-then-dense oracle, per-row offsets/lengths:
    rows cover empty prefix, a prefix ending mid-block, a full-extent
    prefix, and a zero-length padding row."""
    offs = np.zeros(b, np.int32)
    lens = np.full(b, c, np.int32)
    offs[1] = bs * 2 + 3                 # crosses a block boundary
    lens[1] = c // 2                     # partial chunk
    offs[-1] = mb * bs                   # full extent
    lens[-1] = 0                         # padding row (never compared)
    q, kp, vp, pt, offs, lens = _paged_prefill_case(
        0, b, c, kvh, hq, d, bs, mb, offs, lens)
    out, m, l = ops.paged_prefill_attention(q, kp, vp, pt, offs, lens, **I)
    ro, rm, rl = ref.ref_paged_prefill_attention(q, kp, vp, pt, offs)
    out, m, l = np.asarray(out), np.asarray(m), np.asarray(l)
    ro, rm, rl = np.asarray(ro), np.asarray(rm), np.asarray(rl)
    for i in range(b):
        n = int(lens[i])                 # rows past lens are dead tiles
        np.testing.assert_allclose(out[i, :n], ro[i, :n],
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(m[i, :, :n], rm[i, :, :n], rtol=1e-5)
        np.testing.assert_allclose(l[i, :, :n], rl[i, :, :n],
                                   rtol=1e-5, atol=1e-6)
    # empty-prefix rows carry the exact zero-weight merge state
    assert np.all(out[0] == 0.0) and np.all(l[0] == 0.0)
    assert np.all(m[0] == -1e30)


def test_paged_prefill_attention_int8():
    """In-kernel dequant via the per-(position, kv-head) scale pools
    matches the oracle's gather-then-dequant."""
    b, c, kvh, hq, d, bs, mb = 3, 32, 2, 2, 32, 8, 8
    offs = np.array([0, 19, mb * bs], np.int32)
    lens = np.array([c, c - 5, c], np.int32)
    q, kp, vp, pt, offs, lens = _paged_prefill_case(
        1, b, c, kvh, hq, d, bs, mb, offs, lens)
    absk = jnp.max(jnp.abs(kp), -1, keepdims=True)
    absv = jnp.max(jnp.abs(vp), -1, keepdims=True)
    kq = jnp.round(kp / absk * 127).astype(jnp.int8)
    vq = jnp.round(vp / absv * 127).astype(jnp.int8)
    ks, vs = absk[..., 0] / 127.0, absv[..., 0] / 127.0
    out, m, l = ops.paged_prefill_attention(q, kq, vq, pt, offs, lens,
                                            ks, vs, **I)
    ro, rm, rl = ref.ref_paged_prefill_attention(q, kq, vq, pt, offs,
                                                 ks, vs)
    for i in range(b):
        n = int(lens[i])
        np.testing.assert_allclose(np.asarray(out)[i, :n],
                                   np.asarray(ro)[i, :n],
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(l)[i, :, :n],
                                   np.asarray(rl)[i, :, :n],
                                   rtol=1e-4, atol=1e-5)


def test_paged_prefill_attention_skips_dead_tiles():
    """The prefetch-driven guard is real: per-(row, kv-head) live-tile
    counts equal ceil(prefix/block) exactly — tiles past a row's prefix
    extent (and every tile of an empty-prefix row) never run."""
    b, c, kvh, hq, d, bs, mb = 3, 32, 2, 2, 32, 8, 8
    offs = np.array([0, 19, mb * bs], np.int32)
    lens = np.full(b, c, np.int32)
    q, kp, vp, pt, offs_j, lens_j = _paged_prefill_case(
        2, b, c, kvh, hq, d, bs, mb, offs, lens)
    *_, cnt = ops.paged_prefill_attention(q, kp, vp, pt, offs_j, lens_j,
                                          return_tile_counts=True, **I)
    want = np.stack([np.full(kvh, -(-int(o) // bs)) for o in offs])
    np.testing.assert_array_equal(np.asarray(cnt), want)


def test_attention_chunk_merge_accepts_kernel_state():
    """`attention_chunk_merge(pfx_state=...)` with the kernel's flash
    state matches the gathered-prefix oracle path — and a zero-offset
    (empty prefix) batch matches it BITWISE, the whole-prompt identity
    the serving stack's one-shot contract rides on."""
    from repro.models.layers import AttnConfig, attention_chunk_merge

    b, c, kvh, hq, d, bs, mb = 2, 24, 2, 2, 32, 8, 6
    h = kvh * hq
    rng = np.random.default_rng(5)
    cfg = AttnConfig(h, kvh, d, causal=True, q_chunk=12)
    kc = jnp.asarray(rng.standard_normal((b, c, kvh, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, c, kvh, d)), jnp.float32)
    chunk_valid = jnp.asarray(np.arange(c)[None] < np.array([[c], [c - 7]]))

    for offs_np in (np.array([0, 0], np.int32),      # bitwise case
                    np.array([11, 37], np.int32)):   # tolerance case
        q, kp, vp, pt, offs, lens = _paged_prefill_case(
            6, b, c, kvh, hq, d, bs, mb, offs_np, np.full(b, c, np.int32))
        q_pos = offs[:, None] + jnp.arange(c, dtype=jnp.int32)[None]
        pfx_valid = jnp.arange(mb * bs, dtype=jnp.int32)[None] < \
            offs[:, None]
        safe = jnp.maximum(pt, 0)
        kg = kp[safe].reshape(b, mb * bs, kvh, d)
        vg = vp[safe].reshape(b, mb * bs, kvh, d)
        want = attention_chunk_merge(q, kg, vg, kc, vc, cfg, q_pos,
                                     pfx_valid, chunk_valid)
        state = ops.paged_prefill_attention(q, kp, vp, pt, offs, None, **I)
        got = attention_chunk_merge(q, None, None, kc, vc, cfg, q_pos,
                                    None, chunk_valid, pfx_state=state)
        if int(offs_np.max()) == 0:
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want))
        else:
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-6)
