"""Per-kernel interpret-mode validation vs the pure-jnp oracles.

Each Pallas kernel is swept across shapes/dtypes and assert_allclose'd
against ref.py (the system prompt's per-kernel requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import quantize
from repro.kernels import ops, ref

I = dict(interpret=True)


@pytest.mark.parametrize("m,n,k", [
    (1, 64, 128), (4, 256, 512), (8, 512, 256), (2, 96, 192),
])
def test_q8_matvec_shapes(m, n, k):
    key = jax.random.PRNGKey(m * 1000 + n + k)
    x = jax.random.normal(key, (m, k))
    w = quantize(jax.random.normal(jax.random.fold_in(key, 1), (n, k)))
    out = ops.q8_matmul(x, w, **I)
    xq = quantize(x)
    want = ref.ref_q8_matmul(xq.q, xq.scale, w.q, w.scale, w.group_size)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (128, 256, 512, 64, 128, 256),
    (256, 512, 1024, 128, 256, 512),
    (64, 128, 128, 64, 128, 128),
])
def test_q8_gemm_blocks(m, n, k, bm, bn, bk):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k))
    w = quantize(jax.random.normal(jax.random.fold_in(key, 1), (n, k)))
    out = ops.q8_matmul(x, w, block_m=bm, block_n=bn, block_k=bk, **I)
    xq = quantize(x)
    want = ref.ref_q8_matmul(xq.q, xq.scale, w.q, w.scale, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("gs", [32, 64, 128])
def test_q8_group_sizes(gs):
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 256))
    w = quantize(jax.random.normal(jax.random.PRNGKey(3), (128, 256)),
                 group_size=gs)
    out = ops.q8_matmul(x, w, **I)
    xq = quantize(x, group_size=gs)
    want = ref.ref_q8_matmul(xq.q, xq.scale, w.q, w.scale, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k", [(4, 256), (16, 512), (256, 1024), (3, 192)])
def test_rmsnorm_quant(m, k):
    x = jax.random.normal(jax.random.PRNGKey(m + k), (m, k)) * 3.0
    g = jax.random.normal(jax.random.PRNGKey(1), (k,))
    qk, sk = ops.rmsnorm_quant(x, g, **I)
    qr, sr = ref.ref_rmsnorm_quant(x, g)
    assert int(jnp.sum(qk != qr)) == 0
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,d", [(4, 8, 128), (2, 12, 64), (16, 2, 128)])
def test_rope_kernel(b, h, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(b * h), (b, h, d)).astype(dtype)
    ang = jax.random.uniform(jax.random.PRNGKey(5), (b, d // 2)) * 6.28
    cos = jnp.concatenate([jnp.cos(ang)] * 2, -1)
    sin = jnp.concatenate([jnp.sin(ang)] * 2, -1)
    out = ops.rope(x, cos, sin, **I)
    want = ref.ref_rope(x, cos[:, None, :], sin[:, None, :])
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-2)


@pytest.mark.parametrize("n,k", [(128, 256), (512, 512), (96, 128)])
def test_q4_matvec(n, k):
    x = jax.random.normal(jax.random.PRNGKey(n), (4, k))
    w = quantize(jax.random.normal(jax.random.PRNGKey(k), (n, k)), bits=4)
    out = ops.q8_matmul(x, w, **I)
    xq = quantize(x)
    want = ref.ref_q4_matvec(xq.q, xq.scale, w.q, w.scale, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,s,kvh,hq,d", [
    (2, 1024, 4, 4, 128), (1, 512, 2, 8, 64), (4, 2048, 1, 4, 128),
])
def test_decode_attention_fp(b, s, kvh, hq, d):
    key = jax.random.PRNGKey(b * s)
    q = jax.random.normal(key, (b, kvh * hq, d)) / np.sqrt(d)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, d))
    lens = jnp.asarray(np.random.default_rng(0).integers(1, s, b), jnp.int32)
    out = ops.decode_attention(q, k, v, lens, **I)
    want = ref.ref_decode_attention(
        q.reshape(b, kvh, hq, d), k, v, lens.reshape(b, 1)
    ).reshape(b, kvh * hq, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_decode_attention_int8_kv():
    """Beyond-paper int8 KV cache: kernel matches dequantized reference."""
    b, s, kvh, hq, d = 2, 512, 2, 4, 64
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (b, kvh * hq, d)) / np.sqrt(d)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, d))
    vf = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, d))
    absk = jnp.max(jnp.abs(kf), -1, keepdims=True)
    absv = jnp.max(jnp.abs(vf), -1, keepdims=True)
    kq = jnp.round(kf / absk * 127).astype(jnp.int8)
    vq = jnp.round(vf / absv * 127).astype(jnp.int8)
    ks = (absk[..., 0] / 127.0)
    vs = (absv[..., 0] / 127.0)
    lens = jnp.array([300, 512], jnp.int32)
    out = ops.decode_attention(q, kq, vq, lens, ks, vs, **I)
    want = ref.ref_decode_attention(q.reshape(b, kvh, hq, d), kq, vq,
                                    lens.reshape(b, 1), ks, vs
                                    ).reshape(b, kvh * hq, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_decode_attention_matches_fp_within_quant_error():
    b, s, kvh, hq, d = 1, 256, 2, 2, 64
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (b, kvh * hq, d)) / np.sqrt(d)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, d))
    vf = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, d))
    lens = jnp.array([s], jnp.int32)
    fp = ops.decode_attention(q, kf, vf, lens, **I)
    absk = jnp.max(jnp.abs(kf), -1, keepdims=True)
    absv = jnp.max(jnp.abs(vf), -1, keepdims=True)
    kq = jnp.round(kf / absk * 127).astype(jnp.int8)
    vq = jnp.round(vf / absv * 127).astype(jnp.int8)
    i8 = ops.decode_attention(q, kq, vq, lens,
                              absk[..., 0] / 127, absv[..., 0] / 127, **I)
    np.testing.assert_allclose(np.asarray(i8), np.asarray(fp),
                               rtol=0.1, atol=0.05)
