"""Parallel sampling (``Request.n_samples``) API contract.

The fork/COW mechanics live in tests/test_prefix_cache.py (bit-exact
sibling reruns, warm-group fanout) and tests/test_scheduler.py (unit
preemption, slot reservation); this file pins the request-level
contract:

  * ``n_samples=1`` is a strict no-op of the PR: greedy streams are
    identical to the dense (pre-paging) engine for f32 AND int8 pools,
    and ``outputs == [output]``;
  * a fanned group's pool footprint stays within
    ``prompt_blocks + n * tail_blocks`` — the acceptance bound that
    proves prompt KV is shared rather than copied per sibling;
  * per-request ``stop_tokens`` are honored in the decode finish check,
    so siblings of one group can retire on different ids;
  * group requests that can never run (wider than the slot table, dense
    cache, ``n_samples < 1``) come back with ``.error``.
"""

import jax
import numpy as np
import pytest

from repro.serving.engine import Engine


def _f32_model():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m")).with_(compute_dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _int8_model():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m")).with_(
        compute_dtype="float32", kv_cache_dtype="int8")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


@pytest.mark.parametrize("build", [_f32_model, _int8_model],
                         ids=["f32", "int8"])
def test_n_samples_one_greedy_identical_to_dense_engine(build):
    """The n=1 regression bar: the paged engine (per-row keyed sampling,
    group plumbing) must emit the exact greedy streams of the dense
    engine, whose decode path predates all of it."""
    m, params = build()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, 500, size=n).astype(np.int32)
               for n in (6, 11, 9)]

    def serve(kind):
        eng = Engine(m, params, max_slots=4, max_seq=64, page_size=8,
                     cache_kind=kind)
        for p in prompts:
            eng.submit(p, max_new_tokens=8, temperature=0.0, n_samples=1)
        done = sorted(eng.run(), key=lambda r: r.uid)
        assert all(r.error is None for r in done)
        return done

    paged = serve("paged")
    dense = serve("dense")
    assert [r.output for r in paged] == [r.output for r in dense]
    for r in paged:
        assert r.outputs == [r.output] and r.outputs[0] is r.output


def test_group_allocates_at_most_prompt_plus_n_tails():
    """The fork-sharing acceptance bound: an n_samples=4 request over a
    multi-block prompt never holds more than ``prompt_blocks + 4 *
    tail_blocks`` live leases — the prompt's full blocks back all four
    page tables instead of being copied per sibling."""
    m, params = _f32_model()
    rng = np.random.default_rng(1)
    plen, max_new, bs, n = 19, 8, 8, 4
    prompt = rng.integers(4, 500, size=plen).astype(np.int32)

    eng = Engine(m, params, max_slots=4, max_seq=64, page_size=bs)
    eng.submit(prompt, max_new_tokens=max_new, temperature=1.0, seed=5,
               n_samples=n)
    (r,) = eng.run()
    assert r.error is None and len(r.outputs) == n

    prompt_blocks = plen // bs                       # shared full blocks
    seq_blocks = -(-(plen + max_new) // bs)          # one sibling's worst
    tail_blocks = seq_blocks - prompt_blocks
    bound = prompt_blocks + n * tail_blocks
    naive = n * seq_blocks
    peak = eng.metrics["blocks_live_peak"]
    assert peak <= bound, f"peak {peak} blocks exceeds shared bound {bound}"
    assert peak < naive, "fork sharing must beat per-sibling copies"
    assert eng.metrics["blocks_saved_by_sharing_peak"] >= \
        (n - 1) * prompt_blocks
    eng.pager.debug_check()
    assert eng.pager.utilization() == 0.0


def test_stop_tokens_per_sibling():
    """Per-request stop ids end a sequence like eos does — and within a
    sampling group each sibling stops independently on its own id."""
    m, params = _f32_model()
    rng = np.random.default_rng(2)
    prompt = rng.integers(4, 500, size=10).astype(np.int32)

    # reference run: no stop ids
    eng = Engine(m, params, max_slots=4, max_seq=64, page_size=8)
    eng.submit(prompt, max_new_tokens=8, temperature=1.0, seed=13,
               n_samples=3)
    (ref,) = eng.run()
    assert all(len(o) == 8 for o in ref.outputs)

    # stop on a token that appears mid-stream in exactly one sibling
    target, pos = None, None
    for i, out in enumerate(ref.outputs):
        for j, tok in enumerate(out[1:-1], start=1):
            others = [o for k, o in enumerate(ref.outputs) if k != i]
            if all(tok not in o[:j + 1] for o in others):
                target, pos, sib = tok, j, i
                break
        if target is not None:
            break
    assert target is not None, "seeded streams must provide a stop token"

    eng2 = Engine(m, params, max_slots=4, max_seq=64, page_size=8)
    eng2.submit(prompt, max_new_tokens=8, temperature=1.0, seed=13,
                n_samples=3, stop_tokens=[int(target)])
    (r,) = eng2.run()
    assert r.outputs[sib] == ref.outputs[sib][:pos + 1], \
        "the matching sibling must stop right after its stop id"
    for k in range(3):
        if k != sib:
            assert r.outputs[k] == ref.outputs[k][:len(r.outputs[k])]
            assert len(r.outputs[k]) >= pos + 1

    # singleton requests honor stop_tokens too
    eng3 = Engine(m, params, max_slots=4, max_seq=64, page_size=8)
    eng3.submit(prompt, max_new_tokens=8, temperature=1.0, seed=13,
                stream=sib, stop_tokens=[int(target)])
    (solo,) = eng3.run()
    assert solo.output == ref.outputs[sib][:pos + 1]


def test_first_token_stop_and_max_new_tokens_one():
    """The finish predicate applies to the FIRST sampled token too: a
    stop id drawn at prefill/fanout retires the sibling before any
    decode, and ``max_new_tokens=1`` yields exactly one token."""
    m, params = _f32_model()
    rng = np.random.default_rng(4)
    prompt = rng.integers(4, 500, size=9).astype(np.int32)

    eng = Engine(m, params, max_slots=4, max_seq=64, page_size=8)
    eng.submit(prompt, max_new_tokens=1, temperature=1.0, seed=4,
               n_samples=3)
    eng.submit(prompt, max_new_tokens=1, temperature=0.0)
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert [len(o) for o in done[0].outputs] == [1, 1, 1]
    assert len(done[1].output) == 1
    eng.pager.debug_check()
    assert eng.pager.utilization() == 0.0

    # reference streams, then stop on sibling 1's very first token
    eng2 = Engine(m, params, max_slots=4, max_seq=64, page_size=8)
    eng2.submit(prompt, max_new_tokens=6, temperature=1.0, seed=4,
                n_samples=3)
    (ref,) = eng2.run()
    tok0 = int(ref.outputs[1][0])
    eng3 = Engine(m, params, max_slots=4, max_seq=64, page_size=8)
    eng3.submit(prompt, max_new_tokens=6, temperature=1.0, seed=4,
                n_samples=3, stop_tokens=[tok0])
    (r,) = eng3.run()
    assert r.outputs[1] == [tok0], \
        "a first-token stop id must retire the sibling before any decode"


def test_group_request_errors():
    m, params = _f32_model()
    eng = Engine(m, params, max_slots=2, max_seq=64, page_size=8)
    rng = np.random.default_rng(3)
    prompt = rng.integers(4, 500, size=6).astype(np.int32)
    eng.submit(prompt, max_new_tokens=4, n_samples=3)      # > max_slots
    eng.submit(prompt, max_new_tokens=4, n_samples=0)
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert "max_slots" in done[0].error
    assert "n_samples" in done[1].error

    dense = Engine(m, params, max_slots=4, max_seq=64, cache_kind="dense")
    dense.submit(prompt, max_new_tokens=4, n_samples=2)
    (r,) = dense.run()
    assert r.error is not None and "paged" in r.error
