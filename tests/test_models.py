"""Per-architecture smoke tests (reduced configs) + model invariants.

Every assigned arch: instantiate reduced config, run one forward/train
step on CPU, assert output shapes + finite values (the assignment's
smoke-test requirement), plus prefill/decode consistency and the
quantized-serving path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, reduced
from repro.core import QuantPolicy
from repro.models import build_model, count_params
from repro.models import ssm

ALL_ARCHS = [a for a in list_configs()]


def _batch(cfg, key, b=2, s=32, with_labels=True):
    if cfg.family == "audio":
        d = {"frames": jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model)) * 0.1,
             "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    elif cfg.family == "vlm":
        d = {"embeds": jax.random.normal(key, (b, s, cfg.d_model)) * 0.1}
    else:
        d = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if with_labels:
        d["labels"] = jax.random.randint(
            jax.random.fold_in(key, 7), (b, s), 0, cfg.vocab_size)
    return d


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch):
    """One forward + gradient step; loss finite, grads finite, shapes ok."""
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    assert count_params(params) > 0
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(lambda p: m.loss(p, batch))(params)
    assert jnp.isfinite(loss), arch
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert jnp.isfinite(gnorm), arch
    # embedding table rows = padded vocab
    assert params["embed"].shape == (cfg.padded_vocab(), cfg.d_model)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_serve(arch):
    """Prefill -> 2 decode steps; logits shaped (B, Vpad), finite."""
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    b, s = 2, 16
    batch = _batch(cfg, key, b=b, s=s, with_labels=False)
    logits, cache = m.prefill(params, batch, max_seq=s + 4)
    assert logits.shape == (b, cfg.padded_vocab())
    assert bool(jnp.all(jnp.isfinite(logits)))
    for _ in range(2):
        logits, cache = m.decode_step(params, cache,
                                      jnp.argmax(logits, -1))
        assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["llama2-110m", "glm4-9b", "zamba2-1.2b",
                                  "whisper-small", "qwen3-moe-30b-a3b"])
def test_decode_matches_prefill(arch):
    """Teacher-forcing the generated token must reproduce decode logits
    (numerically, not argmax — bf16 archs carry ~1e-2 noise)."""
    cfg = reduced(get_config(arch)).with_(capacity_factor=8.0,
                                          compute_dtype="float32")
    m = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = m.init(key)
    b, s = 2, 24
    batch = _batch(cfg, key, b=b, s=s, with_labels=False)
    logits, cache = m.prefill(params, batch, max_seq=s + 2)
    tok = jnp.argmax(logits, -1)
    l_dec, _ = m.decode_step(params, cache, tok)
    batch2 = dict(batch, tokens=jnp.concatenate(
        [batch["tokens"], tok[:, None]], axis=1))
    l_ref, _ = m.prefill(params, batch2, max_seq=s + 2)
    np.testing.assert_allclose(np.asarray(l_dec), np.asarray(l_ref),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["llama2-110m", "mamba2-370m"])
def test_quantized_serving_quality(arch):
    """Q8_0 PTQ: quantized logits correlate >0.97 with fp logits
    (the paper's 0.04% perplexity delta story at reduced scale)."""
    cfg = reduced(get_config(arch)).with_(compute_dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(3))
    batch = _batch(cfg, jax.random.PRNGKey(4), b=2, s=24, with_labels=False)
    lf, _ = m.prefill(params, batch, max_seq=26)
    qp = m.quantize(params, QuantPolicy(min_size=256))
    lq, _ = m.prefill(qp, batch, max_seq=26)
    lf_, lq_ = np.asarray(lf).ravel(), np.asarray(lq).ravel()
    corr = np.corrcoef(lf_, lq_)[0, 1]
    assert corr > 0.97, corr


def test_int8_kv_cache_close_to_fp():
    cfg = reduced(get_config("llama2-110m")).with_(compute_dtype="float32")
    m8 = build_model(cfg.with_(kv_cache_dtype="int8"))
    mf = build_model(cfg)
    params = mf.init(jax.random.PRNGKey(5))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(6), (1, 16),
                                          0, cfg.vocab_size)}
    lf, cf = mf.prefill(params, batch, max_seq=20)
    l8, c8 = m8.prefill(params, batch, max_seq=20)
    assert c8["attn"]["k"].dtype == jnp.int8
    d1, _ = mf.decode_step(params, cf, jnp.argmax(lf, -1))
    d2, _ = m8.decode_step(params, c8, jnp.argmax(l8, -1))
    corr = np.corrcoef(np.asarray(d1).ravel(), np.asarray(d2).ravel())[0, 1]
    assert corr > 0.98, corr


class TestSSM:
    def test_chunked_matches_recurrent(self):
        dims = ssm.make_ssm_dims(64, 16, 2, 8, 2, 4)
        b, s = 2, 96
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (b, s, dims.n_heads, dims.head_dim)) * .5
        dt = jax.nn.softplus(jax.random.normal(
            jax.random.fold_in(key, 1), (b, s, dims.n_heads)))
        A = -jnp.exp(jnp.linspace(0., 1., dims.n_heads))
        B = jax.random.normal(jax.random.fold_in(key, 2),
                              (b, s, dims.n_groups, dims.state)) * .3
        C = jax.random.normal(jax.random.fold_in(key, 3),
                              (b, s, dims.n_groups, dims.state)) * .3
        yc, sc = ssm.ssd_chunked(x, dt, A, B, C, chunk=32)
        yr, sr = ssm.ssd_recurrent_ref(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(yc), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(sc.reshape(sr.shape)),
                                   np.asarray(sr), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("chunk", [16, 32, 96])
    def test_chunk_size_invariance(self, chunk):
        dims = ssm.make_ssm_dims(32, 8, 2, 8, 1, 4)
        p = ssm.init_mamba2_params(jax.random.PRNGKey(0), dims)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 96, 32)) * .5
        y1, _ = ssm.mamba2_forward(p, x, dims, chunk=chunk)
        y2, _ = ssm.mamba2_forward(p, x, dims, chunk=96)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)

    def test_prefill_decode_continuation(self):
        dims = ssm.make_ssm_dims(32, 8, 2, 8, 1, 4)
        p = ssm.init_mamba2_params(jax.random.PRNGKey(0), dims)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 33, 32)) * .5
        y_all, _ = ssm.mamba2_forward(p, x, dims, chunk=16)
        y_pre, (cs, hs) = ssm.mamba2_forward(p, x[:, :32], dims, chunk=16)
        y_dec, _ = ssm.mamba2_decode_step(p, x[:, 32], dims, cs, hs)
        np.testing.assert_allclose(np.asarray(y_all[:, 32]),
                                   np.asarray(y_dec), rtol=1e-4, atol=1e-4)


class TestMoE:
    def test_dense_matches_grouped_without_drops(self):
        from repro.models.layers import moe_mlp
        key = jax.random.PRNGKey(0)
        E, F, D, K = 8, 64, 32, 2
        p = {"router": jax.random.normal(key, (E, D)) * .1,
             "w1": jax.random.normal(jax.random.fold_in(key, 1), (E, F, D)) * .1,
             "w3": jax.random.normal(jax.random.fold_in(key, 2), (E, F, D)) * .1,
             "w2": jax.random.normal(jax.random.fold_in(key, 3), (E, D, F)) * .1}
        x = jax.random.normal(jax.random.fold_in(key, 4), (2, 36, D))
        yd = moe_mlp(p, x, n_experts=E, top_k=K, dense_dispatch=True)
        yg = moe_mlp(p, x, n_experts=E, top_k=K, group_size=64,
                     capacity_factor=8.0)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(yg),
                                   rtol=1e-4, atol=1e-4)

    def test_capacity_drops_bounded(self):
        """With cap factor 1.0, dropped-token output shrinks but stays
        finite and close in expectation."""
        from repro.models.layers import moe_mlp
        key = jax.random.PRNGKey(1)
        E, F, D, K = 4, 32, 16, 1
        p = {"router": jax.random.normal(key, (E, D)),
             "w1": jax.random.normal(jax.random.fold_in(key, 1), (E, F, D)) * .1,
             "w3": jax.random.normal(jax.random.fold_in(key, 2), (E, F, D)) * .1,
             "w2": jax.random.normal(jax.random.fold_in(key, 3), (E, D, F)) * .1}
        x = jax.random.normal(jax.random.fold_in(key, 4), (1, 64, D))
        y = moe_mlp(p, x, n_experts=E, top_k=K, group_size=64,
                    capacity_factor=1.0)
        assert bool(jnp.all(jnp.isfinite(y)))
