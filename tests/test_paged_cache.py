"""Paged KV cache: allocator invariants + numerical equivalence with the
contiguous cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.models.layers import AttnConfig, attention_decode
from repro.serving.paged_cache import (BlockAllocator, OutOfBlocks,
                                       PagedConfig, PagedKVCache)


def _cfg(**kw):
    base = dict(n_layers=2, n_kv_heads=2, head_dim=8, block_size=4,
                n_blocks=16, max_slots=3, max_blocks_per_seq=4)
    base.update(kw)
    return PagedConfig(**base)


class TestAllocator:
    def test_ensure_grows_by_blocks(self):
        a = BlockAllocator(_cfg())
        assert a.ensure(0, 1) == a.ensure(0, 4)          # 1..4 -> one block
        assert len(a.ensure(0, 5)) == 2

    def test_release_returns_blocks(self):
        a = BlockAllocator(_cfg())
        a.ensure(0, 16)
        used = a.utilization()
        a.release(0)
        assert a.utilization() == 0.0 and used > 0

    def test_out_of_blocks(self):
        a = BlockAllocator(_cfg(n_blocks=2))
        a.ensure(0, 8)
        with pytest.raises(OutOfBlocks):
            a.ensure(1, 4)

    def test_no_double_ownership(self):
        a = BlockAllocator(_cfg())
        a.ensure(0, 8)
        a.ensure(1, 8)
        assert not set(a.owned[0]) & set(a.owned[1])

    def test_page_table_covers_lengths(self):
        cases = [[0, 0, 0], [1, 16, 0], [4, 5, 16], [16, 16, 16], [3, 0, 9]]
        for lens in cases:
            a = BlockAllocator(_cfg())
            for s, ln in enumerate(lens):
                if ln:
                    a.ensure(s, ln)
            pt = a.page_table()
            for s, ln in enumerate(lens):
                assert (pt[s] >= 0).sum() == a.blocks_needed(ln)

    @settings(max_examples=20, deadline=None)
    @given(lens=st.lists(st.integers(0, 16), min_size=3, max_size=3))
    def test_page_table_covers_lengths_prop(self, lens):
        a = BlockAllocator(_cfg())
        for s, ln in enumerate(lens):
            if ln:
                a.ensure(s, ln)
        pt = a.page_table()
        for s, ln in enumerate(lens):
            assert (pt[s] >= 0).sum() == a.blocks_needed(ln)


class TestPagedVsContiguous:
    def test_prefill_append_gather_equivalence(self):
        """admit + appends through pages == one contiguous cache."""
        cfg = _cfg()
        cache = PagedKVCache(cfg)
        key = jax.random.PRNGKey(0)
        l, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim

        # two slots with different prompt lengths
        kp0 = jax.random.normal(key, (l, 6, kvh, hd))
        vp0 = jax.random.normal(jax.random.fold_in(key, 1), (l, 6, kvh, hd))
        kp1 = jax.random.normal(jax.random.fold_in(key, 2), (l, 3, kvh, hd))
        vp1 = jax.random.normal(jax.random.fold_in(key, 3), (l, 3, kvh, hd))
        cache.admit(0, kp0, vp0)
        cache.admit(1, kp1, vp1)

        # three decode appends on both slots
        news = []
        for i in range(3):
            kn = jax.random.normal(jax.random.fold_in(key, 10 + i),
                                   (l, cfg.max_slots, kvh, hd))
            vn = jax.random.normal(jax.random.fold_in(key, 20 + i),
                                   (l, cfg.max_slots, kvh, hd))
            cache.append(kn, vn, np.array([True, True, False]))
            news.append((kn, vn))

        kv, vv = cache.view()
        # reference contiguous layout
        ref_k0 = jnp.concatenate([kp0] + [n[0][:, :1] for n in news], 1)
        ref_v0 = jnp.concatenate([vp0] + [n[1][:, :1] for n in news], 1)
        np.testing.assert_allclose(np.asarray(kv[:, 0, :9]),
                                   np.asarray(ref_k0), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(vv[:, 0, :9]),
                                   np.asarray(ref_v0), rtol=1e-6)
        ref_k1 = jnp.concatenate([kp1] + [n[0][:, 1:2] for n in news], 1)
        np.testing.assert_allclose(np.asarray(kv[:, 1, :6]),
                                   np.asarray(ref_k1), rtol=1e-6)
        assert cache.lens.tolist() == [9, 6, 0]

    def test_attention_through_pages_matches(self):
        """Decode attention over the paged view == contiguous attention."""
        cfg = _cfg()
        cache = PagedKVCache(cfg)
        key = jax.random.PRNGKey(5)
        l, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        h = kvh * 2
        s_p = 7
        kp = jax.random.normal(key, (l, s_p, kvh, hd))
        vp = jax.random.normal(jax.random.fold_in(key, 1), (l, s_p, kvh, hd))
        cache.admit(0, kp, vp)

        kv, vv = cache.view()                     # (L, B, S_max, KVH, hd)
        q = jax.random.normal(jax.random.fold_in(key, 2), (1, h, hd)) / 3
        acfg = AttnConfig(h, kvh, hd)
        out_paged = attention_decode(q, kv[0, :1], vv[0, :1],
                                     jnp.asarray([s_p]), acfg)
        out_ref = attention_decode(q, kp[0][None], vp[0][None],
                                   jnp.asarray([s_p]), acfg)
        np.testing.assert_allclose(np.asarray(out_paged),
                                   np.asarray(out_ref), rtol=1e-5, atol=1e-6)

    def test_slot_reuse_after_release(self):
        cfg = _cfg(n_blocks=4, max_slots=2)
        cache = PagedKVCache(cfg)
        l, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        k = jnp.ones((l, 8, kvh, hd))
        cache.admit(0, k, k)
        cache.admit(1, k * 2, k * 2)
        cache.release(0)
        cache.admit(0, k * 3, k * 3)              # reuses freed blocks
        kv, _ = cache.view()
        np.testing.assert_allclose(np.asarray(kv[:, 0, :8]),
                                   np.asarray(k * 3), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(kv[:, 1, :8]),
                                   np.asarray(k * 2), rtol=1e-6)

    def test_memory_savings(self):
        """The point of paging: short requests don't reserve max_seq."""
        cfg = _cfg(n_blocks=8, max_slots=4, max_blocks_per_seq=8)
        cache = PagedKVCache(cfg)
        l, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        for s in range(4):
            cache.admit(s, jnp.ones((l, 2, kvh, hd)),
                        jnp.ones((l, 2, kvh, hd)))
        # 4 slots x 2 tokens = 4 blocks of 4 -> half the pool free, while a
        # contiguous cache would have reserved 4 x 32 rows
        assert cache.alloc.utilization() == 0.5
