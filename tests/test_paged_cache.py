"""Paged KV cache: refcounted allocator invariants (admit / fork /
release / COW / prefix-cache ops never double-free, never leak, and keep
refcounts consistent with the page tables) + numerical equivalence with
the contiguous cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.models.layers import AttnConfig, attention_decode
from repro.serving.paged_cache import (BlockAllocator, OutOfBlocks,
                                       PagedConfig, PagedKVCache,
                                       chain_hash, prefix_block_hashes)


def _cfg(**kw):
    base = dict(n_layers=2, n_kv_heads=2, head_dim=8, block_size=4,
                n_blocks=16, max_slots=3, max_blocks_per_seq=4)
    base.update(kw)
    return PagedConfig(**base)


class TestAllocator:
    def test_ensure_grows_by_blocks(self):
        a = BlockAllocator(_cfg())
        assert a.ensure(0, 1) == a.ensure(0, 4)          # 1..4 -> one block
        assert len(a.ensure(0, 5)) == 2

    def test_release_returns_blocks(self):
        a = BlockAllocator(_cfg())
        a.ensure(0, 16)
        used = a.utilization()
        a.release(0)
        assert a.utilization() == 0.0 and used > 0

    def test_out_of_blocks(self):
        a = BlockAllocator(_cfg(n_blocks=2))
        a.ensure(0, 8)
        with pytest.raises(OutOfBlocks):
            a.ensure(1, 4)

    def test_no_double_ownership(self):
        a = BlockAllocator(_cfg())
        a.ensure(0, 8)
        a.ensure(1, 8)
        assert not set(a.owned[0]) & set(a.owned[1])

    def test_page_table_covers_lengths(self):
        cases = [[0, 0, 0], [1, 16, 0], [4, 5, 16], [16, 16, 16], [3, 0, 9]]
        for lens in cases:
            a = BlockAllocator(_cfg())
            for s, ln in enumerate(lens):
                if ln:
                    a.ensure(s, ln)
            pt = a.page_table()
            for s, ln in enumerate(lens):
                assert (pt[s] >= 0).sum() == a.blocks_needed(ln)

    @settings(max_examples=20, deadline=None)
    @given(lens=st.lists(st.integers(0, 16), min_size=3, max_size=3))
    def test_page_table_covers_lengths_prop(self, lens):
        a = BlockAllocator(_cfg())
        for s, ln in enumerate(lens):
            if ln:
                a.ensure(s, ln)
        pt = a.page_table()
        for s, ln in enumerate(lens):
            assert (pt[s] >= 0).sum() == a.blocks_needed(ln)


class TestRefcountCow:
    """Refcounted lease semantics: sharing, COW, LRU reclaim, and the
    prefix index."""

    def test_release_keeps_shared_blocks_alive(self):
        a = BlockAllocator(_cfg())
        a.ensure(0, 10)
        a.fork(0, 1)
        assert a.owned[0] == a.owned[1]
        a.release(0)
        a.debug_check()
        assert all(a.refcount[b] == 1 for b in a.owned[1])
        a.release(1)
        a.debug_check()
        assert a.n_free() == a.cfg.n_blocks

    def test_cow_unshares_and_accounts(self):
        a = BlockAllocator(_cfg())
        a.ensure(0, 10)                       # 3 blocks, last partial
        a.fork(0, 1)
        assert a.copy_on_write(0, 2) is not None
        a.debug_check()
        assert a.owned[0][2] != a.owned[1][2]
        assert a.owned[0][:2] == a.owned[1][:2]
        # already exclusive: no copy
        assert a.copy_on_write(0, 2) is None
        assert a.stats["cow_copies"] == 1

    def test_registered_blocks_park_on_lru_and_rehit(self):
        a = BlockAllocator(_cfg())
        toks = np.arange(10)
        a.ensure(0, len(toks))
        bs = a.cfg.block_size
        hs = prefix_block_hashes(toks, bs)
        for j, h in enumerate(hs):
            a.register_block(0, j, h, toks[j * bs:(j + 1) * bs])
        a.release(0)
        a.debug_check()
        assert a.n_cached() == len(hs) == 2   # partial tail never cached
        assert a.n_free() == a.cfg.n_blocks   # cached blocks reclaimable
        bids, hs2 = a.lookup_prefix(toks)
        assert hs2 == hs
        a.acquire_cached(1, bids)
        a.debug_check()
        assert a.n_cached() == 0 and all(a.refcount[b] == 1 for b in bids)

    def test_lru_eviction_invalidates_lookup_oldest_first(self):
        a = BlockAllocator(_cfg(n_blocks=4, max_slots=2))
        t0, t1 = np.arange(8), np.arange(100, 108)
        for slot, toks in ((0, t0), (1, t1)):
            a.ensure(slot, 8)
            for j, h in enumerate(prefix_block_hashes(toks, 4)):
                a.register_block(slot, j, h, toks[j * 4:(j + 1) * 4])
        a.release(0)                          # t0 blocks are LRU-oldest
        a.release(1)
        assert a.n_cached() == 4
        a.ensure(0, 8)                        # evicts both t0 blocks
        a.debug_check()
        assert a.stats["evictions"] == 2
        assert a.lookup_prefix(t0) == ([], [])
        bids, _ = a.lookup_prefix(t1)
        assert len(bids) == 2, "survivor prefix must still hit"

    def test_append_cost_prices_growth_and_cow(self):
        a = BlockAllocator(_cfg())
        a.ensure(0, 6)                        # 2 blocks, tail partial
        assert a.append_cost(0, 6) == 0       # in-place tail append
        assert a.append_cost(0, 8) == 1       # opens block 3
        a.fork(0, 1)
        assert a.append_cost(0, 6) == 1       # COW of the shared tail
        assert a.append_cost(0, 8) == 1       # new block, no COW

    def test_fork_cost_prices_fanout(self):
        """fork_cost = fresh blocks the first divergent appends need:
        n-1 COW copies for a shared partial tail (the last writer keeps
        the original), n new blocks when the tail is full/registered."""
        a = BlockAllocator(_cfg())            # block_size 4
        assert a.fork_cost(6, 1) == 0
        assert a.fork_cost(6, 3) == 2         # partial tail: n-1 COWs
        assert a.fork_cost(8, 3) == 3         # aligned: n fresh blocks
        assert a.fork_cost(8, 1) == 0
        # matches what the machinery actually allocates: fork 3 ways at
        # a partial tail, then make each sibling's tail writable
        a.ensure(0, 6)
        a.fork(0, 1)
        a.fork(0, 2)
        used0 = a.cfg.n_blocks - a.n_free()
        for slot in (0, 1, 2):
            a.copy_on_write(slot, 1)
        assert (a.cfg.n_blocks - a.n_free()) - used0 == a.fork_cost(6, 3)
        a.debug_check()

    def test_hash_collision_degrades_to_miss(self):
        """lookup_prefix verifies the stored token ids, so a chain_hash
        collision (engineered here by registering other tokens under the
        query's hash) is a cache miss — never another prefix's KV."""
        a = BlockAllocator(_cfg())
        a.ensure(0, 4)
        t_query, t_stored = np.arange(4), np.arange(50, 54)
        a.register_block(0, 0, chain_hash(None, t_query), t_stored)
        assert a.lookup_prefix(t_query) == ([], [])
        bids, _ = a.lookup_prefix(t_stored)   # honest hash still misses
        assert bids == []

    def test_duplicate_registration_keeps_canonical(self):
        a = BlockAllocator(_cfg())
        a.ensure(0, 4)
        a.ensure(1, 4)
        h = chain_hash(None, np.arange(4))
        a.register_block(0, 0, h, np.arange(4))
        a.register_block(1, 0, h, np.arange(4))   # duplicate content
        canonical = a.index[h]
        assert canonical == a.owned[0][0]
        a.release(1)                          # non-canonical frees outright
        a.debug_check()
        assert a.n_cached() == 0
        a.release(0)                          # canonical parks on the LRU
        a.debug_check()
        assert a.n_cached() == 1 and a.lookup_prefix(np.arange(4))[0] == \
            [canonical]


def _random_op_machine(ops):
    """Shared random-ops state machine: every op sequence must keep the
    allocator's invariants (checked via debug_check after each op) —
    no double-free, no leak, refcounts == page-table multiplicity."""
    cfg = _cfg(n_blocks=8, max_slots=4, max_blocks_per_seq=8)
    a = BlockAllocator(cfg)
    reg_count = [0] * cfg.max_slots           # full blocks registered/slot
    for op, slot, arg in ops:
        op, slot = op % 6, slot % cfg.max_slots
        if op == 0:                           # grow (guarded, like _plan_chunk)
            length = arg % (cfg.max_blocks_per_seq * cfg.block_size) + 1
            if a.can_allocate(slot, length):
                a.ensure(slot, length)
        elif op == 1:                         # release (finish / preempt)
            a.release(slot)
            reg_count[slot] = 0
        elif op == 2:                         # fork into an empty slot
            dst = (slot + 1 + arg % (cfg.max_slots - 1)) % cfg.max_slots
            if a.owned[slot] and not a.owned[dst] and dst != slot:
                a.fork(slot, dst)
                reg_count[dst] = reg_count[slot]
        elif op == 3:                         # COW a leased block
            if a.owned[slot] and a.n_free() >= 1:
                a.copy_on_write(slot, arg % len(a.owned[slot]))
        elif op == 4:                         # register the next full block
            j = reg_count[slot]
            if j < len(a.owned[slot]):
                # low-entropy hash stream -> deliberate duplicates
                block = (j, arg % 3)
                a.register_block(slot, j, chain_hash(None, block), block)
                reg_count[slot] = j + 1
        elif op == 5:                         # acquire cached into empty slot
            # leased blocks may be acquired too: that IS concurrent
            # prefix sharing (ref goes 1 -> 2)
            if not a.owned[slot] and a.index:
                bids = list(dict.fromkeys(a.index.values()))[: arg % 3 + 1]
                if bids:
                    a.acquire_cached(slot, bids)
                    reg_count[slot] = len(bids)
        a.debug_check()
        pt = a.page_table()
        for s in range(cfg.max_slots):
            assert list(pt[s][pt[s] >= 0]) == a.owned[s]
    for s in range(cfg.max_slots):
        a.release(s)
    a.debug_check()
    assert a.n_free() == cfg.n_blocks, "blocks leaked after full release"


class TestAllocatorInvariantProperties:
    @settings(max_examples=50, deadline=None)
    @given(ops=st.lists(st.tuples(st.integers(0, 5), st.integers(0, 3),
                                  st.integers(0, 63)),
                        min_size=1, max_size=80))
    def test_random_ops_prop(self, ops):
        _random_op_machine(ops)

    @pytest.mark.parametrize("seed", range(8))
    def test_random_ops_seeded(self, seed):
        """Deterministic twin of the hypothesis property (the CI image
        has no hypothesis — this keeps the invariant machine exercised
        there)."""
        rng = np.random.default_rng(seed)
        ops = [(int(o), int(s), int(g)) for o, s, g in
               zip(rng.integers(0, 6, 400), rng.integers(0, 4, 400),
                   rng.integers(0, 64, 400))]
        _random_op_machine(ops)


class TestPagedVsContiguous:
    def test_prefill_append_gather_equivalence(self):
        """admit + appends through pages == one contiguous cache."""
        cfg = _cfg()
        cache = PagedKVCache(cfg)
        key = jax.random.PRNGKey(0)
        l, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim

        # two slots with different prompt lengths
        kp0 = jax.random.normal(key, (l, 6, kvh, hd))
        vp0 = jax.random.normal(jax.random.fold_in(key, 1), (l, 6, kvh, hd))
        kp1 = jax.random.normal(jax.random.fold_in(key, 2), (l, 3, kvh, hd))
        vp1 = jax.random.normal(jax.random.fold_in(key, 3), (l, 3, kvh, hd))
        cache.admit(0, kp0, vp0)
        cache.admit(1, kp1, vp1)

        # three decode appends on both slots
        news = []
        for i in range(3):
            kn = jax.random.normal(jax.random.fold_in(key, 10 + i),
                                   (l, cfg.max_slots, kvh, hd))
            vn = jax.random.normal(jax.random.fold_in(key, 20 + i),
                                   (l, cfg.max_slots, kvh, hd))
            cache.append(kn, vn, np.array([True, True, False]))
            news.append((kn, vn))

        kv, vv = cache.view()
        # reference contiguous layout
        ref_k0 = jnp.concatenate([kp0] + [n[0][:, :1] for n in news], 1)
        ref_v0 = jnp.concatenate([vp0] + [n[1][:, :1] for n in news], 1)
        np.testing.assert_allclose(np.asarray(kv[:, 0, :9]),
                                   np.asarray(ref_k0), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(vv[:, 0, :9]),
                                   np.asarray(ref_v0), rtol=1e-6)
        ref_k1 = jnp.concatenate([kp1] + [n[0][:, 1:2] for n in news], 1)
        np.testing.assert_allclose(np.asarray(kv[:, 1, :6]),
                                   np.asarray(ref_k1), rtol=1e-6)
        assert cache.lens.tolist() == [9, 6, 0]

    def test_attention_through_pages_matches(self):
        """Decode attention over the paged view == contiguous attention."""
        cfg = _cfg()
        cache = PagedKVCache(cfg)
        key = jax.random.PRNGKey(5)
        l, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        h = kvh * 2
        s_p = 7
        kp = jax.random.normal(key, (l, s_p, kvh, hd))
        vp = jax.random.normal(jax.random.fold_in(key, 1), (l, s_p, kvh, hd))
        cache.admit(0, kp, vp)

        kv, vv = cache.view()                     # (L, B, S_max, KVH, hd)
        q = jax.random.normal(jax.random.fold_in(key, 2), (1, h, hd)) / 3
        acfg = AttnConfig(h, kvh, hd)
        out_paged = attention_decode(q, kv[0, :1], vv[0, :1],
                                     jnp.asarray([s_p]), acfg)
        out_ref = attention_decode(q, kp[0][None], vp[0][None],
                                   jnp.asarray([s_p]), acfg)
        np.testing.assert_allclose(np.asarray(out_paged),
                                   np.asarray(out_ref), rtol=1e-5, atol=1e-6)

    def test_slot_reuse_after_release(self):
        cfg = _cfg(n_blocks=4, max_slots=2)
        cache = PagedKVCache(cfg)
        l, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        k = jnp.ones((l, 8, kvh, hd))
        cache.admit(0, k, k)
        cache.admit(1, k * 2, k * 2)
        cache.release(0)
        cache.admit(0, k * 3, k * 3)              # reuses freed blocks
        kv, _ = cache.view()
        np.testing.assert_allclose(np.asarray(kv[:, 0, :8]),
                                   np.asarray(k * 3), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(kv[:, 1, :8]),
                                   np.asarray(k * 2), rtol=1e-6)

    def test_memory_savings(self):
        """The point of paging: short requests don't reserve max_seq."""
        cfg = _cfg(n_blocks=8, max_slots=4, max_blocks_per_seq=8)
        cache = PagedKVCache(cfg)
        l, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        for s in range(4):
            cache.admit(s, jnp.ones((l, 2, kvh, hd)),
                        jnp.ones((l, 2, kvh, hd)))
        # 4 slots x 2 tokens = 4 blocks of 4 -> half the pool free, while a
        # contiguous cache would have reserved 4 x 32 rows
        assert cache.alloc.utilization() == 0.5
