"""Hypothesis property tests on model-level invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.models import layers as L


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 2), s=st.sampled_from([32, 64]),
       h=st.sampled_from([2, 4]), d=st.sampled_from([16, 32]))
def test_gqa_equals_mha_when_kv_equals_heads(b, s, h, d):
    """KVH == H must reduce GQA to plain MHA (same KV used per head)."""
    key = jax.random.PRNGKey(b * s + h + d)
    q = jax.random.normal(key, (b, s, h, d)) / np.sqrt(d)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
    out_mha = L.attention_scores_blockwise(
        q, k, v, L.AttnConfig(h, h, d, q_chunk=16))
    # grouped with kv=1: every head uses the same kv -> different result
    # unless we pass the same kv for kvh=h; identity check:
    out_again = L.attention_scores_blockwise(
        q, k, v, L.AttnConfig(h, h, d, q_chunk=32))
    np.testing.assert_allclose(np.asarray(out_mha), np.asarray(out_again),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([32, 48]), chunk=st.sampled_from([8, 16, 48]))
def test_attention_chunk_invariance(s, chunk):
    """Blockwise attention must not depend on the q-chunk size."""
    key = jax.random.PRNGKey(s * chunk)
    b, h, d = 1, 2, 16
    q = jax.random.normal(key, (b, s, h, d)) / 4
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
    a = L.attention_scores_blockwise(q, k, v, L.AttnConfig(h, h, d,
                                                           q_chunk=chunk))
    ref = L.attention_scores_blockwise(q, k, v, L.AttnConfig(h, h, d,
                                                             q_chunk=s))
    np.testing.assert_allclose(np.asarray(a), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_causal_masking_blocks_future():
    """Changing a future token must not change past outputs."""
    key = jax.random.PRNGKey(0)
    b, s, h, d = 1, 16, 2, 16
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
    out1 = L.attention_scores_blockwise(q, k, v, L.AttnConfig(h, h, d,
                                                              q_chunk=4))
    k2 = k.at[:, -1].add(100.0)
    v2 = v.at[:, -1].add(100.0)
    out2 = L.attention_scores_blockwise(q, k2, v2, L.AttnConfig(h, h, d,
                                                                q_chunk=4))
    np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                               np.asarray(out2[:, :-1]), rtol=1e-5,
                               atol=1e-5)
    assert not np.allclose(np.asarray(out1[:, -1]), np.asarray(out2[:, -1]))


@settings(max_examples=10, deadline=None)
@given(pos=st.integers(0, 100), d=st.sampled_from([32, 64]))
def test_rope_relative_property(pos, d):
    """RoPE inner products depend only on relative position:
    <R(p)q, R(p+k)v> == <R(0)q, R(k)v>."""
    key = jax.random.PRNGKey(pos + d)
    q = jax.random.normal(key, (1, 1, d))
    v = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, d))
    delta = 7

    def rot(x, p):
        cos, sin = L.rope_angles(jnp.asarray([float(p)]), d, 1e4)
        return L.apply_rope(x, cos[:, None], sin[:, None])

    a = jnp.sum(rot(q, pos) * rot(v, pos + delta))
    b = jnp.sum(rot(q, 0) * rot(v, delta))
    np.testing.assert_allclose(float(a), float(b), rtol=1e-4, atol=1e-4)


def test_mrope_reduces_to_rope_on_equal_streams():
    """If all three m-rope position streams are equal, m-rope == rope."""
    d = 32
    pos = jnp.arange(8, dtype=jnp.float32)
    cos_r, sin_r = L.rope_angles(pos, d, 1e4)
    pos3 = jnp.broadcast_to(pos, (3, 8))
    cos_m, sin_m = L.mrope_angles(pos3, d, 1e4, (6, 5, 5))
    np.testing.assert_allclose(np.asarray(cos_r), np.asarray(cos_m),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sin_r), np.asarray(sin_m),
                               rtol=1e-6)


@settings(max_examples=8, deadline=None)
@given(window=st.sampled_from([4, 8]))
def test_sliding_window_masks_distant(window):
    key = jax.random.PRNGKey(window)
    b, s, h, d = 1, 32, 2, 16
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
    out1 = L.attention_scores_blockwise(
        q, k, v, L.AttnConfig(h, h, d, q_chunk=8, window=window))
    # perturb a token further than `window` in the past of the last query
    k2 = k.at[:, 0].add(50.0)
    out2 = L.attention_scores_blockwise(
        q, k2, v, L.AttnConfig(h, h, d, q_chunk=8, window=window))
    np.testing.assert_allclose(np.asarray(out1[:, -1]),
                               np.asarray(out2[:, -1]), rtol=1e-5, atol=1e-5)
