"""Automatic prefix caching + copy-on-write across the serving stack.

Covers the PR-3 acceptance criteria:
  * a request whose prompt prefix is cached produces a **bit-identical**
    greedy stream to the same request served cold (f32 and int8 pools),
    while executing zero prefill-chunk tokens for the shared prefix
    (asserted via ``Engine.plan_log``: every warm chunk starts at
    ``cached_len``),
  * two requests sharing a prefix then diverging mid-block both complete
    with streams identical to isolated runs, and releasing one never
    corrupts or frees the other's blocks (live refcounted sharing),
  * forked sequences (shared partial tail) append through copy-on-write:
    the plan carries (src, dst) pairs, the engine copies the device rows,
    and the original stream is unaffected by the fork's divergence,
  * same-shape prefill chunks from different slots run as ONE batched
    device call (``metrics["chunk_batch_calls"]``),
  * the scheduler's starvation bound exempts a sequence from victim
    selection after ``preempt_limit`` preemptions.

Bit-identity note: warm-vs-cold streams are compared with the cold run's
chunk boundaries aligned to ``cached_len`` (same ``prefill_chunk_tokens``)
so both executions trace the exact same device computations over the
exact same pool rows — the suffix chunk shapes match, and decode reads
the identical block content through the page table.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.engine import Engine, Request
from repro.serving.scheduler import Scheduler, Sequence


def _f32_model():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m")).with_(compute_dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _int8_model():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m")).with_(
        compute_dtype="float32", kv_cache_dtype="int8")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _engine(m, params, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 8)
    return Engine(m, params, **kw)


def _chunks_of(eng, uid):
    return [(s, e) for plan in eng.plan_log
            for (u, s, e) in plan["prefills"] if u == uid]


def _cached_of(eng, uid):
    return [cl for plan in eng.plan_log
            for (u, cl) in plan["cached"] if u == uid]


# ---------------------------------------------------------------------------
# warm request: zero prefix prefill tokens, bit-identical stream
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("build", [_f32_model, _int8_model],
                         ids=["f32", "int8"])
def test_warm_request_skips_prefix_bit_identical(build):
    """Cold then warm serve of the same 24-token prompt (block size 8,
    chunk budget 16): the warm admission maps 2 cached full blocks
    (cached_len = 16 — capped below the prompt so the last chunk yields
    sampling logits), its only chunk covers [16, 24), and the greedy
    stream matches the cold one bit for bit."""
    m, params = build()
    rng = np.random.default_rng(0)
    prompt = rng.integers(4, 500, size=24).astype(np.int32)
    eng = _engine(m, params, prefill_chunk_tokens=16)

    ua = eng.submit(prompt, max_new_tokens=8, temperature=0.0)
    (a,) = eng.run()
    ub = eng.submit(prompt, max_new_tokens=8, temperature=0.0)
    (b,) = eng.run()

    assert a.error is None and b.error is None
    assert a.output == b.output, "warm stream must be bit-identical"
    assert _chunks_of(eng, ua) == [(0, 16), (16, 24)]
    assert _cached_of(eng, ub) == [16]
    warm = _chunks_of(eng, ub)
    assert warm == [(16, 24)], \
        f"shared prefix must execute zero prefill tokens, got {warm}"
    assert eng.metrics["prefix_hits"] == 1
    assert eng.metrics["prefix_cached_tokens"] == 16
    eng.pager.debug_check()


def test_prefix_caching_disabled_is_all_cold():
    m, params = _f32_model()
    rng = np.random.default_rng(1)
    prompt = rng.integers(4, 500, size=24).astype(np.int32)
    eng = _engine(m, params, prefill_chunk_tokens=16, prefix_caching=False)
    eng.submit(prompt, max_new_tokens=4, temperature=0.0)
    eng.run()
    ub = eng.submit(prompt, max_new_tokens=4, temperature=0.0)
    eng.run()
    assert eng.metrics["prefix_hits"] == 0
    assert _chunks_of(eng, ub)[0] == (0, 16)
    assert eng.pager.n_cached() == 0


# ---------------------------------------------------------------------------
# concurrent sharing: refcounted blocks, release never corrupts the peer
# ---------------------------------------------------------------------------


def test_concurrent_warm_requests_share_blocks_live():
    """After a cold run registers the prefix, two warm requests admitted
    in the SAME step lease the same cached blocks (refcount 2) — and the
    first one finishing (shorter max_new_tokens) releases its lease
    without corrupting or freeing the survivor's blocks."""
    m, params = _f32_model()
    rng = np.random.default_rng(2)
    prompt = rng.integers(4, 500, size=24).astype(np.int32)
    eng = _engine(m, params, prefill_chunk_tokens=64)
    eng.submit(prompt, max_new_tokens=6, temperature=0.0)
    (cold,) = eng.run()

    ub = eng.submit(prompt, max_new_tokens=6, temperature=0.0)
    uc = eng.submit(prompt, max_new_tokens=12, temperature=0.0)
    eng.run(max_steps=1)                     # admission step only
    pager = eng.pager
    shared = [bid for bid in pager.owned[0] if bid in pager.owned[1]]
    assert shared, "warm admissions must lease the same prefix blocks"
    assert all(pager.refcount[bid] == 2 for bid in shared)
    pager.debug_check()

    done = {r.uid: r for r in eng.run()}
    assert done[ub].output == cold.output
    assert done[uc].output[:6] == cold.output, \
        "survivor's stream must be unaffected by the peer's release"
    assert len(done[uc].output) == 12
    pager.debug_check()


def test_divergent_mid_block_prompts_match_isolated_runs():
    """Prompts sharing 12 tokens (1.5 blocks of 8) diverge inside block
    1: only block 0 is reusable, and both streams equal the streams of
    cold isolated serves (chunk boundaries aligned at 8)."""
    m, params = _f32_model()
    rng = np.random.default_rng(3)
    head = rng.integers(4, 500, size=12).astype(np.int32)
    tails = [rng.integers(4, 500, size=4).astype(np.int32)
             for _ in range(2)]
    prompts = [np.concatenate([head, t]) for t in tails]

    def isolated(p):
        e = _engine(m, params, prefill_chunk_tokens=8)
        e.submit(p, max_new_tokens=8, temperature=0.0)
        (r,) = e.run()
        return r.output

    refs = [isolated(p) for p in prompts]
    eng = _engine(m, params, prefill_chunk_tokens=8)
    u0 = eng.submit(prompts[0], max_new_tokens=8, temperature=0.0)
    done0 = eng.run()
    u1 = eng.submit(prompts[1], max_new_tokens=8, temperature=0.0)
    done1 = eng.run()
    assert done0[0].output == refs[0]
    assert done1[0].output == refs[1]
    assert _cached_of(eng, u1) == [8], "only the full shared block reuses"
    assert _chunks_of(eng, u1)[0][0] == 8
    eng.pager.debug_check()


# ---------------------------------------------------------------------------
# copy-on-write: forked sequences append into a shared partial tail
# ---------------------------------------------------------------------------


def test_fork_cow_preserves_original_stream():
    """Emulate n=2 parallel sampling by hand (below the ``n_samples``
    API): after the original has a partial tail block, fork its leases
    into a second slot whose request diverges at the last sampled token.
    The next decode step must COW the shared tail (plan.cows -> device
    copy), and the original's greedy stream must be bit-identical to an
    unforked run."""
    m, params = _f32_model()
    rng = np.random.default_rng(4)
    prompt = rng.integers(4, 500, size=10).astype(np.int32)

    ref_eng = _engine(m, params)
    ref_eng.submit(prompt, max_new_tokens=12, temperature=0.0)
    (ref,) = ref_eng.run()

    eng = _engine(m, params)
    eng.submit(prompt, max_new_tokens=12, temperature=0.0)
    eng.run(max_steps=3)                     # prefill + 2 decodes
    (slot_a, seq_a), = eng.scheduler.running.items()
    assert seq_a.kv_len % eng.page_size != 0, "fork wants a partial tail"

    slot_b = 1 - slot_a
    eng.pager.fork(slot_a, slot_b)
    div = int((seq_a.output[-1] + 7) % 400 + 4)
    req_b = Request(uid=999, prompt=np.asarray(prompt), max_new_tokens=8,
                    temperature=0.0, output=seq_a.output[:-1] + [div],
                    rng_key=jax.random.PRNGKey(0))
    seq_b = Sequence(req=req_b, prompt=seq_a.prompt, tokens=seq_a.tokens,
                     slot=slot_b, prefilled=seq_a.prefilled,
                     kv_len=seq_a.kv_len, order=eng.scheduler._order,
                     block_hashes=list(seq_a.block_hashes),
                     registered=seq_a.registered, output=req_b.output)
    eng.scheduler._order += 1
    eng.scheduler.running[slot_b] = seq_b
    # the engine syncs device lens from scheduler state after each decode;
    # a real fork API would do the same — the injected slot needs it once
    eng.cache["lens"] = jnp.asarray(eng.scheduler.device_lens(), jnp.int32)

    done = {r.uid: r for r in eng.run()}
    assert eng.metrics["cow_copies"] >= 1, "shared tail append must COW"
    cow_pairs = [p for plan in eng.plan_log for p in plan["cows"]]
    assert cow_pairs
    assert done[1].output == ref.output, \
        "fork + divergence must not corrupt the original stream"
    assert len(done[999].output) == 8 and done[999].output[2] == div
    assert done[999].output != done[1].output[:8]
    eng.pager.debug_check()


def test_scheduler_plans_cow_for_shared_tail_append():
    """Unit-level: two running sequences sharing a forked partial tail —
    the first planned decode carries exactly one COW pair, after which
    the tails are distinct and every lease is exclusive."""
    from repro.serving.paged_cache import BlockAllocator, PagedConfig
    pager = BlockAllocator(PagedConfig(
        n_layers=1, n_kv_heads=1, head_dim=8, block_size=4, n_blocks=8,
        max_slots=2, max_blocks_per_seq=8))
    sched = Scheduler(2, 64, pager, prefill_chunk_tokens=64)

    def req(uid):
        return Request(uid=uid, prompt=np.arange(6, dtype=np.int32),
                       max_new_tokens=8, output=[5])

    a = Sequence(req=req(1), prompt=np.arange(6, dtype=np.int32),
                 tokens=np.arange(6, dtype=np.int32), slot=0, prefilled=6,
                 kv_len=6, order=0)
    pager.ensure(0, 6)
    pager.fork(0, 1)
    b = Sequence(req=req(2), prompt=a.prompt, tokens=a.tokens, slot=1,
                 prefilled=6, kv_len=6, order=1)
    sched.running = {0: a, 1: b}
    sched._order = 2

    plan = sched.schedule()
    assert sorted(plan.decodes) == [0, 1]
    assert len(plan.cows) == 1, "one COW un-shares the tail for both"
    src, dst = plan.cows[0]
    # the first planned append (oldest seq) got the fresh copy; the
    # other keeps the original — now exclusive
    assert {pager.owned[0][1], pager.owned[1][1]} == {src, dst}
    assert all(pager.refcount[blk] == 1
               for s in (0, 1) for blk in pager.owned[s][1:])
    pager.debug_check()


def test_preempted_victim_cow_pairs_retracted():
    """A victim whose decode (and COW) were already planned this step
    must have BOTH retracted: the COW dst returns to the free list on
    release and may be re-leased within the same plan, so a stale device
    copy could clobber a live slot's rows."""
    from repro.serving.paged_cache import BlockAllocator, PagedConfig
    pager = BlockAllocator(PagedConfig(
        n_layers=1, n_kv_heads=1, head_dim=8, block_size=4, n_blocks=4,
        max_slots=2, max_blocks_per_seq=8))
    sched = Scheduler(2, 64, pager, prefill_chunk_tokens=64,
                      preempt_limit=2)

    def mk(uid, slot, order, kv, n_pre):
        r = Request(uid=uid, prompt=np.arange(6, dtype=np.int32),
                    max_new_tokens=20, output=[5])
        return Sequence(req=r, prompt=r.prompt, tokens=r.prompt,
                        slot=slot, prefilled=6, kv_len=kv, order=order,
                        n_preemptions=n_pre)

    old = mk(1, 0, 0, 6, 0)                  # fair; shared partial tail
    pager.ensure(0, 6)
    pager.fork(0, 1)
    new = mk(2, 1, 1, 12, 2)                 # exempt; needs a 4th block
    pager.ensure(1, 12)
    sched.running = {0: old, 1: new}
    sched._order = 2

    plan = sched.schedule()
    # old planned decode+COW first, then new's growth evicted it (the
    # only fair candidate) — decode AND cow retracted, new proceeds
    assert plan.preempted == [1]
    assert plan.decodes == [1] and plan.decode_uids == [2]
    assert plan.cows == [], "victim's planned COW must be retracted"
    assert pager.stats["cow_copies"] == 1    # allocator did copy-remap
    pager.debug_check()


# ---------------------------------------------------------------------------
# parallel sampling (n_samples) over fork/COW — cold/warm methodology
# extended from the prefix tests above to sampling groups
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("build", [_f32_model, _int8_model],
                         ids=["f32", "int8"])
def test_sampling_group_siblings_bit_identical_to_reruns(build):
    """The fanout bit-exactness bar: sibling ``i`` of an (seed=s,
    n_samples=n) request streams the identical tokens to an independent
    (seed=s, stream=i, n_samples=1) request served alone on a fresh
    engine — the forked prompt KV, the COW'd tails, and the per-stream
    PRNG must all be invisible to the sampled output (f32 and int8
    pools).  The group also prefills its prompt exactly once."""
    m, params = build()
    rng = np.random.default_rng(10)
    prompt = rng.integers(4, 500, size=13).astype(np.int32)

    eng = _engine(m, params, max_slots=4, prefill_chunk_tokens=16)
    ug = eng.submit(prompt, max_new_tokens=7, temperature=1.0, top_p=0.9,
                    seed=11, n_samples=3)
    (r,) = eng.run()
    assert r.error is None and len(r.outputs) == 3
    assert all(len(o) == 7 for o in r.outputs)
    assert r.output is r.outputs[0]
    eng.pager.debug_check()
    assert eng.pager.utilization() == 0.0, "drained group must release all"

    # one prompt prefill: the group's chunks cover [0, len) exactly once
    assert _chunks_of(eng, ug) == [(0, 13)]
    assert eng.metrics["fanouts"] == 1

    for i in range(3):
        solo = _engine(m, params, max_slots=4, prefill_chunk_tokens=16)
        solo.submit(prompt, max_new_tokens=7, temperature=1.0, top_p=0.9,
                    seed=11, stream=i)
        (ri,) = solo.run()
        assert ri.output == r.outputs[i], \
            f"sibling {i} diverged from its independent rerun"


def test_warm_sampling_group_fanout_bit_identical():
    """Cold then warm serve of the same n_samples=3 request: the warm
    admission maps the prompt's cached full blocks read-only, fans out
    on top of them (fork ref++ over already-shared cached blocks), and
    every sibling's stream matches the cold run bit for bit while the
    shared prefix executes zero prefill tokens."""
    m, params = _f32_model()
    rng = np.random.default_rng(11)
    prompt = rng.integers(4, 500, size=24).astype(np.int32)
    eng = _engine(m, params, max_slots=4, prefill_chunk_tokens=16)

    ua = eng.submit(prompt, max_new_tokens=6, temperature=1.0, seed=21,
                    n_samples=3)
    (a,) = eng.run()
    ub = eng.submit(prompt, max_new_tokens=6, temperature=1.0, seed=21,
                    n_samples=3)
    (b,) = eng.run()

    assert a.error is None and b.error is None
    assert a.outputs == b.outputs, \
        "warm group fanout must be bit-identical to the cold one"
    assert _chunks_of(eng, ua) == [(0, 16), (16, 24)]
    assert _cached_of(eng, ub) == [16]
    assert _chunks_of(eng, ub) == [(16, 24)], \
        "warm group must execute zero prefill tokens for the prefix"
    eng.pager.debug_check()


# ---------------------------------------------------------------------------
# batched chunk execution
# ---------------------------------------------------------------------------


def test_same_shape_chunks_run_as_one_batched_call():
    """Two same-length prompts admitted in one step produce one batched
    prefill_chunk_batch call (2 chunks, 1 call) with streams identical
    to serving each prompt alone."""
    m, params = _f32_model()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(4, 500, size=12).astype(np.int32)
               for _ in range(2)]

    def isolated(p):
        e = _engine(m, params)
        e.submit(p, max_new_tokens=6, temperature=0.0)
        (r,) = e.run()
        return r.output

    refs = [isolated(p) for p in prompts]
    eng = _engine(m, params)
    for p in prompts:
        eng.submit(p, max_new_tokens=6, temperature=0.0)
    done = sorted(eng.run(), key=lambda r: r.uid)
    first = eng.plan_log[0]
    assert len(first["prefills"]) == 2
    assert eng.metrics["prefill_chunks"] == 2
    assert eng.metrics["chunk_batch_calls"] == 1, \
        "same-shape chunks must share one device call"
    assert [r.output for r in done] == refs


def test_different_shape_chunks_share_one_padded_call():
    """Shape-stable batching: chunks with different lengths (and hence
    different last-position indices) still execute as ONE padded device
    call per step — with streams identical to serving each prompt
    alone."""
    m, params = _f32_model()
    rng = np.random.default_rng(6)
    prompts = [rng.integers(4, 500, size=n).astype(np.int32)
               for n in (12, 9)]

    def isolated(p):
        e = _engine(m, params)
        e.submit(p, max_new_tokens=4, temperature=0.0)
        (r,) = e.run()
        return r.output

    refs = [isolated(p) for p in prompts]
    eng = _engine(m, params)
    for p in prompts:
        eng.submit(p, max_new_tokens=4, temperature=0.0)
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert all(r.error is None for r in done)
    assert eng.metrics["prefill_chunks"] == 2
    assert eng.metrics["chunk_batch_calls"] == 1, \
        "mixed-shape chunks must share one padded device call"
    assert [r.output for r in done] == refs


# ---------------------------------------------------------------------------
# starvation bound
# ---------------------------------------------------------------------------


def test_starvation_bound_exempts_repeatedly_preempted():
    """With the newer sequence already at preempt_limit, growth pressure
    victimizes the OLDER (fair) sequence instead — the exempt one keeps
    its slot and can finish."""
    from repro.serving.paged_cache import BlockAllocator, PagedConfig
    pager = BlockAllocator(PagedConfig(
        n_layers=1, n_kv_heads=1, head_dim=8, block_size=4, n_blocks=4,
        max_slots=2, max_blocks_per_seq=8))
    sched = Scheduler(2, 64, pager, prefill_chunk_tokens=64,
                      preempt_limit=2)

    def mk(uid, slot, order, n_pre):
        r = Request(uid=uid, prompt=np.arange(8, dtype=np.int32),
                    max_new_tokens=8, output=[3])
        s = Sequence(req=r, prompt=r.prompt, tokens=r.prompt, slot=slot,
                     prefilled=8, kv_len=8, order=order,
                     n_preemptions=n_pre)
        pager.ensure(slot, 8)
        return s

    old = mk(1, 0, 0, 0)
    new = mk(2, 1, 1, 2)                     # at the limit: exempt
    sched.running = {0: old, 1: new}
    sched._order = 2

    plan = sched.schedule()                  # both decodes need a block
    assert plan.preempted == [1], \
        "victim must be the fair (older) sequence, not the exempt one"
    assert plan.decodes == [1]
    pager.debug_check()


def test_starvation_bound_falls_back_when_all_exempt():
    """If every running sequence is past the limit the newest is still
    evictable — the progress guarantee outranks the bound."""
    from repro.serving.paged_cache import BlockAllocator, PagedConfig
    pager = BlockAllocator(PagedConfig(
        n_layers=1, n_kv_heads=1, head_dim=8, block_size=4, n_blocks=4,
        max_slots=2, max_blocks_per_seq=8))
    sched = Scheduler(2, 64, pager, prefill_chunk_tokens=64,
                      preempt_limit=1)

    def mk(uid, slot, order):
        r = Request(uid=uid, prompt=np.arange(8, dtype=np.int32),
                    max_new_tokens=8, output=[3])
        s = Sequence(req=r, prompt=r.prompt, tokens=r.prompt, slot=slot,
                     prefilled=8, kv_len=8, order=order, n_preemptions=5)
        pager.ensure(slot, 8)
        return s

    sched.running = {0: mk(1, 0, 0), 1: mk(2, 1, 1)}
    sched._order = 2
    plan = sched.schedule()
    assert plan.preempted == [2] and plan.decodes == [0]


def test_repeatedly_preempted_request_finishes_under_pressure():
    """End-to-end: an oversubscribed pool with continuous contention
    still drains every request (the bound guarantees the oldest survivor
    makes progress), outputs identical to the uncontended run."""
    m, params = _f32_model()
    rng = np.random.default_rng(7)
    prompts = [rng.integers(4, 500, size=9).astype(np.int32)
               for _ in range(3)]

    def serve(n_pages):
        eng = _engine(m, params, n_pages=n_pages, preempt_limit=2)
        for p in prompts:
            eng.submit(p, max_new_tokens=14, temperature=0.0)
        done = sorted(eng.run(), key=lambda r: r.uid)
        assert all(r.error is None for r in done)
        return [r.output for r in done], eng

    contended, eng = serve(5)
    assert eng.metrics["preemptions"] > 0
    uncontended, _ = serve(None)
    assert contended == uncontended
    assert all(len(o) == 14 for o in contended)
