"""Tests for the roofline analysis tooling — these are load-bearing for
§Roofline, so they get their own validation against known-good cases."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.launch import flops as flopslib
from repro.launch import hlo_cost
from repro.launch.roofline import analytic_bytes, per_device_bytes, tree_bytes


class TestFlopsCounter:
    def test_plain_matmul(self):
        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        got = flopslib.count_flops(lambda x, y: x @ y, a, b)
        assert got == 2 * 64 * 128 * 32

    def test_scan_multiplies_length(self):
        """The exact failure mode of cost_analysis: scans must multiply."""
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)

        def f(x, ws):
            def body(h, w):
                return h @ w, None
            return lax.scan(body, x, ws)[0]

        got = flopslib.count_flops(f, x, ws)
        assert got == 10 * 2 * 64 * 64 * 64

    def test_nested_scan(self):
        x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
        ws = jax.ShapeDtypeStruct((4, 5, 32, 32), jnp.float32)

        def f(x, ws):
            def outer(h, wgrp):
                def inner(h2, w):
                    return h2 @ w, None
                return lax.scan(inner, h, wgrp)[0], None
            return lax.scan(outer, x, ws)[0]

        got = flopslib.count_flops(f, x, ws)
        assert got == 4 * 5 * 2 * 32 ** 3

    def test_grad_counts_backward(self):
        """VJP roughly triples matmul flops (fwd + two transposes)."""
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        fwd = flopslib.count_flops(lambda x, w: jnp.sum(x @ w), a, w)
        bwd = flopslib.count_flops(
            jax.grad(lambda x, w: jnp.sum(x @ w), argnums=(0, 1)), a, w)
        assert bwd >= 2 * fwd

    def test_batched_dot(self):
        a = jax.ShapeDtypeStruct((8, 16, 32), jnp.float32)
        b = jax.ShapeDtypeStruct((8, 32, 4), jnp.float32)
        got = flopslib.count_flops(
            lambda x, y: jnp.einsum("bik,bkj->bij", x, y), a, b)
        assert got == 8 * 2 * 16 * 32 * 4


class TestHloCollectives:
    HLO = """
%wbody.1 (arg.1: (s32[], f32[16,512])) -> (s32[], f32[16,512]) {
  %ar.1 = f32[16,512]{1,0} all-reduce(%gte.2), replica_groups={{0,1,2,3}}, to_apply=%add.1
}
%wcond.1 (arg.2: (s32[], f32[16,512])) -> pred[] {
  %c.9 = s32[] constant(7)
  ROOT %cmp.1 = pred[] compare(%gte.9, %c.9), direction=LT
}
ENTRY %main.1 (p0: f32[16,512]) -> f32[16,512] {
  %w.1 = (s32[], f32[16,512]) while(%t.0), condition=%wcond.1, body=%wbody.1
  %ag.1 = f32[64,512]{1,0} all-gather(%gte.5), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""

    def test_while_trip_multiplication(self):
        got = hlo_cost.collective_wire_bytes(self.HLO)
        ar_bytes = 16 * 512 * 4
        # ring all-reduce: 2*b*(g-1)/g, 7 trips
        assert got["all-reduce"] == pytest.approx(7 * 2 * ar_bytes * 3 / 4)
        ag_bytes = 64 * 512 * 4
        assert got["all-gather"] == pytest.approx(ag_bytes * 3 / 4)

    def test_trip_count_parsing(self):
        assert hlo_cost._trip_count(self.HLO.split("ENTRY")[0]
                                    .split("%wcond.1")[1]) == 7
        assert hlo_cost._trip_count("no compare here") == 1

    def test_real_lowered_module(self):
        """End to end on an actual compiled SPMD module: a row-parallel
        matmul (contraction dim sharded over `model`) lowers to a
        partial-sum all-reduce, and the parser prices its wire bytes at
        the ring cost for the real device count.  Runs for real under
        the CI multi-device lane's forced host devices."""
        if jax.device_count() < 2:
            pytest.skip("needs >1 device (CI multi-device lane)")
        from jax.sharding import Mesh, NamedSharding
        from jax.sharding import PartitionSpec as P
        n = jax.device_count()
        mesh = Mesh(np.asarray(jax.devices()).reshape(n), ("model",))
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 32), jnp.float32)
        compiled = jax.jit(
            lambda a, b: a @ b,
            in_shardings=(NamedSharding(mesh, P(None, "model")),
                          NamedSharding(mesh, P("model", None))),
            out_shardings=NamedSharding(mesh, P()),
        ).lower(x, w).compile()
        got = hlo_cost.collective_wire_bytes(compiled.as_text())
        assert got.get("all-reduce", 0) > 0, \
            f"no all-reduce priced in SPMD module: {got}"
        # one ring all-reduce of the full (8, 32) f32 partial sums
        out_bytes = 8 * 32 * 4
        assert got["all-reduce"] == pytest.approx(
            2 * out_bytes * (n - 1) / n)

    def test_group_size_iota_format(self):
        line = "replica_groups=[8,32]<=[256] ..."
        assert hlo_cost._group_size(line, 1) == 32


class TestRoofline:
    def test_tree_bytes_quantized(self):
        from repro.core import quantize
        t = {"w": quantize(jnp.ones((64, 128))), "b": jnp.ones((4,))}
        got = tree_bytes(t)
        assert got == 64 * 128 + 64 * 2 * 4 + 4 * 4    # codes + scales + b

    def test_per_device_bytes_2d_sharding(self):
        from jax.sharding import PartitionSpec as P

        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")

        struct = {"w": jax.ShapeDtypeStruct((128, 256, 64), jnp.float32)}
        specs = {"w": P("data", "model", None)}
        got = per_device_bytes(struct, specs, FakeMesh())
        assert got == 128 * 256 * 64 * 4 / 256

    def test_analytic_decode_is_weights_plus_cache(self):
        from repro.configs import get_config
        from repro.configs.base import ShapeCell
        cfg = get_config("glm4-9b")
        cell = ShapeCell("decode_32k", 32768, 128, "decode")
        out = analytic_bytes(cfg, cell, 256, int(10e9), int(100e9))
        assert out["weights"] == pytest.approx(10e9 / 16)
        assert out["cache"] == pytest.approx(100e9 / 256)
        assert out["total"] > out["weights"] + out["cache"]
