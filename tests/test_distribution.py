"""Distribution-layer tests on a small in-process device mesh.

conftest.py does NOT set device-count flags (smoke tests must see 1
device), so this module spawns subprocess checks only where a multi-device
mesh is essential, and otherwise validates spec construction logic (pure
Python, no devices needed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.configs.base import ShapeCell
from repro.distribution import sharding as sh
from repro.launch import steps as steplib
from repro.models import build_model


class FakeMesh:
    """Duck-typed mesh for spec-rule tests (axis sizes only)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
POD_MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def specs_for(arch, mode="train", mesh=MESH):
    cfg = get_config(arch)
    model = build_model(cfg)
    ps = steplib.params_struct(model, quantized=(mode == "serve"))
    return cfg, ps, sh.param_specs(cfg, ps, mesh, mode=mode)


def test_dense_train_specs():
    cfg, ps, specs = specs_for("glm4-9b")
    assert specs["embed"] == P("model", None)
    blk = specs["blocks"]
    # H=32 divisible -> heads sharded; KV=2 not -> replicated
    assert blk["attn"]["wq"] == P(None, "model", None, None)
    assert blk["attn"]["wk"] == P(None, None, None, None)
    assert blk["attn"]["wo"] == P(None, None, "model", None)
    assert blk["mlp"]["w1"] == P(None, "model", None)
    assert blk["mlp"]["w2"] == P(None, None, "model")
    assert blk["norm1"]["gamma"] == P(None, None)


def test_nondivisible_heads_fall_back_to_hd():
    cfg, ps, specs = specs_for("llama3.2-3b")     # H=24 % 16 != 0
    blk = specs["blocks"]
    assert blk["attn"]["wq"] == P(None, None, "model", None)
    assert blk["attn"]["wk"] == P(None, None, "model", None)


def test_moe_expert_parallel():
    # qwen3 default is FSDP-EP (promoted after the §Perf hillclimb):
    # experts over data, d_ff over model
    cfg, ps, specs = specs_for("qwen3-moe-30b-a3b")
    blk = specs["blocks"]
    assert blk["moe"]["w1"] == P(None, "data", "model", None)
    assert blk["moe"]["w2"] == P(None, "data", None, "model")
    assert blk["moe"]["router"] == P(None, None, None)
    # classic TP-EP still available as an override
    cfg2 = get_config("qwen3-moe-30b-a3b").with_(moe_shard="model")
    model = build_model(cfg2)
    ps2 = steplib.params_struct(model)
    specs2 = sh.param_specs(cfg2, ps2, MESH, mode="train")
    assert specs2["blocks"]["moe"]["w1"] == P(None, "model", None, None)


def test_ssm_specs():
    cfg, ps, specs = specs_for("mamba2-370m")
    blk = specs["blocks"]
    assert blk["ssm"]["wz"] == P(None, "model", None)
    assert blk["ssm"]["wB"] == P(None, None, None)
    assert blk["ssm"]["out_proj"] == P(None, None, "model")
    assert blk["ssm"]["A_log"] == P(None, "model")
    assert blk["ssm"]["norm"]["gamma"] == P(None, "model")


def test_serve_specs_quantized():
    cfg, ps, specs = specs_for("glm4-9b", mode="serve")
    wq = specs["blocks"]["attn"]["wq"]
    # serve: din row-parallel — D (last dim of codes) on model
    assert wq.q == P(None, None, None, "model")
    # scale last dim G=D/64=64 also divides 16
    assert wq.scale == P(None, None, None, "model")
    # embed stays vocab-sharded
    assert specs["embed"].q == P("model", None)


def test_sanitize_nulls_nondivisible():
    spec = sh.sanitize(P("model", None), (100, 64), MESH)
    assert spec == P(None, None)
    spec = sh.sanitize(P(("pod", "data"), None), (64, 8), POD_MESH)
    assert spec == P(("pod", "data"), None)
    spec = sh.sanitize(P(("pod", "data"), None), (8, 8), POD_MESH)
    assert spec == P(None, None)


def test_cache_specs_kv_vs_seq():
    # zamba2: KVH=32 divisible -> KVH sharded
    cfg = get_config("zamba2-1.2b")
    model = build_model(cfg)
    cs = jax.eval_shape(lambda: model.init_cache(128, 32768))
    specs = sh.cache_specs(cfg, cs, MESH)
    assert specs["attn"]["k"] == P(None, "data", None, "model", None)
    # glm4: KVH=2 -> sequence sharded (flash-decode SP)
    cfg2 = get_config("glm4-9b")
    m2 = build_model(cfg2)
    cs2 = jax.eval_shape(lambda: m2.init_cache(128, 32768))
    specs2 = sh.cache_specs(cfg2, cs2, MESH)
    assert specs2["attn"]["k"] == P(None, "data", "model", None, None)


def test_long500k_batch_replicated():
    cfg = get_config("mamba2-370m")
    model = build_model(cfg)
    cell = ShapeCell("long_500k", 524288, 1, "decode")
    batch = steplib.input_specs(cfg, cell)
    specs = sh.data_specs(cfg, batch, MESH)
    assert specs["tokens"] == P(None)


def test_zero_optimizer_sharding():
    cfg = get_config("llama3.2-3b")
    model = build_model(cfg)
    ps = steplib.params_struct(model)
    pspecs = sh.param_specs(cfg, ps, MESH, mode="train")
    sspecs = steplib.train_state_specs(cfg, pspecs, MESH, ps, zero=True)
    # embed (V@model, D): ZeRO adds data to D
    assert sspecs["opt"]["m"]["embed"] == P("model", "data")
    # params themselves stay param-sharded only
    assert sspecs["params"]["embed"] == P("model", None)


def test_input_specs_cells():
    cfg = get_config("qwen2-vl-7b")
    for cell in (ShapeCell("train_4k", 4096, 256, "train"),
                 ShapeCell("prefill_32k", 32768, 32, "prefill"),
                 ShapeCell("decode_32k", 32768, 128, "decode")):
        spec = steplib.input_specs(cfg, cell)
        if cell.kind == "train":
            assert spec["embeds"].shape == (256, 4096, cfg.d_model)
            assert spec["labels"].shape == (256, 4096)
        if cell.kind == "decode":
            assert spec["tokens"].shape == (128,)


def test_microbatch_picker():
    cell = ShapeCell("train_4k", 4096, 256, "train")
    assert steplib.pick_microbatches(cell, MESH) == 8      # 16 rows -> 2/dev
    assert steplib.pick_microbatches(cell, POD_MESH) == 4  # 8 rows -> 2/dev


def test_shapes_for_skips():
    from repro.configs.base import shapes_for
    names = [c.name for c in shapes_for(get_config("glm4-9b"))]
    assert "long_500k" not in names        # pure full attention
    names = [c.name for c in shapes_for(get_config("zamba2-1.2b"))]
    assert "long_500k" in names            # hybrid SSM
