"""Tests for the beyond-paper extensions added in the extension pass:
flash-prefill kernel, distributed sampling, GGML export."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import quantize
from repro.kernels import ops, ref


class TestFlashPrefill:
    @pytest.mark.parametrize("b,s,h,kvh,d,causal", [
        (2, 256, 4, 2, 64, True),
        (1, 512, 8, 8, 128, True),
        (2, 256, 4, 1, 64, False),
        (1, 384, 6, 2, 64, True),      # non-pow2 S exercises block picker
    ])
    def test_vs_oracle(self, b, s, h, kvh, d, causal):
        key = jax.random.PRNGKey(b * s + h)
        q = jax.random.normal(key, (b, s, h, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, d))
        out = ops.flash_prefill(q, k, v, causal=causal, interpret=True)
        want = ref.ref_flash_prefill(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=2e-5)

    def test_matches_model_blockwise_attention(self):
        """The kernel and the model's scan-form attention agree."""
        from repro.models.layers import AttnConfig, attention_scores_blockwise
        b, s, h, kvh, d = 1, 256, 4, 2, 64
        key = jax.random.PRNGKey(3)
        q = jax.random.normal(key, (b, s, h, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, d))
        blockwise = attention_scores_blockwise(
            q * d ** -0.5, k, v, AttnConfig(h, kvh, d, q_chunk=64))
        kern = ops.flash_prefill(q, k, v, causal=True, interpret=True)
        np.testing.assert_allclose(np.asarray(kern), np.asarray(blockwise),
                                   rtol=1e-4, atol=1e-4)


class TestDistributedSampling:
    def test_gumbel_matches_categorical_distribution(self):
        from repro.serving.sampling_distributed import gumbel_argmax
        logits = jnp.log(jnp.asarray([[0.6, 0.3, 0.1, 1e-9]]))
        counts = np.zeros(4)
        for i in range(600):
            tok = gumbel_argmax(jax.random.PRNGKey(i), logits)
            counts[int(tok[0])] += 1
        freq = counts / counts.sum()
        np.testing.assert_allclose(freq[:3], [0.6, 0.3, 0.1], atol=0.07)

    def test_gumbel_shard_invariance(self):
        """Same key -> same token regardless of how V would be sharded
        (the noise is a function of the global index)."""
        from repro.serving.sampling_distributed import gumbel_argmax
        logits = jax.random.normal(jax.random.PRNGKey(0), (4, 512))
        a = gumbel_argmax(jax.random.PRNGKey(7), logits)
        b = gumbel_argmax(jax.random.PRNGKey(7), logits)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_topp_candidates_exact_when_k_covers(self):
        from repro.serving.engine import sample_logits
        from repro.serving.sampling_distributed import distributed_sample
        logits = jax.random.normal(jax.random.PRNGKey(1), (8, 256)) * 3
        # k = V: candidate strip == full vocab -> distribution matches the
        # dense sampler; check top-p mask produces tokens from the nucleus
        for i in range(32):
            tok = distributed_sample(jax.random.PRNGKey(i), logits,
                                     temperature=1.0, top_p=0.5, k=256)
            dense_keep = []
            for row in range(8):
                srt = np.sort(np.asarray(logits[row]))[::-1]
                probs = np.exp(srt - srt.max())
                probs /= probs.sum()
                kcount = int((np.cumsum(probs) < 0.5).sum()) + 1
                thresh = srt[kcount - 1]
                dense_keep.append(np.asarray(logits[row]) >= thresh)
            for row in range(8):
                assert dense_keep[row][int(tok[row])]

    def test_wire_savings_estimate(self):
        """The §Perf motivation: candidate strip << full logits."""
        b, v, k, shards = 128, 151552, 64, 16
        full = b * v * 4
        strip = b * k * shards * (4 + 4)
        assert full / strip > 70


class TestGgmlExport:
    def test_roundtrip_fidelity(self, tmp_path):
        from repro.checkpoint import ggml_export
        w = jax.random.normal(jax.random.PRNGKey(0), (16, 128)) * 2.0
        t = quantize(w, group_size=64)
        params = {"w": t, "norm": jnp.ones((128,))}
        path = str(tmp_path / "model.rpq8")
        manifest = ggml_export.export(path, params)
        assert set(manifest) == {"['w']", "['norm']"}
        back = ggml_export.read_back(path)
        shape, arr = back["['w']"]
        assert tuple(shape) == (16, 128)
        # re-blocked 64->32: codes re-round against the (smaller) 32-block
        # absmax — error bound is half a block step + f16 scale rounding
        src = np.asarray(t.dequantize())
        step = np.abs(src.reshape(16, 4, 32)).max(-1, keepdims=True) / 127.0
        err = np.abs(arr - src).reshape(16, 4, 32)
        assert np.all(err <= step * 0.51 + 1e-3)
        _, norm = back["['norm']"]
        np.testing.assert_array_equal(norm, np.ones(128, np.float32))

    def test_block32_exactness_when_source_is_32(self, tmp_path):
        from repro.checkpoint import ggml_export
        w = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
        t = quantize(w, group_size=32)          # source == GGML block
        codes, scales = ggml_export._reblock_q8(t)
        # same blocks -> identical codes
        np.testing.assert_array_equal(codes, np.asarray(t.q))
