"""Speculative decoding: draft-then-verify on the paged pool.

The acceptance bar (ISSUE 8 / docs/ARCHITECTURE.md):

  * **greedy bit-exactness** — a speculative greedy stream must be
    bit-identical to the non-speculative stream of the same request,
    f32 AND int8 pools, whatever the proposer's quality (a perfect
    replay oracle, the n-gram default, or adversarially wrong drafts):
    the acceptance rule samples every position from the *verified*
    logits with the per-position keys plain decode would have used, so
    drafts only decide how many tokens land per step, never which,
  * **rollback-as-truncation** — rejected tail tokens un-append through
    ``BlockAllocator.truncate``: leases shrink via the ordinary release
    paths, exclusively-held dropped blocks are unregistered so the
    prefix index never serves speculative KV, and the drained pool
    holds zero leases (audit clean),
  * **composition independence** — a sampled sequence's stream does not
    change when other requests share its verify batches (per-row keyed
    draws + the chunk path's row independence),
  * **compile stability** — however draft lengths churn, the verify
    entry stays at ONE executable per pool key
    (``Engine.verify_compile_count``), because every verify call is
    padded to the fixed ``(max_slots, spec_tokens + 1)`` extent.
"""

import jax
import numpy as np
import pytest

from repro.serving.engine import Engine
from repro.serving.paged_cache import BlockAllocator, PagedConfig, chain_hash
from repro.serving.spec_decode import (DraftModelProposer, DraftProposer,
                                       NgramProposer, build_proposer)


@pytest.fixture(scope="module")
def f32_model():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m")).with_(compute_dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def int8_model():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m")).with_(
        compute_dtype="float32", kv_cache_dtype="int8")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _engine(m, params, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 96)
    kw.setdefault("page_size", 8)
    return Engine(m, params, **kw)


def _prompts():
    rng = np.random.default_rng(5)
    flat = rng.integers(4, 500, size=11).astype(np.int32)
    # a repetitive prompt: the n-gram proposer finds its suffix earlier
    # in the context and proposes the (correct, if the model loops) next
    # tokens — the self-speculation sweet spot
    rep = np.tile(np.asarray([7, 11, 13, 17], np.int32), 4)
    return [flat, rep]


class _ReplayProposer:
    """Oracle proposer: replays a known-good reference stream — every
    draft is right, so acceptance is maximal (upper-bounds the win)."""

    def __init__(self, ref_output):
        self.ref = [int(t) for t in ref_output]

    def propose(self, prompt, output, k):
        m = len(output)
        if output != self.ref[:m]:
            return []                # diverged (must never happen)
        return self.ref[m:m + k]


class _WrongProposer:
    """Adversarial proposer: drafts that are always wrong (the reference
    token shifted by one) — every verify step rolls back, and the stream
    must STILL be bit-identical to non-speculative decode."""

    def __init__(self, ref_output, vocab=512):
        self.ref = [int(t) for t in ref_output]
        self.vocab = vocab

    def propose(self, prompt, output, k):
        m = len(output)
        return [(t + 1) % self.vocab
                for t in self.ref[m:m + k]] or [3] * k


def _serve(m, params, prompts, max_new=20, temperature=0.0, seed=None,
           **kw):
    eng = _engine(m, params, **kw)
    uids = [eng.submit(p, max_new_tokens=max_new, temperature=temperature,
                       seed=seed) for p in prompts]
    done = {r.uid: r for r in eng.run()}
    assert all(done[u].error is None for u in uids), \
        [done[u].error for u in uids]
    return [done[u].output for u in uids], eng


# ---------------------------------------------------------------------------
# greedy bit-exactness (the hard bar), f32 + int8
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("build", ["f32_model", "int8_model"],
                         ids=["f32", "int8"])
def test_greedy_bitexact_ngram(build, request):
    """Speculative greedy streams == non-speculative streams, token for
    token, under the default n-gram proposer (f32 and int8 pools)."""
    m, params = request.getfixturevalue(build)
    base, _ = _serve(m, params, _prompts())
    spec, eng = _serve(m, params, _prompts(), spec_tokens=4)
    assert spec == base
    assert eng.metrics["draft_tokens"] > 0
    assert eng.metrics["verify_steps"] > 0


def test_greedy_bitexact_wrong_drafts(f32_model):
    """Adversarially wrong drafts: every verify rolls back (zero
    accepted) and the stream is still bit-identical — correctness never
    depends on the proposer."""
    m, params = f32_model
    (base, base2), _ = _serve(m, params, _prompts())
    spec, eng = _serve(m, params, _prompts(), spec_tokens=3,
                       draft_proposer=_WrongProposer(base))
    assert spec[0] == base and spec[1] == base2
    assert eng.metrics["accepted_tokens"] == 0
    # every per-sequence verify (verify_steps counts batched device
    # calls, plans carry the per-sequence rows) rolled back
    seq_verifies = sum(len(p.get("verifies", [])) for p in eng.plan_log)
    assert eng.metrics["spec_rollbacks"] == seq_verifies > 0
    assert eng.metrics["verify_steps"] > 0
    # every verify step still emits its one committed token, so
    # speculation never does WORSE than one token per sequence-step
    assert eng.metrics["steps_per_token"] <= 1.0
    assert eng.pager.audit(repair=False).clean
    assert all(rc == 0 for rc in eng.pager.refcount)


def test_replay_oracle_maximal_acceptance(f32_model):
    """A perfect proposer accepts (nearly) everything: far fewer device
    steps than tokens, identical stream."""
    m, params = f32_model
    (base,), _ = _serve(m, params, _prompts()[:1], max_new=24)
    (spec,), eng = _serve(m, params, _prompts()[:1], max_new=24,
                          spec_tokens=4,
                          draft_proposer=_ReplayProposer(base))
    assert spec == base
    assert eng.metrics["accept_ratio"] > 0.9
    assert eng.metrics["steps_per_token"] < 0.5
    assert eng.metrics["spec_rollbacks"] == 0


def test_max_new_tokens_never_exceeded(f32_model):
    """k is capped by the remaining output budget at planning time and
    the acceptance walk stops at the budget — a verify step can never
    overshoot ``max_new_tokens``."""
    m, params = f32_model
    for max_new in (2, 3, 5):
        (base,), _ = _serve(m, params, _prompts()[:1], max_new=max_new)
        (spec,), _ = _serve(m, params, _prompts()[:1], max_new=max_new,
                            spec_tokens=4,
                            draft_proposer=_ReplayProposer(base))
        assert spec == base
        assert len(spec) == max_new


# ---------------------------------------------------------------------------
# sampled traffic: composition independence
# ---------------------------------------------------------------------------


def test_sampled_stream_composition_independent(f32_model):
    """A seeded sampled request's speculative stream is identical served
    solo or alongside other traffic: per-position keyed draws + the
    verify batch's row independence make acceptance counts a private
    matter."""
    m, params = f32_model
    probe = _prompts()[1]
    other = np.tile(np.asarray([23, 29, 31], np.int32), 5)

    def serve(prompts):
        eng = _engine(m, params, max_slots=4, spec_tokens=3)
        uid = eng.submit(prompts[0], max_new_tokens=12, temperature=1.0,
                         seed=77)
        for p in prompts[1:]:
            eng.submit(p, max_new_tokens=12, temperature=0.0)
        done = {r.uid: r for r in eng.run()}
        assert all(r.error is None for r in done.values())
        return done[uid].output

    solo = serve([probe])
    mixed = serve([probe, other])
    assert solo == mixed
    # and the sampled speculative run is reproducible
    assert serve([probe]) == solo


# ---------------------------------------------------------------------------
# compile stability: one verify executable per pool key
# ---------------------------------------------------------------------------


def test_verify_compile_bound(f32_model):
    """Draft lengths churn step to step (the n-gram proposer returns
    0..k tokens, pool pressure shrinks drafts), yet the verify entry
    compiles ONCE: every call is padded to (max_slots, spec_tokens+1)
    with per-row lengths as traced data."""
    m, params = f32_model
    eng = _engine(m, params, spec_tokens=4)
    c0 = eng.verify_compile_count()
    uids = [eng.submit(p, max_new_tokens=16, temperature=0.0)
            for p in _prompts()]
    done = {r.uid: r for r in eng.run()}
    assert all(done[u].error is None for u in uids)
    c1 = eng.verify_compile_count()
    # the probe is process-global (one entry per pool key across the
    # test session) — the bound here is the DELTA: this pool key costs
    # at most one executable, and further churn compiles nothing
    assert c1 - c0 <= 1
    assert eng.metrics["verify_steps"] > 1
    lens = {ln for plan in eng.plan_log
            for (_, _, ln) in plan.get("verifies", [])}
    assert len(lens) >= 1          # the traffic really mixed draft sizes
    for p in _prompts():           # second wave: same key, zero compiles
        eng.submit(p, max_new_tokens=8, temperature=0.0)
    assert all(r.error is None for r in eng.run())
    assert eng.verify_compile_count() == c1
    assert eng.metrics["verify_compiles"] == c1


# ---------------------------------------------------------------------------
# rollback-as-truncation at the allocator
# ---------------------------------------------------------------------------


def _acfg(**kw):
    base = dict(n_layers=2, n_kv_heads=2, head_dim=8, block_size=4,
                n_blocks=16, max_slots=3, max_blocks_per_seq=4)
    base.update(kw)
    return PagedConfig(**base)


class TestTruncate:
    def test_truncate_shrinks_lease(self):
        a = BlockAllocator(_acfg())
        a.ensure(0, 15)                       # 4 blocks
        assert a.truncate(0, 9) == 1          # 9 tokens -> 3 blocks
        assert len(a.owned[0]) == 3
        assert a.truncate(0, 9) == 0          # idempotent at the boundary
        assert a.truncate(0, 0) == 3
        assert a.owned[0] == [] and a.n_free() == a.cfg.n_blocks

    def test_truncate_unregisters_exclusive_blocks(self):
        """A dropped block this slot holds exclusively must leave the
        prefix index — parking it on the LRU would let the index serve
        rejected (speculative) KV."""
        a = BlockAllocator(_acfg())
        a.ensure(0, 8)
        toks = np.arange(8, dtype=np.int32)
        h0 = chain_hash(None, toks[:4])
        h1 = chain_hash(h0, toks[4:])
        a.register_block(0, 0, h0, toks[:4])
        a.register_block(0, 1, h1, toks[4:])
        dropped_bid = a.owned[0][1]
        assert a.block_hash[dropped_bid] is not None
        a.truncate(0, 4)
        assert a.block_hash[dropped_bid] is None     # unregistered
        assert dropped_bid in a.free                 # plain free, not LRU
        assert a.audit(repair=False).clean

    def test_truncate_shared_block_derefs_only(self):
        """A dropped block with another leaseholder predates the
        speculation (fork/prefix sharing): it must stay registered and
        intact for its other holders — truncate only drops this slot's
        lease."""
        a = BlockAllocator(_acfg())
        a.ensure(0, 8)
        toks = np.arange(8, dtype=np.int32)
        h0 = chain_hash(None, toks[:4])
        h1 = chain_hash(h0, toks[4:])
        a.register_block(0, 0, h0, toks[:4])
        a.register_block(0, 1, h1, toks[4:])
        a.fork(0, 1)                          # slot 1 shares both blocks
        shared = a.owned[0][1]
        assert a.refcount[shared] == 2
        a.truncate(0, 4)
        assert a.refcount[shared] == 1        # deref'd, not freed
        assert a.block_hash[shared] is not None
        assert a.owned[1][1] == shared        # other holder unaffected
        assert a.audit(repair=False).clean

    def test_append_cost_multi_row(self):
        a = BlockAllocator(_acfg())
        a.ensure(0, 6)                        # 2 blocks, 2 spare rows
        assert a.append_cost(0, 6, 1) == 0    # fits the partial tail
        assert a.append_cost(0, 6, 2) == 0
        assert a.append_cost(0, 6, 3) == 1    # opens one block
        assert a.append_cost(0, 6, 7) == 2


# ---------------------------------------------------------------------------
# proposers
# ---------------------------------------------------------------------------


class TestProposers:
    def test_ngram_finds_repetition(self):
        p = NgramProposer(max_n=3)
        prompt = np.asarray([1, 2, 3, 9, 8, 1, 2, 3], np.int32)
        # suffix [1, 2, 3] occurred at position 0; continuation [9, 8]
        assert p.propose(prompt, [], 2) == [9, 8]

    def test_ngram_uses_output_tail(self):
        p = NgramProposer(max_n=2)
        prompt = np.asarray([5, 6, 7], np.int32)
        # context [5,6,7,5,6]: suffix [5,6] matches at 0 and the
        # continuation [7,5,6] follows it
        assert p.propose(prompt, [5, 6], 3) == [7, 5, 6]
        assert p.propose(prompt, [5, 6], 1) == [7]

    def test_ngram_no_match_is_empty(self):
        p = NgramProposer()
        assert p.propose(np.asarray([1, 2, 3, 4], np.int32), [], 4) == []
        assert p.propose(np.asarray([1, 2], np.int32), [], 0) == []

    def test_ngram_satisfies_protocol(self):
        assert isinstance(NgramProposer(), DraftProposer)
        assert isinstance(_ReplayProposer([1]), DraftProposer)

    def test_build_proposer(self):
        assert isinstance(build_proposer("ngram"), NgramProposer)
        with pytest.raises(ValueError):
            build_proposer("nonsense")

    def test_draft_model_proposer(self, f32_model):
        """The small-model draft path proposes k greedy continuations
        behind the same interface (and they verify bit-exactly: the
        draft model here IS the target, so acceptance is maximal)."""
        m, params = f32_model
        prop = DraftModelProposer(m, params, max_seq=64)
        prompt = _prompts()[0]
        drafts = prop.propose(prompt, [], 3)
        assert len(drafts) == 3
        assert all(isinstance(t, int) for t in drafts)
        # self-draft == greedy continuation of the target model
        (base,), _ = _serve(m, params, [prompt], max_new=8)
        (spec,), eng = _serve(m, params, [prompt], max_new=8,
                              spec_tokens=3, draft_proposer=prop)
        assert spec == base
        assert eng.metrics["accept_ratio"] > 0.9


# ---------------------------------------------------------------------------
# engine wiring
# ---------------------------------------------------------------------------


def test_spec_requires_paged_pool(f32_model):
    m, params = f32_model
    with pytest.raises(ValueError, match="paged"):
        Engine(m, params, max_slots=2, max_seq=64, cache_kind="dense",
               spec_tokens=4)


def test_spec_off_plans_no_verifies(f32_model):
    m, params = f32_model
    _, eng = _serve(m, params, _prompts()[:1])
    assert all(not plan.get("verifies") for plan in eng.plan_log)
    assert eng.metrics["verify_steps"] == 0
    assert eng.metrics["steps_per_token"] == 1.0


def test_prefix_cache_attribution_per_request(f32_model):
    """metrics["requests"] records each uid's cached_tokens / cache_hit
    (first admission wins) — the per-request slice of the aggregate
    prefix stats."""
    m, params = f32_model
    eng = _engine(m, params, max_seq=96, page_size=8,
                  prefill_chunk_tokens=32)
    prompt = np.tile(np.asarray([3, 5, 7, 9], np.int32), 6)   # 24 tokens
    u_cold = eng.submit(prompt, max_new_tokens=4, temperature=0.0)
    assert all(r.error is None for r in eng.run())
    u_warm = eng.submit(prompt, max_new_tokens=4, temperature=0.0)
    assert all(r.error is None for r in eng.run())
    reqs = eng.metrics["requests"]
    assert reqs[u_cold] == {"cached_tokens": 0, "cache_hit": False}
    assert reqs[u_warm]["cache_hit"] is True
    assert reqs[u_warm]["cached_tokens"] >= eng.page_size


def test_energy_accounting_accumulates(f32_model):
    """The roofline energy model charges every device call — decode,
    chunk and verify paths all accumulate joules, and speculation with
    a good oracle lowers joules per token (fewer weight streams)."""
    m, params = f32_model
    (base,), eng0 = _serve(m, params, _prompts()[:1], max_new=16)
    assert eng0.metrics["energy_joules"] > 0
    (spec,), eng1 = _serve(m, params, _prompts()[:1], max_new=16,
                           spec_tokens=4,
                           draft_proposer=_ReplayProposer(base))
    assert spec == base
    assert 0 < eng1.metrics["energy_joules"] < eng0.metrics["energy_joules"]
