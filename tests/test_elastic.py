"""End-to-end elastic recovery: train -> node failure -> shrink the data
axis -> resume from checkpoint -> keep training.  This container has one
real device, so the "hosts" are simulated rows of the data axis; the
mechanism under test (plan + checkpoint reshard + resumed convergence) is
exactly what the launcher runs per-host on a cluster."""

import jax
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import get_config, reduced
from repro.configs.base import ShapeCell
from repro.data.pipeline import DataConfig, SyntheticTinyStories
from repro.launch import steps as steplib
from repro.models import build_model
from repro.optim import adamw
from repro.runtime.health import HeartbeatMonitor, plan_elastic


def test_elastic_shrink_and_resume(tmp_path):
    cfg = reduced(get_config("llama2-110m"))
    model = build_model(cfg)
    ocfg = adamw.AdamWConfig(lr_peak=5e-4, warmup_steps=5, decay_steps=60)

    # phase 1: "8 hosts" (global batch 8), train 10 steps, checkpoint
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw.init_state(params)}
    step8 = jax.jit(steplib.make_train_step(model, ocfg))
    data = SyntheticTinyStories(DataConfig(vocab_size=cfg.vocab_size,
                                           seq_len=64, batch_size=8))
    it = data.batches()
    for s in range(10):
        state, m = step8(state, next(it))
    loss_before = float(m["loss"])
    store.save(tmp_path, 10, state, extra={"data_state": data.state()})

    # phase 2: host 5 dies -> heartbeat detects -> elastic plan shrinks
    clock = [0.0]
    hb = HeartbeatMonitor(8, timeout_s=30, clock=lambda: clock[0])
    for h in range(8):
        hb.beat(h, 10)
    clock[0] = 60.0
    for h in range(8):
        if h != 5:
            hb.beat(h, 11)
    dead = hb.dead_hosts()
    assert dead == {5}
    plan = plan_elastic(n_pods=1, hosts_per_pod=8, model_hosts=1, dead=dead)
    assert plan is not None and plan.new_data_size == 4   # 8 -> 4 (divisor)

    # phase 3: resume with the shrunk batch (4 rows), same checkpoint
    restored, step, extra = store.restore(tmp_path, state)
    data2 = SyntheticTinyStories(DataConfig(vocab_size=cfg.vocab_size,
                                            seq_len=64, batch_size=4))
    data2.restore({**extra["data_state"],
                   "buf": extra["data_state"]["buf"]})
    step4 = jax.jit(steplib.make_train_step(model, ocfg))
    st2 = jax.tree_util.tree_map(jax.numpy.asarray, restored)
    losses = []
    it2 = data2.batches()
    for s in range(10):
        st2, m2 = step4(st2, next(it2))
        losses.append(float(m2["loss"]))
    # training continues sanely after the shrink
    assert all(np.isfinite(l) for l in losses)
    assert np.mean(losses[-3:]) < loss_before + 0.3
