"""Continuous-arrival async serving: the pipelined step split
(``step_async``/``finish_step``), the streaming front-end
(serving/async_serving.py), and the open-loop driver.

The load-bearing claims pinned here:

  * mid-flight ``submit()`` — including from inside the overlap window
    while the device step is in flight — produces streams bit-identical
    to the closed ``run()`` path for the same arrival order, with zero
    leaked blocks and no new prefill executables;
  * streaming delivers every token exactly once, in order, per sibling,
    at any ``stream_interval_steps``, via callbacks and the generator;
  * latency accounting measures from TRUE arrival time and excludes
    requests that never produced a first token (the
    ``t_first_token == 0.0`` default would otherwise contribute a huge
    negative sample — the serve.py TTFT bugfix's regression test);
  * deadlines are charged from true arrival, so a request that queued
    too long expires even if it was released to the engine "just now".
"""

import jax
import numpy as np
import pytest

from repro.serving.async_serving import (AsyncServer, first_token_latencies,
                                         latency_summary_ms,
                                         negative_latency_samples,
                                         poisson_arrivals, run_open_loop,
                                         time_per_output_token)
from repro.serving.engine import Engine
from repro.serving.faults import ERR_DEADLINE, ERR_SHED, SimClock


@pytest.fixture(scope="module")
def model_params():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m")).with_(compute_dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _mk_engine(model, params, **kw):
    kw.setdefault("max_slots", 3)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 4)
    kw.setdefault("n_pages", 32)
    kw.setdefault("prefill_chunk_tokens", 8)
    return Engine(model, params, **kw)


def _prompts(seed, n, lo=4, hi=12):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, 500, size=int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


def _streams(req):
    outs = req.outputs if req.outputs is not None else [req.output or []]
    return tuple(tuple(o) for o in outs)


class TestPipelinedStep:
    def test_overlap_submit_bitexact_no_leaks_no_recompiles(
            self, model_params):
        """Requests submitted inside the dispatch→sync overlap window
        serve bit-identically to the same arrival order submitted up
        front and drained closed-loop."""
        model, params = model_params
        prompts = _prompts(3, 5)
        kws = [dict(max_new_tokens=4 + i % 3, seed=50 + i,
                    temperature=0.0 if i % 2 else 1.0)
               for i in range(len(prompts))]

        eng1 = _mk_engine(model, params)
        for p, kw in zip(prompts, kws):
            eng1.submit(p, **kw)
        ref = {r.uid: _streams(r) for r in eng1.run()}
        compiles_after_closed = eng1.prefill_compile_count()

        eng2 = _mk_engine(model, params)
        for p, kw in zip(prompts[:2], kws[:2]):
            eng2.submit(p, **kw)
        done, nxt = [], 2
        while eng2.scheduler.has_work() or eng2._pending is not None:
            out, pending = eng2.step_async()
            if out:
                done.extend(out)
            if nxt < len(prompts):
                # the device step (if any) is in flight right now
                eng2.submit(prompts[nxt], **kws[nxt])
                nxt += 1
            done.extend(eng2.finish_step(pending))
        assert nxt == len(prompts)
        got = {r.uid: _streams(r) for r in done}
        assert got == ref, "mid-flight submission changed a stream"
        assert all(rc == 0 for rc in eng2.pager.refcount)
        # continuous arrivals reuse the same pool-key executable: the
        # closed pass already compiled it, the open pass adds none
        assert eng2.prefill_compile_count() == compiles_after_closed

    def test_step_guard_and_finish_idempotence(self, model_params):
        model, params = model_params
        eng = _mk_engine(model, params)
        assert eng.finish_step() == []            # nothing pending: no-op
        eng.submit(_prompts(4, 1)[0], max_new_tokens=4, seed=1)
        pending = None
        while eng.scheduler.has_work():
            out, pending = eng.step_async()
            if pending is not None:
                break
        if pending is not None:
            with pytest.raises(RuntimeError, match="finish_step"):
                eng.step()
            eng.finish_step(pending)
        eng.run()

    def test_rejected_drains_through_step(self, model_params):
        model, params = model_params
        eng = _mk_engine(model, params)
        uid = eng.submit(np.zeros(0, np.int32), max_new_tokens=4)
        out = eng.step()
        assert [r.uid for r in out] == [uid]
        assert out[0].error is not None
        assert eng.step() is None                 # idle now


class TestStreaming:
    @pytest.mark.parametrize("interval", [1, 3])
    def test_callback_every_token_once_in_order(self, model_params,
                                                interval):
        model, params = model_params
        eng = _mk_engine(model, params)
        server = AsyncServer(eng, stream_interval_steps=interval)
        got = {}
        flags = {}

        def on_token(handle, sibling, tokens, done):
            got.setdefault(handle.uid, {}).setdefault(sibling,
                                                      []).extend(tokens)
            if done:
                flags[handle.uid] = True

        handles = [server.submit(p, on_token=on_token, max_new_tokens=5,
                                 seed=60 + i)
                   for i, p in enumerate(_prompts(5, 3))]
        while server.has_work():
            server.step()
        for h in handles:
            assert h.done and flags.get(h.uid)
            streams = _streams(h.req)
            for s, stream in enumerate(streams):
                assert tuple(got[h.uid].get(s, [])) == stream, \
                    "callback deltas must reassemble the exact stream"

    def test_fanout_siblings_stream_separately(self, model_params):
        model, params = model_params
        eng = _mk_engine(model, params)
        server = AsyncServer(eng)
        h = server.submit(_prompts(6, 1)[0], max_new_tokens=4,
                          n_samples=2, seed=7, temperature=1.0)
        while server.has_work():
            server.step()
        assert h.req.outputs is not None and len(h.req.outputs) == 2
        by_sib = {}
        for s, t in h.buffer:
            by_sib.setdefault(s, []).append(t)
        for s, stream in enumerate(_streams(h.req)):
            assert tuple(by_sib.get(s, [])) == stream

    def test_generator_surface(self, model_params):
        """The generator pumps the engine itself; other requests on the
        same pump complete too."""
        model, params = model_params
        eng = _mk_engine(model, params)
        server = AsyncServer(eng)
        prompts = _prompts(7, 2)
        h0 = server.submit(prompts[0], max_new_tokens=5, seed=70)
        h1 = server.submit(prompts[1], max_new_tokens=3, seed=71)
        toks = [t for _, t in server.stream(h0)]
        assert tuple(toks) == _streams(h0.req)[0]
        while server.has_work():
            server.step()
        assert h1.done and h1.req.error is None


class TestLatencyAccounting:
    def test_ttft_filter_excludes_requests_without_first_token(
            self, model_params):
        """Regression for the serve.py TTFT bug: an errored/rejected
        request keeps ``t_first_token == 0.0``; with a nonzero clock its
        unfiltered 'latency' is hugely negative and corrupts every
        percentile.  The shared helpers must exclude it."""
        model, params = model_params
        clock = SimClock(start=5.0)               # t_enqueue >= 5s
        eng = _mk_engine(model, params, clock=clock)
        server = AsyncServer(eng)
        valid = [server.submit(p, max_new_tokens=4, seed=80 + i)
                 for i, p in enumerate(_prompts(8, 3))]
        invalid = server.submit(np.zeros(0, np.int32), max_new_tokens=4)
        while server.has_work():
            server.step()
        reqs = [h.req for h in valid + [invalid]]
        assert invalid.req.error is not None
        assert invalid.req.t_first_token == 0.0
        # the buggy unfiltered expression really would corrupt things:
        raw = [r.t_first_token - r.t_enqueue for r in reqs]
        assert min(raw) < -1.0
        lat = first_token_latencies(reqs)
        assert len(lat) == len(valid)
        assert np.all(lat >= 0.0)
        assert negative_latency_samples(reqs) == 0
        summ = latency_summary_ms(lat)
        assert all(v >= 0.0 for v in summ.values())
        assert np.all(time_per_output_token(reqs) >= 0.0)

    def test_deadline_charged_from_true_arrival(self, model_params):
        """A request that queued past its deadline BEFORE release
        expires immediately: the watchdog clock starts at true arrival
        (t_enqueue), not at batch/release time."""
        model, params = model_params
        clock = SimClock(start=10.0)
        eng = _mk_engine(model, params, clock=clock)
        server = AsyncServer(eng)
        stale = server.submit(_prompts(9, 1)[0], max_new_tokens=4,
                              t_arrival=0.0, deadline_ms=1_000.0)
        fresh = server.submit(_prompts(10, 1)[0], max_new_tokens=4,
                              seed=90, deadline_ms=60_000.0)
        while server.has_work():
            server.step()
        assert stale.req.error_kind == ERR_DEADLINE
        assert fresh.req.error is None

    def test_backpressure_shed_bounds_queue(self, model_params):
        model, params = model_params
        eng = _mk_engine(model, params)
        server = AsyncServer(eng, max_queue_depth=2)
        handles = [server.submit(p, max_new_tokens=3, seed=95 + i)
                   for i, p in enumerate(_prompts(11, 6))]
        shed = [h for h in handles if h.error_kind == ERR_SHED]
        assert shed, "burst past the queue bound must shed"
        assert all(h.done for h in shed)
        while server.has_work():
            server.step()
        served = [h for h in handles if h not in shed]
        assert all(h.req.error is None for h in served)
        assert eng.metrics["shed_requests"] >= len(shed)


class TestOpenLoopDriver:
    def test_open_loop_bitexact_vs_closed_and_sane_report(
            self, model_params):
        """The acceptance bar: Poisson arrivals served open-loop stream
        bit-identically to the closed-batch run of the same arrival
        order, and the report's latency fields are sane (measured from
        true arrival, no negative samples, nonzero goodput)."""
        model, params = model_params
        prompts = _prompts(12, 6)
        kws = [dict(max_new_tokens=4, seed=100 + i,
                    temperature=0.0 if i % 2 else 1.0)
               for i in range(len(prompts))]

        eng1 = _mk_engine(model, params)
        for p, kw in zip(prompts, kws):
            eng1.submit(p, **kw)
        ref = [_streams(r) for r in
               sorted(eng1.run(), key=lambda r: r.uid)]

        # wall clock on purpose: a fast Poisson burst lands arrivals
        # while earlier requests are mid-flight, and the streams must
        # be bit-identical REGARDLESS of real release timing — that
        # independence is the claim under test
        eng2 = _mk_engine(model, params)
        arrivals = poisson_arrivals(seed=12, n=len(prompts), rate_per_s=200.0)
        workload = [(float(t), p, kw)
                    for t, p, kw in zip(arrivals, prompts, kws)]
        handles, report = run_open_loop(eng2, workload)
        got = [_streams(h.req) for h in handles]
        assert got == ref, "open-loop stream diverged from closed-loop"
        assert report.completed_ok == len(prompts)
        assert report.failed == 0
        assert report.neg_latency_samples == 0
        assert report.goodput_tok_s > 0.0
        assert report.ttft_ms["p50"] >= 0.0
        assert report.ttft_ms["p99"] >= report.ttft_ms["p50"]
        assert all(rc == 0 for rc in eng2.pager.refcount)
