"""Core quantization: unit + hypothesis property tests (paper §3.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (DEFAULT_GROUP_SIZE, PAPER_POLICY, QuantPolicy,
                        QuantizedTensor, choose_group_size, count_bytes,
                        dequantize, qmatmul_ref, quantize, quantize_params,
                        quantize_q4_0, quantize_q8_0)
from repro.core.qlinear import _qdot_dequant, _qdot_integer, qdot


class TestQ8Basics:
    def test_roundtrip_error_bound(self):
        """|x - dq(q(x))| <= scale/2 = absmax/254 per group (half step)."""
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 256))
        t = quantize_q8_0(x)
        err = jnp.abs(t.dequantize() - x)
        xg = x.reshape(16, -1, 64)
        bound = jnp.max(jnp.abs(xg), -1, keepdims=True) / 127.0 / 2.0 + 1e-7
        assert bool(jnp.all(err.reshape(16, -1, 64) <= bound))

    def test_paper_formula(self):
        """q = round(127 * w / ||w||_inf) exactly (paper eq. in §3.2)."""
        w = np.array([[0.5, -1.0, 0.25, 0.125] * 16], np.float32)
        t = quantize_q8_0(jnp.asarray(w))
        expect = np.round(127.0 * w / np.max(np.abs(w)))
        np.testing.assert_array_equal(np.asarray(t.q)[0], expect[0])

    def test_zero_group(self):
        t = quantize_q8_0(jnp.zeros((2, 128)))
        assert bool(jnp.all(t.q == 0)) and bool(jnp.all(t.scale == 0))
        assert bool(jnp.all(t.dequantize() == 0))

    def test_q4_pack_roundtrip(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
        t = quantize_q4_0(x)
        assert t.q.shape == (8, 64)          # packed 2:1
        err = jnp.max(jnp.abs(t.dequantize() - x))
        assert float(err) < 0.5              # 4-bit: coarse but bounded

    def test_choose_group_size(self):
        assert choose_group_size(256) == 64
        assert choose_group_size(96) == 48
        assert choose_group_size(50280) == 60
        assert choose_group_size(7) == 7

    def test_pytree_flatten(self):
        t = quantize_q8_0(jnp.ones((4, 64)))
        leaves, treedef = jax.tree_util.tree_flatten(t)
        assert len(leaves) == 2
        t2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert t2.group_size == t.group_size and t2.bits == 8


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 8),
    k_groups=st.integers(1, 6),
    gs=st.sampled_from([32, 64, 128]),
    scale_pow=st.integers(-8, 8),
)
def test_property_roundtrip_bounded(rows, k_groups, gs, scale_pow):
    """Quantization error is bounded by half a step at ANY magnitude."""
    k = k_groups * gs
    x = np.asarray(jax.random.normal(
        jax.random.PRNGKey(rows * 131 + k), (rows, k))) * (2.0 ** scale_pow)
    t = quantize(jnp.asarray(x), group_size=gs)
    deq = np.asarray(t.dequantize())
    xg = x.reshape(rows, k_groups, gs)
    step = np.max(np.abs(xg), -1, keepdims=True) / 127.0
    err = np.abs((deq - x).reshape(rows, k_groups, gs))
    assert np.all(err <= step / 2 + 1e-6 * (1 + step))


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 4), n=st.integers(1, 3), kg=st.integers(1, 4),
    bits=st.sampled_from([8, 4]),
)
def test_property_qmatmul_close_to_fp(m, n, kg, bits):
    """Integer matmul approximates the fp32 matmul within quant error:
    |err| <= sum_g (|x|_g-max · step_w + |w|-max · step_x + step·step)·gs."""
    k = kg * 64
    kx = jax.random.PRNGKey(m * 7 + n * 13 + k)
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(jax.random.fold_in(kx, 1), (n * 32, k))
    xq = quantize(x, bits=8)
    wq = quantize(w, bits=bits)
    out = qmatmul_ref(xq, wq)
    exact = xq.dequantize() @ wq.dequantize().T
    np.testing.assert_allclose(np.asarray(out), np.asarray(exact),
                               rtol=2e-4, atol=2e-4)


class TestQdotStrategies:
    def test_integer_vs_dequant_agree(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 256))
        w = quantize(jax.random.normal(jax.random.PRNGKey(1), (96, 256)))
        a = _qdot_integer(x, w)
        # feed the dequant path the SAME quantized activations the integer
        # path sees — then the two must agree to f32 rounding
        xdq = dequantize(quantize(x))
        b = _qdot_dequant(xdq, w)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

    def test_float_weight_passthrough(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 64))
        np.testing.assert_allclose(np.asarray(qdot(x, w)),
                                   np.asarray(x @ w.T), rtol=1e-5,
                                   atol=1e-5)


class TestPolicy:
    def test_norms_stay_float(self):
        """Paper: RMSNorm params are fp32; embeddings/attn/ffn quantize."""
        params = {
            "embed": jnp.ones((512, 64)),
            "blocks": {
                "attn": {"wq": jnp.ones((16, 32, 64))},
                "mlp": {"w1": jnp.ones((256, 64))},
                "norm1": {"gamma": jnp.ones((4096,))},
            },
            "final_norm": {"gamma": jnp.ones((4096,))},
        }
        qp = quantize_params(params, QuantPolicy(min_size=128))
        assert isinstance(qp["embed"], QuantizedTensor)
        assert isinstance(qp["blocks"]["attn"]["wq"], QuantizedTensor)
        assert isinstance(qp["blocks"]["mlp"]["w1"], QuantizedTensor)
        assert not isinstance(qp["blocks"]["norm1"]["gamma"], QuantizedTensor)
        assert not isinstance(qp["final_norm"]["gamma"], QuantizedTensor)

    def test_bytes_shrink_4x(self):
        params = {"mlp": {"w1": jnp.ones((1024, 1024), jnp.float32)}}
        before = count_bytes(params)["total"]
        after = count_bytes(quantize_params(params, PAPER_POLICY))["total"]
        assert after < before / 3.5          # int8 + scales ≈ 3.76x smaller

    def test_q4_packs_8x(self):
        params = {"mlp": {"w1": jnp.ones((1024, 1024), jnp.float32)}}
        after = count_bytes(quantize_params(
            params, QuantPolicy(bits=4)))["total"]
        assert after < 1024 * 1024 * 4 / 6.5
