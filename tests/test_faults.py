"""Fault-isolated serving: deterministic injection, per-request failure
domains, deadlines, allocator self-audit recovery.

The contract under test (serving/faults.py + the engine's fault hooks):

  * **Determinism** — a (traffic, FaultPlan) pair replays bit-identically;
    the injector's log records exactly what fired where, with no wall
    clock anywhere.
  * **Blast radius** — a fault targeted at one request fails only that
    request (its whole sampling group, as a unit) with a typed
    ``.error_kind``; every *other* stream is bit-identical to a
    fault-free run.  Per-row keyed sampling is the lever: a row leaving
    the batch cannot change any survivor's draws.
  * **Retry before isolate** — injected step exceptions fire before the
    (cache-donating) device dispatch, so the engine retries clean up to
    ``retry_limit`` and only then isolates the culprit.
  * **Deadlines** — ``Request.deadline_ms`` / ``ttft_deadline_ms`` are
    enforced by a watchdog against an injectable clock (SimClock), so
    expiry tests don't sleep.
  * **Audit recovery** — injected page-table corruption (refcount /
    free-list / index flavors) is detected by ``BlockAllocator.audit``,
    repaired in place (corrupted blocks quarantined, free list rebuilt),
    and fails exactly the leaseholders; the pool drains with zero leaked
    refcounts.
  * **Degradation** — an idle plan with work pending sheds the
    lowest-value waiter and keeps serving when the fault layer is on,
    and raises the typed :class:`SchedulerStall` (queue snapshot
    attached) when it is off.
"""

import jax
import numpy as np
import pytest

from repro.runtime.health import StragglerDetector
from repro.serving.engine import Engine
from repro.serving.faults import (ERR_AUDIT, ERR_CAPACITY, ERR_DEADLINE,
                                  ERR_FAULT, ERR_INVALID, ERR_NAN, ERR_SHED,
                                  FaultInjector, FaultPlan, SchedulerStall,
                                  SimClock)
from repro.serving.scheduler import StepPlan


@pytest.fixture(scope="module")
def model_params():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m")).with_(compute_dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


PROMPT_SIZES = (6, 11, 9, 14)


def _prompts(seed=0, sizes=PROMPT_SIZES):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, 500, size=n).astype(np.int32) for n in sizes]


def _serve(model, params, prompts=None, deadlines=None, n_samples=None,
           **kw):
    """Submit ``prompts`` (seeded sampling, uid i+1 gets seed 100+i) and
    drain; returns (engine, {uid: request})."""
    prompts = _prompts() if prompts is None else prompts
    eng = Engine(model, params, max_slots=4, max_seq=64, page_size=8,
                 prefill_chunk_tokens=16, **kw)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=8, temperature=1.0, seed=100 + i,
                   deadline_ms=(deadlines or {}).get(i + 1),
                   n_samples=(n_samples or {}).get(i + 1, 1))
    done = eng.run()
    return eng, {r.uid: r for r in done}


@pytest.fixture(scope="module")
def baseline(model_params):
    """Fault-free streams every isolation test compares survivors to."""
    model, params = model_params
    eng, by = _serve(model, params)
    assert all(r.error is None for r in by.values())
    return {u: r.output for u, r in by.items()}


# ---------------------------------------------------------------------------
# determinism + the fault-free bit-exactness gate
# ---------------------------------------------------------------------------


def test_fault_layer_enabled_but_empty_is_bitexact(model_params, baseline):
    """CI gate (c): engine with injector + SimClock + per-step audit but
    an EMPTY plan must emit bit-identical streams to no fault layer."""
    model, params = model_params
    eng, by = _serve(model, params, faults=FaultPlan(), clock=SimClock(),
                     audit_interval=1)
    assert {u: r.output for u, r in by.items()} == baseline
    assert eng.metrics["requests_failed"] == 0
    assert eng.metrics["audit_repairs"] == 0


def test_identical_plan_replays_identically(model_params):
    model, params = model_params
    plan = lambda: (FaultPlan(seed=7)                      # noqa: E731
                    .step_exception(step=2, times=1)
                    .nan_logits(step=5, uid=3)
                    .corrupt_pages(step=6, uid=1))
    runs = []
    for _ in range(2):
        eng, by = _serve(model, params, faults=FaultInjector(plan()),
                         clock=SimClock(), audit_interval=1)
        runs.append(({u: (r.output, r.error, r.error_kind)
                      for u, r in by.items()}, eng.faults.log,
                     eng.fault_log))
    assert runs[0] == runs[1], "same (traffic, plan) must replay exactly"


# ---------------------------------------------------------------------------
# step exceptions: transient retry, persistent isolation
# ---------------------------------------------------------------------------


def test_transient_step_fault_retries_and_stays_bitexact(model_params,
                                                         baseline):
    model, params = model_params
    p = FaultPlan().step_exception(step=2, times=1)
    eng, by = _serve(model, params, faults=p, clock=SimClock())
    assert eng.metrics["step_retries"] == 1
    assert eng.metrics["requests_failed"] == 0
    assert {u: r.output for u, r in by.items()} == baseline


def test_persistent_fault_isolates_only_its_request(model_params, baseline):
    model, params = model_params
    p = FaultPlan().step_exception(step=3, uid=2, times=10**6)
    eng, by = _serve(model, params, faults=p, clock=SimClock())
    assert by[2].error is not None and by[2].error_kind == ERR_FAULT
    # retried retry_limit times, then isolated — and once uid 2 left the
    # batch the still-armed fault went quiet
    assert eng.metrics["step_retries"] == eng.retry_limit + 1
    survivors = {u: r.output for u, r in by.items() if u != 2}
    assert survivors == {u: o for u, o in baseline.items() if u != 2}
    assert all(r.error is None for u, r in by.items() if u != 2)
    eng.pager.debug_check()
    assert all(rc == 0 for rc in eng.pager.refcount)


def test_untargeted_persistent_fault_propagates(model_params):
    """No uid to isolate = simulated total device loss: after the retry
    budget the InjectedFault escapes run() instead of spinning."""
    from repro.serving.faults import InjectedFault
    model, params = model_params
    p = FaultPlan().step_exception(step=2, times=10**6)
    eng = Engine(model, params, max_slots=4, max_seq=64, page_size=8,
                 prefill_chunk_tokens=16, faults=p, clock=SimClock())
    eng.submit(_prompts()[0], max_new_tokens=8, temperature=0.0)
    with pytest.raises(InjectedFault):
        eng.run()


# ---------------------------------------------------------------------------
# NaN guard: request (and sampling group) fails as a unit, KV quarantined
# ---------------------------------------------------------------------------


def test_nan_row_fails_only_that_request(model_params, baseline):
    model, params = model_params
    p = FaultPlan().nan_logits(step=4, uid=3)
    eng, by = _serve(model, params, faults=p, clock=SimClock())
    assert by[3].error_kind == ERR_NAN and "logits" in by[3].error
    assert eng.metrics["nan_rows"] == 1
    assert {u: r.output for u, r in by.items() if u != 3} \
        == {u: o for u, o in baseline.items() if u != 3}
    eng.pager.debug_check()
    assert eng.pager.n_free() == eng.pager.cfg.n_blocks


def test_nan_during_decode_fails_sampling_group_as_unit(model_params):
    model, params = model_params
    prompts = _prompts(sizes=(9, 11))
    eng, by = _serve(model, params, prompts=prompts,
                     n_samples={1: 3},
                     faults=FaultPlan().nan_logits(step=5, uid=1),
                     clock=SimClock())
    assert by[1].error_kind == ERR_NAN, (by[1].error, by[1].error_kind)
    assert by[2].error is None and by[2].output
    # the whole group is gone: no sibling still holds a lease
    eng.pager.debug_check()
    assert all(rc == 0 for rc in eng.pager.refcount)


def test_nan_quarantine_keeps_poisoned_blocks_out_of_prefix_cache(
        model_params):
    """A NaN-failed sequence's self-written blocks must NOT park on the
    LRU: resubmitting the same prompt may not hit the poisoned KV."""
    model, params = model_params
    prompt = _prompts(sizes=(24,))[0]      # 3 full blocks at page_size 8
    p = FaultPlan().nan_logits(step=4, uid=1)
    eng = Engine(model, params, max_slots=4, max_seq=64, page_size=8,
                 prefill_chunk_tokens=16, faults=p, clock=SimClock())
    eng.submit(prompt, max_new_tokens=8, temperature=0.0)
    (r,) = eng.run()
    assert r.error_kind == ERR_NAN
    # resubmit the identical prompt: admission must find NO cached prefix
    hits0 = eng.scheduler.prefix_stats["hits"]
    eng.submit(prompt, max_new_tokens=4, temperature=0.0)
    (r2,) = eng.run()
    assert r2.error is None
    assert eng.scheduler.prefix_stats["hits"] == hits0, \
        "poisoned KV blocks survived into the prefix index"


# ---------------------------------------------------------------------------
# deadlines (simulated clock — no sleeping)
# ---------------------------------------------------------------------------


def test_deadline_expiry_fails_only_late_request(model_params, baseline):
    model, params = model_params
    p = FaultPlan().advance_clock(step=5, ms=500.0)
    eng, by = _serve(model, params, faults=p, clock=SimClock(),
                     deadlines={2: 100.0, 1: 10_000.0, 3: 10_000.0,
                                4: 10_000.0})
    assert by[2].error_kind == ERR_DEADLINE and "deadline" in by[2].error
    assert eng.metrics["deadline_misses"] == 1
    assert {u: r.output for u, r in by.items() if u != 2} \
        == {u: o for u, o in baseline.items() if u != 2}
    eng.pager.debug_check()


def test_ttft_deadline(model_params):
    """A request still waiting for its first token past its TTFT budget
    fails even though its total budget is fine."""
    model, params = model_params
    clk = SimClock()
    p = FaultPlan().advance_clock(step=1, ms=50.0)
    eng = Engine(model, params, max_slots=2, max_seq=64, page_size=8,
                 prefill_chunk_tokens=8, faults=p, clock=clk)
    pr = _prompts(sizes=(6, 30))
    u1 = eng.submit(pr[0], max_new_tokens=4, temperature=0.0)
    # 30-token prompt at 8-token chunks: several steps to first token,
    # but time already jumped 50ms at step 1 -> TTFT budget of 10ms blows
    u2 = eng.submit(pr[1], max_new_tokens=4, temperature=0.0,
                    ttft_deadline_ms=10.0, deadline_ms=10_000.0)
    by = {r.uid: r for r in eng.run()}
    assert by[u2].error_kind == ERR_DEADLINE and "ttft" in by[u2].error
    assert by[u1].error is None


def test_deadline_racing_same_step_preemption(model_params):
    """Interleaving: the clock fault expires a request in the same step
    the scheduler preempts it (deadline watchdog runs after schedule()).
    The watchdog must win cleanly: the seq is torn out of waiting, its
    retracted plan leaves no dangling work, nothing leaks."""
    model, params = model_params
    # tiny pool: two long-decode requests fight over blocks, so
    # preemptions fire constantly; give the newer request (the usual
    # victim) a deadline that expires mid-run
    probe = Engine(model, params, max_slots=2, max_seq=64, page_size=4,
                   n_pages=6, prefill_chunk_tokens=8)
    pr = _prompts(sizes=(10, 10), seed=3)
    for p_ in pr:
        probe.submit(p_, max_new_tokens=10, temperature=0.0)
    probe.run()
    pre_steps = [i + 1 for i, e in enumerate(probe.plan_log)
                 if e["preempted"]]
    assert pre_steps, "pool must be tight enough to preempt"
    step = pre_steps[0]

    clk = SimClock()
    p = FaultPlan().advance_clock(step=step, ms=1000.0)
    eng = Engine(model, params, max_slots=2, max_seq=64, page_size=4,
                 n_pages=6, prefill_chunk_tokens=8, faults=p, clock=clk)
    uids = [eng.submit(p_, max_new_tokens=10, temperature=0.0,
                       deadline_ms=500.0) for p_ in pr]
    by = {r.uid: r for r in eng.run()}
    assert sorted(by) == sorted(uids), "requests lost or duplicated"
    assert all(r.error_kind == ERR_DEADLINE for r in by.values())
    eng.pager.debug_check()
    assert all(rc == 0 for rc in eng.pager.refcount)
    assert eng.pager.n_free() == eng.pager.cfg.n_blocks


# ---------------------------------------------------------------------------
# allocator audit: detect, quarantine, repair, bounded blast radius
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("flavor", ["refcount", "free_dup"])
def test_audit_recovers_corruption_failing_only_leaseholder(
        model_params, baseline, flavor):
    model, params = model_params
    p = FaultPlan().corrupt_pages(step=3, uid=1, flavor=flavor)
    eng, by = _serve(model, params, faults=p, clock=SimClock(),
                     audit_interval=1)
    assert by[1].error_kind == ERR_AUDIT
    assert eng.metrics["audit_repairs"] == 1
    assert {u: r.output for u, r in by.items() if u != 1} \
        == {u: o for u, o in baseline.items() if u != 1}
    eng.pager.debug_check()              # audit() clean again
    assert all(rc == 0 for rc in eng.pager.refcount)
    assert eng.pager.n_free() == eng.pager.cfg.n_blocks


def test_index_corruption_repairs_without_failing_anyone(model_params,
                                                         baseline):
    """A repointed prefix-index entry corrupts no leased content — the
    audit drops the stale entry (and the orphaned registration) and
    nobody's request fails."""
    model, params = model_params
    p = FaultPlan().corrupt_pages(step=4, flavor="index")
    eng, by = _serve(model, params, faults=p, clock=SimClock(),
                     audit_interval=1)
    assert {u: r.output for u, r in by.items()} == baseline
    assert eng.metrics["requests_failed"] == 0
    assert eng.metrics["audit_repairs"] == 1
    eng.pager.debug_check()


def test_audit_detects_without_repair_and_repairs_on_demand(model_params):
    """Direct allocator-level check: audit(repair=False) reports without
    mutating; audit(repair=True) rebuilds to a clean pool."""
    from repro.serving.paged_cache import BlockAllocator, PagedConfig
    a = BlockAllocator(PagedConfig(n_layers=1, n_kv_heads=1, head_dim=4,
                                   block_size=4, n_blocks=8, max_slots=2,
                                   max_blocks_per_seq=4))
    a.ensure(0, 8)                        # slot 0 leases 2 blocks
    bid = a.owned[0][-1]
    a.refcount[bid] += 1                  # corrupt: refcount != leases
    a.free.append(a.owned[0][0])          # corrupt: leased block on free
    rep = a.audit(repair=False)
    assert not rep.clean and not rep.repaired
    assert set(rep.corrupted_blocks) == set(a.owned[0])
    assert rep.victim_slots == [0]
    rep2 = a.audit(repair=True)
    assert rep2.repaired
    # leaseholder teardown is the caller's job; after it the pool is whole
    a.release(0)
    a.debug_check()
    assert a.n_free() == a.cfg.n_blocks


# ---------------------------------------------------------------------------
# stall handling: typed error off, shed + continue on
# ---------------------------------------------------------------------------


def test_injected_stall_sheds_newest_waiter_and_continues(model_params,
                                                          baseline):
    model, params = model_params
    p = FaultPlan().stall(step=1, times=2)
    eng, by = _serve(model, params, faults=p, clock=SimClock())
    shed = sorted(u for u, r in by.items() if r.error_kind == ERR_SHED)
    # nothing admitted before step 1, so both stall steps shed the
    # newest zero-progress waiters: uids 4 then 3
    assert shed == [3, 4]
    assert eng.metrics["shed_requests"] == 2
    assert eng.metrics["stalls"] == 2
    assert {u: r.output for u, r in by.items() if u not in shed} \
        == {u: o for u, o in baseline.items() if u not in shed}


def test_scheduler_stall_raises_typed_error_with_snapshot(model_params):
    """Without the fault layer a broken scheduler contract raises
    SchedulerStall carrying the queue snapshot (not a bare
    RuntimeError)."""
    model, params = model_params
    eng = Engine(model, params, max_slots=2, max_seq=64, page_size=8)
    eng.submit(_prompts()[0], max_new_tokens=4, temperature=0.0)
    # wedge the scheduler: make schedule() return idle plans
    eng.scheduler.schedule = lambda: StepPlan()
    with pytest.raises(SchedulerStall) as exc:
        eng.run()
    assert isinstance(exc.value, RuntimeError)    # typed subclass
    assert exc.value.snapshot["waiting"] == [1]
    assert "no progress" in str(exc.value)


def test_stall_with_nothing_to_shed_raises_after_bounded_retries(
        model_params):
    model, params = model_params
    eng = Engine(model, params, max_slots=2, max_seq=64, page_size=8,
                 faults=FaultPlan(), clock=SimClock(), stall_shed_limit=2)
    eng.submit(_prompts()[0], max_new_tokens=4, temperature=0.0)
    eng.scheduler.schedule = lambda: StepPlan()
    # shedding would drain the queue and end the loop cleanly; forbid it
    # too, so the stall is a genuine wedge
    eng.scheduler.shed_load = lambda k=1: []
    with pytest.raises(SchedulerStall):
        eng.run()
    assert eng.metrics["stalls"] == eng.stall_shed_limit + 1


# ---------------------------------------------------------------------------
# submit-time validation (satellite)
# ---------------------------------------------------------------------------


def test_submit_time_validation_sets_error_immediately(model_params):
    model, params = model_params
    eng = Engine(model, params, max_slots=2, max_seq=16, page_size=8,
                 n_pages=1)
    ok = _prompts(sizes=(6,))[0]
    u_empty = eng.submit(np.zeros((0,), np.int32), max_new_tokens=4)
    u_mnt = eng.submit(ok, max_new_tokens=16)
    u_ns = eng.submit(ok, max_new_tokens=4, n_samples=0)
    u_wide = eng.submit(ok, max_new_tokens=4, n_samples=3)
    u_big = eng.submit(_prompts(sizes=(12,))[0], max_new_tokens=2)
    # errors are set at submit, before any run()
    reqs = {r.uid: r for r in eng._rejected}
    assert reqs[u_empty].error == "empty prompt"
    assert "max_new_tokens" in reqs[u_mnt].error
    assert "n_samples" in reqs[u_ns].error
    assert "max_slots" in reqs[u_wide].error
    assert "blocks" in reqs[u_big].error
    assert all(r.error_kind in (ERR_INVALID, ERR_CAPACITY)
               for r in reqs.values())
    # and they come back exactly once from run(), which never scheduled
    done = eng.run()
    assert sorted(r.uid for r in done) == sorted(reqs)
    assert eng.plan_log == []
    assert eng.metrics["requests_rejected"] == 5

    dense = Engine(model, params, max_slots=4, max_seq=64,
                   cache_kind="dense")
    dense.submit(ok, max_new_tokens=4, n_samples=2)
    (r,) = dense.run()
    assert "paged" in r.error and r.error_kind == ERR_INVALID


# ---------------------------------------------------------------------------
# straggler wiring (satellite)
# ---------------------------------------------------------------------------


def test_single_host_record_slow():
    det = StragglerDetector(n_hosts=1, window=8, threshold=2.0)
    assert not any(det.record_slow(0, 0.1) for _ in range(6))
    assert det.record_slow(0, 0.5)        # 5x the rolling median
    assert not det.record_slow(0, 0.1)    # back to normal


def test_slow_steps_metric_counts_latency_faults(model_params):
    """Injected decode latency (clock jump inside the timing window)
    shows up as Engine.metrics['slow_steps'] via the StragglerDetector.
    A steady 10 ms baseline warms the rolling median (the detector needs
    window/2 = 8 samples), then one 200 ms spike flags."""
    model, params = model_params
    p = (FaultPlan()
         .advance_clock(step=1, ms=10.0, site="decode", times=10**6)
         .advance_clock(step=20, ms=200.0, site="decode", times=1))
    eng = Engine(model, params, max_slots=2, max_seq=64, page_size=8,
                 prefill_chunk_tokens=16, faults=p, clock=SimClock(),
                 eos_id=-1)     # never stop early: the spike step must run
    eng.submit(_prompts(sizes=(6,))[0], max_new_tokens=24,
               temperature=1.0, seed=100)
    (r,) = eng.run()
    assert r.error is None
    assert eng.metrics["slow_steps"] >= 1
    assert eng.metrics["deadline_misses"] == 0


# ---------------------------------------------------------------------------
# fault x feature interleavings (satellite)
# ---------------------------------------------------------------------------


def test_prefill_fault_during_chunked_prefill_of_warm_group(model_params):
    """Step-failure during the chunked prefill of a sampling group whose
    prompt prefix is cache-warm: the group dies as a unit pre-fanout,
    the warm blocks stay cached, and an identical resubmission still
    gets its prefix hit and completes."""
    model, params = model_params
    prompt = _prompts(sizes=(28,), seed=5)[0]    # 3 full blocks + tail
    eng = Engine(model, params, max_slots=4, max_seq=64, page_size=8,
                 prefill_chunk_tokens=8,
                 faults=FaultPlan().step_exception(step=2, uid=2,
                                                   site="prefill",
                                                   times=10**6),
                 clock=SimClock())
    # warm the prefix with a singleton...
    eng.submit(prompt, max_new_tokens=2, temperature=0.0)
    done = eng.run()
    assert done[0].error is None
    # ...then a group over the same prompt: admission maps the cached
    # prefix, and its remaining prefill chunk hits the persistent fault
    eng.submit(prompt, max_new_tokens=4, temperature=1.0, seed=9,
               n_samples=3)
    (r,) = eng.run()
    assert r.error_kind == ERR_FAULT
    assert eng.plan_log[-1]["cached"] or \
        any(e["cached"] for e in eng.plan_log), "prefix must be warm"
    eng.pager.debug_check()
    assert all(rc == 0 for rc in eng.pager.refcount)
    # cached prefix blocks survived the failure (they predate it)
    eng2_hits = eng.scheduler.prefix_stats["hits"]
    eng.submit(prompt, max_new_tokens=2, temperature=0.0)
    (r3,) = eng.run()
    assert r3.error is None
    assert eng.scheduler.prefix_stats["hits"] == eng2_hits + 1


def test_thrash_shedding_bounds_preemption_storms(model_params):
    """shed_after_preempts: consecutive preempting steps shed the
    newest zero-progress waiter instead of thrashing forever."""
    model, params = model_params
    eng = Engine(model, params, max_slots=2, max_seq=64, page_size=4,
                 n_pages=6, prefill_chunk_tokens=8,
                 faults=FaultPlan(), clock=SimClock(),
                 shed_after_preempts=2)
    for p_ in _prompts(sizes=(10, 10, 10), seed=3):
        eng.submit(p_, max_new_tokens=12, temperature=0.0)
    by = {r.uid: r for r in eng.run()}
    assert len(by) == 3
    finished = [u for u, r in by.items() if r.error is None]
    assert finished, "someone must finish"
    shed = [u for u, r in by.items() if r.error_kind == ERR_SHED]
    if shed:      # pool pressure is traffic-dependent; leak-freedom isn't
        assert eng.metrics["shed_requests"] == len(shed)
    eng.pager.debug_check()
    assert all(rc == 0 for rc in eng.pager.refcount)
