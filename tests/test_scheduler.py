"""Scheduler subsystem: chunked prefill numerics, step-plan structure,
preemption over an oversubscribed pool, and admission clamping.

Covers the PR-2 acceptance criteria:
  * ``prefill_chunk`` composed over 2+ chunks matches the one-shot
    ``prefill`` (single chunk: bit-identical; multi-chunk: last-ulp
    reduction-order tolerance with bit-identical first-layer KV rows and
    identical greedy streams) for f32 and int8 pools,
  * a prompt longer than ``prefill_chunk_tokens`` is admitted in chunks
    while decode steps for running slots continue between chunks
    (asserted via step-plan inspection),
  * shrinking ``n_pages`` below the full reservation no longer raises
    ``OutOfBlocks`` — preempted requests finish with outputs identical
    to an uncontended run under greedy sampling,
  * the seed engine's truncation bug (``max_new_tokens >= max_seq``
    silently flipping the prompt slice positive) now rejects with
    ``.error``, and the no-progress spin-loop is gone (defer / preempt /
    reject, never idle).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.paged_cache import BlockAllocator, PagedConfig
from repro.serving.scheduler import Scheduler, Sequence


def _f32_model():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m")).with_(compute_dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _int8_model():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("llama2-110m")).with_(
        compute_dtype="float32", kv_cache_dtype="int8")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _chunked_prefill(m, params, prompt, splits, bs=8, n_blocks=16, slot=1):
    """Run prefill_chunk over the given chunk sizes; returns (logits,
    pool cache, page table row blocks)."""
    mb = 8
    alloc = BlockAllocator(PagedConfig(
        n_layers=m.cfg.n_layers, n_kv_heads=m.cfg.n_kv_heads,
        head_dim=m.cfg.hd(), block_size=bs, n_blocks=n_blocks,
        max_slots=2, max_blocks_per_seq=mb))
    cache = m.init_paged_cache(2, block_size=bs, n_blocks=n_blocks,
                               max_blocks_per_seq=mb)
    off, logits = 0, None
    for c in splits:
        end = min(off + c, len(prompt))
        if end <= off:
            break
        alloc.ensure(slot, end)
        cache = dict(cache)
        cache["page_table"] = jnp.asarray(alloc.page_table())
        logits, cache = m.prefill_chunk(
            params, jnp.asarray(prompt[off:end]), cache, slot, off)
        off = end
    blocks = [b for b in np.asarray(cache["page_table"][slot]) if b >= 0]
    return logits, cache, blocks


def _slot_rows(cache, blocks, plen, key="k"):
    pool = np.asarray(cache["attn"][key])
    nl, _, bs = pool.shape[:3]
    return pool[:, blocks].reshape(nl, len(blocks) * bs,
                                   *pool.shape[3:])[:, :plen]


# ---------------------------------------------------------------------------
# chunked prefill vs one-shot
# ---------------------------------------------------------------------------


def test_single_chunk_bit_exact_vs_oneshot_f32():
    m, params = _f32_model()
    rng = np.random.default_rng(0)
    plen = 21
    prompt = rng.integers(4, 500, size=plen).astype(np.int32)
    l_one, pcache = m.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                              max_seq=plen)
    l_chunk, cache, blocks = _chunked_prefill(m, params, prompt, [plen])
    assert bool(jnp.all(l_one == l_chunk)), \
        "whole-prompt chunk must be bit-identical to one-shot prefill"
    for kk in ("k", "v"):
        np.testing.assert_array_equal(
            _slot_rows(cache, blocks, plen, kk),
            np.asarray(pcache["attn"][kk])[:, 0])


@pytest.mark.parametrize("splits", [[8, 5, 8], [16, 5], [1, 20], [7, 7, 7]])
def test_multi_chunk_matches_oneshot_f32(splits):
    """Composed chunks reduce over the same key sets; a later chunk's
    queries reduce its prefix and chunk segments separately and merge
    them by softmax renormalization (the shape-stable form), so stored
    KV rows and final logits agree to last-ulp reassociation tolerance
    with the same argmax (a whole-prompt single chunk stays *bitwise* —
    see test_single_chunk_bit_exact_vs_oneshot_f32)."""
    m, params = _f32_model()
    rng = np.random.default_rng(1)
    plen = 21
    prompt = rng.integers(4, 500, size=plen).astype(np.int32)
    l_one, pcache = m.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                              max_seq=plen)
    l_chunk, cache, blocks = _chunked_prefill(m, params, prompt, splits)
    for kk in ("k", "v"):
        np.testing.assert_allclose(
            _slot_rows(cache, blocks, plen, kk),
            np.asarray(pcache["attn"][kk])[:, 0], rtol=1e-5, atol=5e-6)
    np.testing.assert_allclose(np.asarray(l_chunk), np.asarray(l_one),
                               rtol=1e-5, atol=1e-5)
    assert int(jnp.argmax(l_chunk)) == int(jnp.argmax(l_one))


def test_multi_chunk_int8_pool_stores_matching_codes():
    """Quantized pools: row-wise Q8_0 is deterministic, so chunked and
    one-shot prefill agree on every stored code up to the +-1 step that a
    last-ulp projection difference can tip over a rounding boundary;
    cross-chunk attention reads the requantized prefix, so logits carry
    the usual int8 tolerance."""
    m, params = _int8_model()
    rng = np.random.default_rng(2)
    plen = 19
    prompt = rng.integers(4, 500, size=plen).astype(np.int32)
    l_one, pcache = m.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                              max_seq=plen)
    l_chunk, cache, blocks = _chunked_prefill(m, params, prompt, [8, 6, 8])
    # layer 0 sees no attention upstream, so its rows isolate the Q8_0
    # round trip; deeper layers also carry the requantized-prefix
    # attention and are covered by the logits tolerance below.
    for kk in ("k", "v"):
        got = _slot_rows(cache, blocks, plen, kk)[0].astype(np.int32)
        want = np.asarray(pcache["attn"][kk])[0, 0].astype(np.int32)
        assert np.abs(got - want).max() <= 1
    for kk in ("ks", "vs"):
        np.testing.assert_allclose(
            _slot_rows(cache, blocks, plen, kk)[0],
            np.asarray(pcache["attn"][kk])[0, 0], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(l_chunk), np.asarray(l_one),
                               rtol=1e-2, atol=1e-2)


def test_prefill_chunk_requires_allocated_blocks():
    m, params = _f32_model()
    cache = m.init_paged_cache(2, block_size=8, n_blocks=4,
                               max_blocks_per_seq=4)
    with pytest.raises(ValueError, match="page table"):
        m.prefill_chunk(params, jnp.zeros((4,), jnp.int32), cache, 0, 0)


def test_flash_prefill_kernel_q_offset_matches_oracle():
    """The Pallas kernel's chunked form (S_k > S_q, shifted diagonal)
    matches the jnp oracle's q_offset path."""
    from repro.kernels import ops
    from repro.models.layers import AttnConfig, attention_scores_blockwise
    b, sq, sk, h, kvh, d = 1, 128, 384, 4, 2, 64
    off = sk - sq
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (b, sq, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, sk, kvh, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, sk, kvh, d))
    want = attention_scores_blockwise(
        q * d ** -0.5, k, v, AttnConfig(h, kvh, d, q_chunk=64), q_offset=off)
    out = ops.flash_prefill(q, k, v, causal=True, q_offset=off,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# engine: chunked admission interleaves with decode (step-plan inspection)
# ---------------------------------------------------------------------------


def _engine(m, params, **kw):
    from repro.serving.engine import Engine
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 8)
    return Engine(m, params, **kw)


def test_long_prompt_chunks_interleave_with_decode():
    m, params = _f32_model()
    rng = np.random.default_rng(3)
    short = rng.integers(4, 500, size=5).astype(np.int32)
    long = rng.integers(4, 500, size=30).astype(np.int32)

    eng = _engine(m, params, prefill_chunk_tokens=8)
    u_short = eng.submit(short, max_new_tokens=10, temperature=0.0)
    u_long = eng.submit(long, max_new_tokens=5, temperature=0.0)
    done = eng.run()
    assert sorted(r.uid for r in done) == [u_short, u_long]
    assert all(r.error is None for r in done)

    long_chunks = [(u, s, e) for plan in eng.plan_log
                   for (u, s, e) in plan["prefills"] if u == u_long]
    assert len(long_chunks) >= 2, "30-token prompt must take >= 2 chunks"
    assert [s for (_, s, _) in long_chunks] == \
        sorted(s for (_, s, _) in long_chunks)
    assert long_chunks[-1][2] == 30
    # the tentpole property: some step carries a prompt chunk AND decodes
    mixed = [p for p in eng.plan_log if p["prefills"] and p["decodes"]]
    assert mixed, "chunked prefill must interleave with running decodes"
    assert any(u_short in p["decodes"] for p in mixed)

    # chunking must not change greedy outputs vs unchunked admission
    eng2 = _engine(m, params, prefill_chunk_tokens=512)
    eng2.submit(short, max_new_tokens=10, temperature=0.0)
    eng2.submit(long, max_new_tokens=5, temperature=0.0)
    done2 = eng2.run()
    assert [r.output for r in sorted(done, key=lambda r: r.uid)] == \
        [r.output for r in sorted(done2, key=lambda r: r.uid)]


# ---------------------------------------------------------------------------
# engine: preemption on an oversubscribed pool
# ---------------------------------------------------------------------------


def test_oversubscribed_pool_preempts_and_completes():
    """With n_pages far below the two sequences' peak demand, mid-decode
    growth preempts (never raises OutOfBlocks) and every request still
    finishes with outputs identical to an uncontended run."""
    m, params = _f32_model()
    rng = np.random.default_rng(4)
    prompts = [rng.integers(4, 500, size=12).astype(np.int32)
               for _ in range(2)]

    def serve(n_pages):
        eng = _engine(m, params, n_pages=n_pages)
        for p in prompts:
            eng.submit(p, max_new_tokens=20, temperature=0.0)
        done = sorted(eng.run(), key=lambda r: r.uid)
        assert all(r.error is None for r in done)
        return [r.output for r in done], eng

    # peak demand: 2 x ceil(31 / 8) = 8 blocks; give the pool 6
    contended, eng = serve(n_pages=6)
    assert eng.metrics["preemptions"] > 0
    assert all(len(o) == 20 for o in contended)
    uncontended, eng2 = serve(n_pages=None)
    assert eng2.metrics["preemptions"] == 0
    assert contended == uncontended
    assert eng.cache_utilization() == 0.0


def test_preempted_mid_decode_resumes_without_resampling():
    """The resume prefill covers prompt + output[:-1] and must not emit a
    duplicate token: output lengths stay exactly max_new_tokens.  A
    resume admission is either cold (first chunk at position 0) or — now
    that released registered blocks park on the allocator's LRU — a
    cached-prefix remap recorded in the plan's ``cached`` entries."""
    m, params = _f32_model()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(4, 500, size=9).astype(np.int32)
               for _ in range(3)]
    eng = _engine(m, params, n_pages=5)
    for p in prompts:
        eng.submit(p, max_new_tokens=12, temperature=0.0)
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert eng.metrics["preemptions"] > 0
    assert [len(r.output) for r in done] == [12, 12, 12]
    preempted = [u for plan in eng.plan_log for u in plan["preempted"]]
    assert preempted
    for u in set(preempted):
        cold = [(s, e) for plan in eng.plan_log
                for (uu, s, e) in plan["prefills"] if uu == u and s == 0]
        cached = [cl for plan in eng.plan_log
                  for (uu, cl) in plan["cached"] if uu == u]
        # one admission per (preemption + 1): each is cold or a remap
        assert len(cold) + len(cached) == preempted.count(u) + 1, \
            (u, cold, cached)


# ---------------------------------------------------------------------------
# admission clamping + no-spin (seed-engine bug fixes)
# ---------------------------------------------------------------------------


def test_max_new_tokens_ge_max_seq_rejected_with_error():
    """Seed bug: prompt[-max_seq + max_new:] flipped into a positive
    slice keeping almost nothing; now it is an explicit rejection."""
    m, params = _f32_model()
    eng = _engine(m, params, max_seq=16)
    rng = np.random.default_rng(6)
    eng.submit(rng.integers(4, 500, size=8).astype(np.int32),
               max_new_tokens=16, temperature=0.0)
    done = eng.run()
    assert len(done) == 1 and done[0].error is not None
    assert "max_new_tokens" in done[0].error
    assert done[0].output == []


def test_long_prompt_clamped_to_window_and_completes():
    m, params = _f32_model()
    eng = _engine(m, params, max_seq=16)
    rng = np.random.default_rng(7)
    eng.submit(rng.integers(4, 500, size=40).astype(np.int32),
               max_new_tokens=6, temperature=0.0)
    done = eng.run()
    assert done[0].error is None and len(done[0].output) == 6


def test_empty_prompt_rejected():
    m, params = _f32_model()
    eng = _engine(m, params)
    eng.submit(np.zeros((0,), np.int32), max_new_tokens=4)
    done = eng.run()
    assert done[0].error == "empty prompt"


def test_never_fits_prompt_rejected_without_spinning():
    """Seed bug: a deferred head with idle slots spun for max_steps; the
    scheduler now rejects never-fits work immediately."""
    m, params = _f32_model()
    eng = _engine(m, params, n_pages=1)
    rng = np.random.default_rng(8)
    eng.submit(rng.integers(4, 500, size=20).astype(np.int32),
               max_new_tokens=4, temperature=0.0)
    done = eng.run(max_steps=50)
    assert len(done) == 1 and "blocks" in done[0].error
    assert len(eng.plan_log) <= 2, "rejection must not burn idle steps"


# ---------------------------------------------------------------------------
# scheduler unit tests (no model)
# ---------------------------------------------------------------------------


def _pager(n_blocks, bs=4, slots=3, mb=16):
    return BlockAllocator(PagedConfig(
        n_layers=1, n_kv_heads=1, head_dim=8, block_size=bs,
        n_blocks=n_blocks, max_slots=slots, max_blocks_per_seq=mb))


def _req(uid, plen, max_new=8):
    from repro.serving.engine import Request
    return Request(uid=uid, prompt=np.arange(plen, dtype=np.int32),
                   max_new_tokens=max_new, output=[])


def test_budget_splits_admission_into_chunks():
    sched = Scheduler(1, 64, _pager(16), prefill_chunk_tokens=4)
    sched.add(_req(1, 10))
    spans = []
    for _ in range(3):
        plan = sched.schedule()
        spans += [(c.start, c.end) for c in plan.prefills]
        assert not plan.decodes or spans[-1][1] == 10
    assert spans == [(0, 4), (4, 8), (8, 10)]
    assert sched.schedule().decodes == [0]


def test_admission_defers_while_pool_exhausted():
    pager = _pager(4, bs=4, slots=2)
    sched = Scheduler(2, 64, pager, prefill_chunk_tokens=64)
    sched.add(_req(1, 12, max_new=2))          # 3 blocks now, 4 at peak
    plan = sched.schedule()
    assert [(c.start, c.end) for c in plan.prefills] == [(0, 12)]
    sched.running[0].req.output.append(5)
    sched.add(_req(2, 8))
    plan = sched.schedule()                    # uid1 growth takes the last
    assert plan.decodes == [0]                 # block; uid2 must defer,
    assert not plan.prefills                   # not preempt a decode
    assert sched.waiting[0].req.uid == 2


def test_decode_growth_preempts_newest_victim():
    pager = _pager(4, bs=4, slots=2)
    sched = Scheduler(2, 64, pager, prefill_chunk_tokens=64)
    sched.add(_req(1, 8))
    sched.add(_req(2, 8))
    plan = sched.schedule()                    # both admitted: 4/4 blocks
    assert len(plan.prefills) == 2 and pager.n_free() == 0
    sched.running[0].req.output.append(5)      # engine would have sampled
    sched.running[1].req.output.append(7)
    plan = sched.schedule()
    assert plan.preempted == [2], "newest-admitted sequence is the victim"
    assert plan.decodes == [0]
    # the victim is requeued at the waiting front and — with a block just
    # freed — immediately begins recompute-on-resume from position 0
    resumed = [c for c in plan.prefills if c.seq.req.uid == 2]
    assert resumed and resumed[0].start == 0
    assert resumed[0].seq.resuming             # keeps its sampled token


def test_deadlock_guard_preempts_newest_mid_prefill():
    """Two mid-prefill sequences splitting an exhausted pool (no decodes
    possible) must not produce an idle plan: the newest is evicted so the
    older prefill can proceed."""
    pager = _pager(2, bs=4, slots=2)
    sched = Scheduler(2, 64, pager, prefill_chunk_tokens=8)
    a = Sequence(req=_req(1, 8), prompt=np.arange(8, dtype=np.int32),
                 tokens=np.arange(8, dtype=np.int32), slot=0, prefilled=4,
                 kv_len=4, order=0)
    b = Sequence(req=_req(2, 8), prompt=np.arange(8, dtype=np.int32),
                 tokens=np.arange(8, dtype=np.int32), slot=1, prefilled=4,
                 kv_len=4, order=1)
    pager.ensure(0, 4)
    pager.ensure(1, 4)
    sched.running = {0: a, 1: b}
    sched._order = 2
    plan = sched.schedule()
    assert plan.preempted == [2] and plan.made_progress()
    plan = sched.schedule()                    # freed block: a continues
    assert [(c.start, c.end) for c in plan.prefills] == [(4, 8)]


def test_growth_beyond_whole_pool_fails_with_error():
    pager = _pager(2, bs=4, slots=1)
    sched = Scheduler(1, 64, pager, prefill_chunk_tokens=64)
    sched.add(_req(1, 8, max_new=16))          # 8 + growth > 8-token pool
    plan = sched.schedule()
    assert [(c.start, c.end) for c in plan.prefills] == [(0, 8)]
    sched.running[0].req.output.append(3)
    plan = sched.schedule()
    assert plan.rejected and "pool" in plan.rejected[0].error
    assert not sched.has_work() and pager.n_free() == 2


def test_can_allocate_matches_ensure():
    pager = _pager(2, bs=4, slots=2)
    assert pager.can_allocate(0, 8)
    pager.ensure(0, 8)
    assert pager.can_allocate(0, 8)            # already covered
    assert not pager.can_allocate(1, 4)
    pager.release(0)
    assert pager.can_allocate(1, 8)


# ---------------------------------------------------------------------------
# sampling groups: unit preemption, slot reservation, admission pricing
# ---------------------------------------------------------------------------


def test_group_preempted_mid_decode_resumes_via_prefix_remap():
    """End-to-end: a sampling group squeezed off an oversubscribed pool
    mid-decode is preempted and later resumed — the resume admissions
    remap the still-cached shared prompt blocks (plan ``cached`` entries:
    a hit, not a recompute) and every sibling's stream is bit-identical
    to the uncontended run."""
    m, params = _f32_model()
    rng = np.random.default_rng(9)
    gp = rng.integers(4, 500, size=12).astype(np.int32)
    sp = rng.integers(4, 500, size=10).astype(np.int32)

    def serve(n_pages):
        eng = _engine(m, params, max_slots=6, n_pages=n_pages)
        us = eng.submit(sp, max_new_tokens=10, temperature=0.0)
        ug = eng.submit(gp, max_new_tokens=10, temperature=1.0, seed=3,
                        n_samples=3)
        done = {r.uid: r for r in eng.run()}
        assert all(r.error is None for r in done.values())
        return done[ug].outputs, done[us].output, ug, eng

    free_out, free_s, _, eng_free = serve(None)
    assert eng_free.metrics["preemptions"] == 0
    tight_out, tight_s, ug, eng = serve(7)
    assert eng.metrics["preemptions"] > 0, "7/16 blocks must preempt"
    preempt_step = next(i for i, p in enumerate(eng.plan_log)
                        if ug in p["preempted"])
    remaps = [cl for p in eng.plan_log[preempt_step:]
              for (u, cl) in p["cached"] if u == ug]
    assert remaps and all(cl >= 8 for cl in remaps), \
        "resumed siblings must remap the cached prompt block, not recompute"
    assert tight_out == free_out, \
        "preempt/resume must not change any sibling's stream"
    assert tight_s == free_s
    eng.pager.debug_check()
    assert eng.pager.utilization() == 0.0


def _fanned_group(pager, uid, n, plen=6, order=0, slot0=0):
    """Build a running, fanned n-sibling group sharing ``plen`` tokens of
    leased blocks (partial tail when plen % block_size != 0)."""
    from repro.serving.engine import Request
    from repro.serving.scheduler import SamplingGroup
    req = Request(uid=uid, prompt=np.arange(plen, dtype=np.int32),
                  max_new_tokens=20, output=[5], n_samples=n)
    group = SamplingGroup(req=req, n=n, fanned=True)
    pager.ensure(slot0, plen)
    sibs = []
    for i in range(n):
        slot = slot0 + i
        if i:
            pager.fork(slot0, slot)
        sibs.append(Sequence(
            req=req, prompt=req.prompt, tokens=req.prompt, slot=slot,
            prefilled=plen, kv_len=plen, order=order,
            output=req.output if i == 0 else [5 + i],
            group=group, sibling_index=i))
    group.siblings = sibs
    return group, sibs


def test_external_growth_preempts_group_as_unit():
    """An exempt non-group sequence growing into an exhausted pool
    victimizes a fanned group: EVERY sibling is preempted in the same
    step, and the siblings' already-planned decodes AND COW pairs are
    all retracted — the engine never executes work for a half-evicted
    group."""
    pager = _pager(5, bs=4, slots=3, mb=8)
    sched = Scheduler(3, 64, pager, prefill_chunk_tokens=64,
                      preempt_limit=2)
    group, (a, b) = _fanned_group(pager, uid=1, n=2, plen=6)
    grower = Sequence(req=_req(2, 8, max_new=20),
                      prompt=np.arange(8, dtype=np.int32),
                      tokens=np.arange(8, dtype=np.int32), slot=2,
                      prefilled=8, kv_len=8, order=1, n_preemptions=2,
                      output=[9])
    grower.req.output = grower.output
    pager.ensure(2, 8)
    sched.running = {0: a, 1: b, 2: grower}
    sched._order = 2

    plan = sched.schedule()
    # group (order 0) planned first: sibling A COW'd the shared tail
    # (consuming the last free block) and both siblings planned decodes;
    # then the exempt grower's growth found the pool dry and victimized
    # the group — as a unit, with its planned work retracted
    assert plan.preempted == [1, 1], "both siblings evict in one step"
    assert plan.decodes == [2] and plan.decode_uids == [2]
    assert plan.cows == [], "the evicted group's COW must be retracted"
    assert pager.stats["cow_copies"] == 1   # allocator did copy-remap
    # both siblings were requeued at the front (sibling 0 first); with
    # the group's blocks freed, sibling 0 was immediately re-admitted
    # for recompute-on-resume in this same plan and sibling 1 waits
    resumed = [c for c in plan.prefills if c.seq.req.uid == 1]
    assert resumed and resumed[0].seq.sibling_index == 0
    assert resumed[0].seq.resuming
    assert [s.sibling_index for s in sched.waiting] == [1]
    pager.debug_check()


def test_intra_group_contention_sheds_one_sibling():
    """When a sibling's own growth finds the pool dry and the victim is
    a sequence of the SAME group, only that sibling is shed — the grower
    keeps its slot and decodes, so a group can drain itself down to a
    servable width instead of self-evicting forever."""
    pager = _pager(3, bs=4, slots=2, mb=8)
    sched = Scheduler(2, 64, pager, prefill_chunk_tokens=64)
    group, (a, b) = _fanned_group(pager, uid=1, n=2, plen=8)
    sched.running = {0: a, 1: b}
    sched._order = 1

    # sibling 0's growth takes the last free block; sibling 1's growth
    # then finds the pool dry and the victim tie-break lands on itself
    plan = sched.schedule()
    assert plan.preempted == [1], "exactly one sibling shed"
    assert plan.decodes == [0], "the surviving sibling still decodes"
    assert sched.running[0] is a and a.group is group
    assert len(sched.waiting) == 1 and sched.waiting[0] is b
    assert b.resuming, "the shed sibling resumes with its tokens intact"
    pager.debug_check()


def test_group_admission_reserves_sibling_slots():
    """An unfanned group parent counts n slots against admission: a
    follow-up request defers while the group's siblings are reserved,
    instead of stealing a slot the fanout was promised."""
    from repro.serving.engine import Request
    pager = _pager(16, bs=4, slots=3, mb=8)
    sched = Scheduler(3, 64, pager, prefill_chunk_tokens=64)
    g = Request(uid=1, prompt=np.arange(6, dtype=np.int32),
                max_new_tokens=4, output=[], n_samples=3)
    sched.add(g)
    sched.add(_req(2, 6))
    plan = sched.schedule()
    assert [(c.seq.req.uid) for c in plan.prefills] == [1], \
        "the group's 2 reserved sibling slots leave no room for uid 2"
    assert sched.waiting and sched.waiting[0].req.uid == 2
    # fanout consumes the reservation; uid 2 still has to wait
    parent = sched.running[0]
    parent.output = g.output
    sibs = sched.fork_group(parent)
    assert len(sibs) == 3 and len(sched.running) == 3
    for i, s in enumerate(sibs):
        assert s.sibling_index == i and s.kv_len == parent.kv_len
    pager.debug_check()
    plan = sched.schedule()
    assert not plan.prefills and sched.waiting[0].req.uid == 2
    # a finished sibling frees a real slot: uid 2 admits
    sched.finish(sibs[2].slot)
    plan = sched.schedule()
    assert [(c.seq.req.uid) for c in plan.prefills] == [2]


def test_group_admission_rejections():
    """n_samples that can never run fail fast with .error: wider than
    the slot table, n_samples < 1, on the dense fallback (no fork), or a
    prompt + fork_cost that exceeds the whole pool."""
    from repro.serving.engine import Request

    def group_req(uid, n, plen=6):
        return Request(uid=uid, prompt=np.arange(plen, dtype=np.int32),
                       max_new_tokens=4, output=[], n_samples=n)

    sched = Scheduler(2, 64, _pager(16, slots=2), prefill_chunk_tokens=64)
    sched.add(group_req(1, 3))
    plan = sched.schedule()
    assert plan.rejected and "max_slots" in plan.rejected[0].error

    sched = Scheduler(2, 64, _pager(16, slots=2), prefill_chunk_tokens=64)
    sched.add(group_req(2, 0))
    plan = sched.schedule()
    assert plan.rejected and "n_samples" in plan.rejected[0].error

    dense = Scheduler(4, 64, None, prefill_chunk_tokens=64)
    dense.add(group_req(3, 2))
    plan = dense.schedule()
    assert plan.rejected and "paged" in plan.rejected[0].error

    # 6-token prompt = 2 blocks, + 1 COW for the extra sibling's first
    # divergent token: 3 > the 2-block pool
    tight = Scheduler(2, 64, _pager(2, slots=2, mb=8),
                      prefill_chunk_tokens=64)
    tight.add(group_req(4, 2))
    plan = tight.schedule()
    assert plan.rejected and "blocks" in plan.rejected[0].error
