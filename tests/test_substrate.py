"""Substrate tests: data pipeline, optimizer, checkpointing, runtime."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, SyntheticTinyStories, eval_batches
from repro.optim import adamw
from repro.runtime.health import (HeartbeatMonitor, StragglerDetector,
                                  plan_elastic)


class TestData:
    def test_determinism(self):
        cfg = DataConfig(vocab_size=512, seq_len=64, batch_size=2, seed=7)
        a = next(SyntheticTinyStories(cfg).batches())
        b = next(SyntheticTinyStories(cfg).batches())
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=512, seq_len=64, batch_size=2)
        batch = next(SyntheticTinyStories(cfg).batches())
        np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                      batch["labels"][:, :-1])

    def test_tokens_in_range(self):
        cfg = DataConfig(vocab_size=300, seq_len=128, batch_size=4)
        batch = next(SyntheticTinyStories(cfg).batches())
        assert batch["tokens"].min() >= 0
        assert batch["tokens"].max() < 300

    def test_host_sharding_differs(self):
        c0 = DataConfig(vocab_size=512, seq_len=64, batch_size=2, host_id=0)
        c1 = DataConfig(vocab_size=512, seq_len=64, batch_size=2, host_id=1)
        a = next(SyntheticTinyStories(c0).batches())
        b = next(SyntheticTinyStories(c1).batches())
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_iterator_state_resume(self):
        cfg = DataConfig(vocab_size=512, seq_len=64, batch_size=2)
        ds = SyntheticTinyStories(cfg)
        it = ds.batches()
        next(it)
        st_ = ds.state()
        want = next(it)
        ds2 = SyntheticTinyStories(cfg)
        ds2.restore(st_)
        got = next(ds2.batches())
        np.testing.assert_array_equal(want["tokens"], got["tokens"])

    def test_eval_differs_from_train(self):
        cfg = DataConfig(vocab_size=512, seq_len=64, batch_size=2)
        tr = next(SyntheticTinyStories(cfg).batches())
        ev = eval_batches(cfg, 1)[0]
        assert not np.array_equal(tr["tokens"], ev["tokens"])


class TestAdamW:
    def _setup(self):
        params = {"w": jnp.ones((8, 8)), "b": jnp.zeros((8,))}
        return params, adamw.init_state(params)

    def test_descends_quadratic(self):
        params, opt = self._setup()
        cfg = adamw.AdamWConfig(lr_peak=0.1, warmup_steps=1, decay_steps=100,
                                weight_decay=0.0)
        def loss(p):
            return jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1) ** 2)
        l0 = loss(params)
        for _ in range(50):
            g = jax.grad(loss)(params)
            params, opt, _, _ = adamw.apply_updates(params, opt, g, cfg)
        assert float(loss(params)) < float(l0) * 0.3

    def test_clip_norm(self):
        params, opt = self._setup()
        cfg = adamw.AdamWConfig(clip_norm=1e-3)
        g = jax.tree_util.tree_map(lambda x: x * 1e6, params)
        _, _, metrics, _ = adamw.apply_updates(params, opt, g, cfg)
        assert float(metrics["grad_norm"]) > 1e3   # raw norm reported

    def test_schedule_shape(self):
        cfg = adamw.AdamWConfig(lr_peak=1e-3, lr_min=1e-5, warmup_steps=10,
                                decay_steps=100)
        lrs = [float(adamw.lr_schedule(cfg, jnp.asarray(s)))
               for s in [0, 5, 10, 50, 100, 1000]]
        assert lrs[1] < lrs[2]                      # warmup rising
        assert abs(lrs[2] - 1e-3) < 1e-4            # peak
        assert lrs[3] < lrs[2]                      # decaying
        assert abs(lrs[-1] - 1e-5) < 1e-6           # floor

    @settings(max_examples=15, deadline=None)
    @given(scale=st.floats(1e-4, 1e4), n=st.integers(64, 1024))
    def test_compression_error_feedback_converges(self, scale, n):
        """int8 grad compression with error feedback: the *accumulated*
        quantization error stays bounded (error feedback re-injects it)."""
        g = np.asarray(jax.random.normal(jax.random.PRNGKey(n), (n,))) * scale
        err = jnp.zeros_like(g)
        total_sent = jnp.zeros_like(g)
        for _ in range(8):
            sent, err = adamw.compress_decompress(jnp.asarray(g), err)
            total_sent = total_sent + sent
        # after k rounds of the same gradient, sum(sent) ≈ k*g  (EF property)
        rel = np.linalg.norm(np.asarray(total_sent) / 8 - g) / \
            (np.linalg.norm(g) + 1e-9)
        assert rel < 0.02


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
                 "opt": {"m": {"w": jnp.ones((3, 4))},
                         "step": jnp.asarray(7)}}
        store.save(tmp_path, 7, state, extra={"note": "x"})
        got, step, extra = store.restore(tmp_path, state)
        assert step == 7 and extra["note"] == "x"
        np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                      np.asarray(state["params"]["w"]))

    def test_quantized_leaves_roundtrip(self, tmp_path):
        from repro.core import quantize
        qt = quantize(jnp.arange(256.0).reshape(2, 128))
        store.save(tmp_path, 1, {"w": qt})
        got, _, _ = store.restore(tmp_path, {"w": qt})
        np.testing.assert_array_equal(np.asarray(got["w"].q),
                                      np.asarray(qt.q))
        assert got["w"].group_size == qt.group_size

    def test_latest_and_prune(self, tmp_path):
        s = {"x": jnp.zeros(3)}
        for step in (10, 20, 30, 40):
            store.save(tmp_path, step, s)
        assert store.latest_step(tmp_path) == 40
        store.prune(tmp_path, keep=2)
        assert store.latest_step(tmp_path) == 40
        got, step, _ = store.restore(tmp_path, s)
        assert step == 40

    def test_crash_safe_tmp_dir(self, tmp_path):
        """A leftover .tmp dir from a crashed writer must not corrupt
        restore."""
        s = {"x": jnp.ones(4)}
        store.save(tmp_path, 5, s)
        (tmp_path / ".tmp_step_00000009_0").mkdir()
        assert store.latest_step(tmp_path) == 5

    def test_async_save(self, tmp_path):
        s = {"x": jnp.ones(128)}
        t = store.save(tmp_path, 3, s, async_=True)
        t.join()
        assert store.latest_step(tmp_path) == 3


class TestRuntime:
    def test_heartbeat_detects_dead(self):
        clock = [0.0]
        hb = HeartbeatMonitor(4, timeout_s=10, clock=lambda: clock[0])
        for h in range(4):
            hb.beat(h, step=1)
        clock[0] = 5.0
        hb.beat(0, 2); hb.beat(1, 2); hb.beat(2, 2)
        clock[0] = 14.0
        assert hb.dead_hosts() == {3}

    def test_straggler_detection(self):
        sd = StragglerDetector(4, window=4, threshold=1.5)
        for step in range(8):
            for h in range(4):
                sd.record(h, 1.0 if h != 2 else 3.0)
        assert sd.stragglers() == {2}

    def test_no_straggler_when_uniform(self):
        sd = StragglerDetector(4, window=4)
        for _ in range(8):
            for h in range(4):
                sd.record(h, 1.0)
        assert sd.stragglers() == set()

    def test_elastic_plan_drops_dead_row(self):
        plan = plan_elastic(n_pods=2, hosts_per_pod=4, model_hosts=16,
                            dead={5})
        assert plan.new_pod_size == 2
        assert plan.new_data_size == 2      # 4 -> largest divisor ≤ 3 is 2
        assert 5 not in plan.usable_hosts
        assert len(plan.reassigned_shards) == 4

    def test_elastic_whole_pod_death(self):
        plan = plan_elastic(2, 4, 16, dead={0, 1, 2, 3})
        assert plan.new_pod_size == 1
        assert plan.new_data_size == 4

    def test_elastic_total_loss(self):
        assert plan_elastic(1, 2, 16, dead={0, 1}) is None


class TestServing:
    def test_engine_end_to_end(self):
        from repro.configs import get_config, reduced
        from repro.models import build_model
        from repro.serving.engine import Engine
        cfg = reduced(get_config("llama2-110m"))
        m = build_model(cfg)
        params = m.quantize(m.init(jax.random.PRNGKey(0)))
        eng = Engine(m, params, max_slots=2, max_seq=64)
        rng = np.random.default_rng(0)
        uids = [eng.submit(rng.integers(4, 500, size=8).astype(np.int32),
                           max_new_tokens=6) for _ in range(4)]
        done = eng.run()
        assert len(done) == 4
        assert all(len(r.output) >= 1 for r in done)
        assert eng.metrics["tokens_out"] > 0

    def test_sampling_topp_subset(self):
        from repro.serving.engine import sample_logits
        logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05]]))
        key = jax.random.PRNGKey(0)
        seen = set()
        for i in range(64):
            tok = sample_logits(jax.random.fold_in(key, i), logits,
                                temperature=1.0, top_p=0.6)
            seen.add(int(tok[0]))
        assert seen <= {0, 1}          # 0.5+0.3 >= 0.6 nucleus

    def test_greedy(self):
        from repro.serving.engine import sample_logits
        logits = jnp.asarray([[0.1, 3.0, 0.2]])
        tok = sample_logits(jax.random.PRNGKey(0), logits, temperature=0.0)
        assert int(tok[0]) == 1
