"""Aggregate dry-run JSONs into the §Roofline table (and markdown)."""

from __future__ import annotations

import json
from pathlib import Path


def load(out_dir: str = "results/dryrun"):
    recs = []
    for f in sorted(Path(out_dir).glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def run(quiet: bool = False, out_dir: str = "results/dryrun"):
    rows = []
    for r in load(out_dir):
        if r.get("multi_pod"):
            continue      # roofline table is single-pod per the assignment
        name = f"roofline/{r['arch']}/{r['shape']}"
        rows.append((name, r["roofline_fraction"],
                     f"dom={r['dominant']} tc={r['t_compute_s']:.3g}s "
                     f"tm={r['t_memory_s']:.3g}s tx={r['t_collective_s']:.3g}s "
                     f"useful={r['useful_flop_ratio']:.2f}"))
    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.4f},{r[2]}")
    return rows


def markdown(out_dir: str = "results/dryrun") -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | MODEL/algo FLOPs | roofline frac | peak mem/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(out_dir):
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        mem = r.get("memory_analysis", {})
        peak = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
                + mem.get("output_bytes", 0)) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {r['t_compute_s']:.4g} | {r['t_memory_s']:.4g} "
            f"| {r['t_collective_s']:.4g} | **{r['dominant']}** "
            f"| {r['useful_flop_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {peak:.1f} GB |")
    return "\n".join(lines)
