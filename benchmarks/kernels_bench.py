"""Kernel-path microbenchmarks (Appendix A.2 analog) + decode-path perf.

The paper's Table 7 lists cycle counts per synthesized module
(rmsnorm / quantize / matmul_768_768 / ... / matmul_768_32000).  The CPU
analog times the same pipeline stages through our jnp execution paths
(the Pallas kernels target TPU and only run in interpret mode here, which
is not a timing surface), at the paper's exact shapes.

``run_decode`` tracks the PR-1 decode optimizations and writes machine-
readable JSON (``BENCH_decode.json``) so CI can chart the trajectory:

  * decode-attention at max_seq=2048 for live lens {64, 512, 2048}: the
    full-scan jnp path costs the same regardless of length; the
    length-pruned kernel's executed-tile count scales with the live
    length (the interpret-mode proxy for HBM traffic — wall-clock there
    is not meaningful, tiles fetched is),
  * one quantized decode layer step, fused (4 GEMVs) vs unfused (7).
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import quantize
from repro.core.qlinear import _qdot_dequant, _qdot_integer
from repro.models.layers import rms_norm


def _time(fn, *args, iters=20) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run(quiet: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    x768 = jax.random.normal(key, (1, 768))

    # rmsnorm_768 (paper: 31.3 us on FPGA @250MHz)
    g = jnp.ones((768,))
    f = jax.jit(lambda x: rms_norm(x, g))
    rows.append(("kernelbench/rmsnorm_768", _time(f, x768), "us/call"))

    # quantize_768 (paper: 3.9 us)
    f = jax.jit(lambda x: quantize(x).q)
    rows.append(("kernelbench/quantize_768", _time(f, x768), "us/call"))

    # the paper's three matvec shapes, integer vs dequant strategy
    for n, k in [(768, 768), (2048, 768), (768, 2048), (32000, 768)]:
        w = quantize(jax.random.normal(jax.random.fold_in(key, n + k),
                                       (n, k)))
        xv = jax.random.normal(key, (1, k))
        fi = jax.jit(lambda x, w=w: _qdot_integer(x, w))
        fd = jax.jit(lambda x, w=w: _qdot_dequant(x, w))
        rows.append((f"kernelbench/matmul_{k}_{n}_integer",
                     _time(fi, xv), "us/call"))
        rows.append((f"kernelbench/matmul_{k}_{n}_dequant",
                     _time(fd, xv), "us/call"))

    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


def run_decode(quiet: bool = False, json_path: str = "BENCH_decode.json",
               max_seq: int = 2048, lens=(64, 512, 2048)) -> dict:
    """Decode hot-path benchmarks; returns (and writes) a JSON dict."""
    from repro.configs import get_config, reduced
    from repro.kernels import ops
    from repro.models import build_model
    from repro.models.layers import AttnConfig, attention_decode

    result: dict = {"max_seq": max_seq, "attention": [], "layer_step": {}}
    key = jax.random.PRNGKey(0)
    b, kvh, hq, d = 4, 2, 4, 64
    block_s = 256
    q = jax.random.normal(key, (b, kvh * hq, d)) / np.sqrt(d)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, max_seq, kvh, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, max_seq, kvh, d))
    acfg = AttnConfig(kvh * hq, kvh, d)
    f = jax.jit(lambda q, k, v, l: attention_decode(q, k, v, l, acfg))
    for ln in lens:
        lens_j = jnp.full((b,), ln, jnp.int32)
        t_full = _time(f, q, k, v, lens_j, iters=10)
        _, counts = ops.decode_attention(q, k, v, lens_j, block_s=block_s,
                                         return_tile_counts=True,
                                         interpret=True)
        tiles_live = int(np.asarray(counts)[0, 0])
        result["attention"].append({
            "len": int(ln),
            "full_scan_us": t_full,
            "tiles_total": max_seq // block_s,
            "tiles_fetched_pruned": tiles_live,
            "hbm_traffic_fraction": tiles_live / (max_seq // block_s),
        })

    # fused vs unfused quantized decode layer step (jnp/XLA timing surface)
    cfg = reduced(get_config("llama2-110m"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((4,), jnp.int32)
    for name, fused in (("unfused_7_gemv", False), ("fused_4_gemv", True)):
        qp = model.quantize(params, fuse_decode=fused)
        cache = model.init_cache(4, 256)
        step = jax.jit(model.decode_step)
        t = _time(lambda p, c, t_: step(p, c, t_)[0], qp, cache, toks,
                  iters=10)
        result["layer_step"][name] = t
    result["layer_step"]["speedup"] = (
        result["layer_step"]["unfused_7_gemv"]
        / result["layer_step"]["fused_4_gemv"])

    with open(json_path, "w") as fh:
        json.dump(result, fh, indent=2)
    if not quiet:
        for row in result["attention"]:
            print(f"kernelbench/decode_attn_len{row['len']},"
                  f"{row['full_scan_us']:.1f},us/call"
                  f" (pruned tiles {row['tiles_fetched_pruned']}"
                  f"/{row['tiles_total']})")
        for name in ("unfused_7_gemv", "fused_4_gemv"):
            print(f"kernelbench/decode_step_{name},"
                  f"{result['layer_step'][name]:.1f},us/call")
    return result
