"""Kernel-path microbenchmarks (Appendix A.2 analog).

The paper's Table 7 lists cycle counts per synthesized module
(rmsnorm / quantize / matmul_768_768 / ... / matmul_768_32000).  The CPU
analog times the same pipeline stages through our jnp execution paths
(the Pallas kernels target TPU and only run in interpret mode here, which
is not a timing surface), at the paper's exact shapes.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import quantize
from repro.core.qlinear import _qdot_dequant, _qdot_integer
from repro.models.layers import rms_norm


def _time(fn, *args, iters=20) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run(quiet: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    x768 = jax.random.normal(key, (1, 768))

    # rmsnorm_768 (paper: 31.3 us on FPGA @250MHz)
    g = jnp.ones((768,))
    f = jax.jit(lambda x: rms_norm(x, g))
    rows.append(("kernelbench/rmsnorm_768", _time(f, x768), "us/call"))

    # quantize_768 (paper: 3.9 us)
    f = jax.jit(lambda x: quantize(x).q)
    rows.append(("kernelbench/quantize_768", _time(f, x768), "us/call"))

    # the paper's three matvec shapes, integer vs dequant strategy
    for n, k in [(768, 768), (2048, 768), (768, 2048), (32000, 768)]:
        w = quantize(jax.random.normal(jax.random.fold_in(key, n + k),
                                       (n, k)))
        xv = jax.random.normal(key, (1, k))
        fi = jax.jit(lambda x, w=w: _qdot_integer(x, w))
        fd = jax.jit(lambda x, w=w: _qdot_dequant(x, w))
        rows.append((f"kernelbench/matmul_{k}_{n}_integer",
                     _time(fi, xv), "us/call"))
        rows.append((f"kernelbench/matmul_{k}_{n}_dequant",
                     _time(fd, xv), "us/call"))

    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows
