"""Tables 2–3 analog — inference speed (tok/s) and latency (ms/token).

The paper measures 57.11 tok/s (FPGA int8) vs 23.21 (CPU fp32) vs 107
(GPU fp16) at batch 1.  Absolute numbers on this container's CPU are not
comparable hardware; the *reproduction target* is the RATIO structure:
int8 weight streaming beats fp32 on a memory-bound decode loop.  We
measure single-stream decode at fp32 / Q8_0(dequant) / Q8_0(integer) /
Q4_0 on the paper's own 110M-config (reduced only in vocab to fit time
budgets), plus batched decode (the paper's §5.2 future work).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import QuantPolicy
from repro.core.qlinear import set_default_strategy
from repro.models import build_model, count_params


def _decode_loop(model, params, cfg, batch: int, tokens: int,
                 max_seq: int = 160):
    """Prefill 16, decode ``tokens``; returns (tok/s, ms/token)."""
    prompt = jnp.ones((batch, 16), jnp.int32)
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_seq=max_seq))(
            params, {"tokens": prompt})
    step = jax.jit(model.decode_step, donate_argnums=(1,))
    tok = jnp.argmax(logits, -1)
    # warmup + compile
    logits, cache = step(params, cache, tok)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(tokens):
        logits, cache = step(params, cache, jnp.argmax(logits, -1))
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    return batch * tokens / dt, dt / tokens * 1e3


def run(tokens: int = 32, quiet: bool = False):
    t0 = time.time()
    # the paper's model: 12L/768d/12H — vocab cut to keep CPU time sane
    cfg = get_config("llama2-110m").with_(vocab_size=4096,
                                          compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = count_params(params) / 1e6

    rows = []
    variants = [
        ("fp32", params, "dequant"),
        ("q8_dequant", model.quantize(params, QuantPolicy(min_size=512)),
         "dequant"),
        ("q8_integer", model.quantize(params, QuantPolicy(min_size=512)),
         "integer"),
        ("q4_dequant", model.quantize(params,
                                      QuantPolicy(bits=4, min_size=512)),
         "dequant"),
    ]
    base_toks = None
    for name, p, strat in variants:
        set_default_strategy(strat)
        toks, ms = _decode_loop(model, p, cfg, batch=1, tokens=tokens)
        if base_toks is None:
            base_toks = toks
        rows.append((f"throughput/decode_b1_{name}", ms * 1e3,
                     f"{toks:.1f} tok/s ({toks/base_toks:.2f}x fp32; "
                     f"paper fpga/cpu=2.46x)"))
    set_default_strategy("dequant")

    # batched decode (paper §5.2 future work)
    q8 = variants[1][1]
    for b in (4, 16):
        toks, ms = _decode_loop(model, q8, cfg, batch=b, tokens=tokens)
        rows.append((f"throughput/decode_b{b}_q8", ms * 1e3,
                     f"{toks:.1f} tok/s aggregate"))

    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")
        print(f"# throughput bench ({n:.0f}M params): {time.time()-t0:.0f}s")
    return rows
