"""Table 1 analog — perplexity: quantized vs unquantized (paper §4.1).

The paper: Q8_0 quantization costs 0.04% perplexity on TinyStories-110M,
while a 42M model costs +7.22%.  We reproduce the *claim structure* at
container scale: train a small Llama-2-family model on the synthetic
TinyStories stream, then evaluate held-out perplexity for
  fp32 / Q8_0 / Q4_0 / a half-size fp32 model,
expecting  ppl(Q8) ≈ ppl(fp)  <<  ppl(half-size).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import QuantPolicy
from repro.data.pipeline import DataConfig, SyntheticTinyStories, eval_batches
from repro.models import build_model, count_params
from repro.launch import steps as steplib
from repro.configs.base import ShapeCell
from repro.optim import adamw


def _train(cfg, steps, batch, seq, seed=0):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    ocfg = adamw.AdamWConfig(lr_peak=2e-3, warmup_steps=30,
                             decay_steps=steps)
    state = {"params": params, "opt": adamw.init_state(params)}
    step = jax.jit(steplib.make_train_step(model, ocfg), donate_argnums=(0,))
    ds = SyntheticTinyStories(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, batch_size=batch, seed=seed))
    it = ds.batches()
    for _ in range(steps):
        state, metrics = step(state, next(it))
    return model, state["params"], float(metrics["loss"])


def perplexity(model, params, cfg, batches) -> float:
    total, count = 0.0, 0
    loss_fn = jax.jit(model.loss)
    for b in batches:
        total += float(loss_fn(params, b)) * b["labels"].size
        count += b["labels"].size
    return float(np.exp(total / count))


def run(steps: int = 600, quiet: bool = False):
    t0 = time.time()
    # capacity contrast needs models that actually fit the stream within
    # the CPU budget: small vocab, 600 steps, and a 16x capacity gap
    cfg = reduced(get_config("llama2-110m")).with_(
        d_model=192, n_heads=6, n_kv_heads=6, head_dim=32, d_ff=512,
        n_layers=4, vocab_size=512, compute_dtype="float32")
    half = cfg.with_(d_model=48, n_heads=2, head_dim=24, n_kv_heads=2,
                     d_ff=96, n_layers=1)

    batch, seq = 16, 128
    model, params, _ = _train(cfg, steps, batch, seq)
    model_h, params_h, _ = _train(half, steps, batch, seq)

    ev = eval_batches(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                 batch_size=batch), n_batches=4)

    rows = []
    ppl_fp = perplexity(model, params, cfg, ev)
    q8 = model.quantize(params, QuantPolicy(min_size=512))
    ppl_q8 = perplexity(model, q8, cfg, ev)
    q4 = model.quantize(params, QuantPolicy(bits=4, min_size=512))
    ppl_q4 = perplexity(model, q4, cfg, ev)
    ppl_half = perplexity(model_h, params_h, half, ev)

    n = count_params(params) / 1e6
    nh = count_params(params_h) / 1e6
    rows.append(("quality/ppl_fp32", ppl_fp, f"{n:.1f}M params"))
    rows.append(("quality/ppl_q8_0", ppl_q8,
                 f"delta={100*(ppl_q8/ppl_fp-1):+.3f}% (paper: +0.04%)"))
    rows.append(("quality/ppl_q4_0", ppl_q4,
                 f"delta={100*(ppl_q4/ppl_fp-1):+.3f}% (beyond-paper)"))
    rows.append(("quality/ppl_half_model", ppl_half,
                 f"{nh:.1f}M params, delta={100*(ppl_half/ppl_fp-1):+.2f}% "
                 f"(paper 42M: +7.22%)"))
    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.4f},{r[2]}")
        print(f"# quality bench: {time.time()-t0:.0f}s")
    return rows
