"""Benchmark harness — one function per paper table.

Prints ``name,value,derived`` CSV rows:
  quality.*      Table 1 (perplexity: fp32 vs Q8_0 vs Q4_0 vs half-size)
  throughput.*   Tables 2-3 (tok/s + ms/token per weight format)
  energy.*       Tables 4-6 (modeled mWh/token on TPU v5e)
  kernelbench.*  Appendix A.2 (per-stage timings at the paper's shapes)
  roofline.*     §Roofline terms per (arch x shape) from the dry-run
"""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: quality,throughput,energy,kernels,"
                         "decode,engine,roofline")
    ap.add_argument("--quick", action="store_true",
                    help="smaller step/token budgets")
    args = ap.parse_args()
    which = set(args.only.split(",")) if args.only else {
        "kernels", "energy", "roofline", "throughput", "quality"}

    print("name,value,derived")
    if "kernels" in which:
        from benchmarks import kernels_bench
        kernels_bench.run()
    if "decode" in which or "kernels" in which:
        from benchmarks import kernels_bench
        kernels_bench.run_decode()
    if "engine" in which:
        from benchmarks import engine_bench
        engine_bench.run()
    if "energy" in which:
        from benchmarks import energy
        energy.run()
    if "roofline" in which:
        from benchmarks import roofline_table
        roofline_table.run()
    if "throughput" in which:
        from benchmarks import throughput
        throughput.run(tokens=8 if args.quick else 32)
    if "quality" in which:
        from benchmarks import quality
        quality.run(steps=60 if args.quick else 250)


if __name__ == "__main__":
    main()
