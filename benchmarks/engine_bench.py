"""Engine-level serving benchmark: mixed traffic on an oversubscribed pool.

Where kernels_bench tracks single-kernel decode costs, this scenario
exercises the scheduler subsystem end to end: short and long prompts
submitted together against a paged pool sized at 3/8 of the full
reservation, with a chunk budget far below the longest prompt — so the
run necessarily exhibits chunked prefill interleaved with decodes, block
recycling, and mid-decode preemption with recompute-on-resume.

Writes machine-readable JSON (``BENCH_engine.json``, emitted into the CI
artifacts dir by ci/run_ci.sh) so the trajectory of serving-level
metrics is chartable across PRs:

  * TTFT p50/p99 (ms) — chunked admission exists to keep the p99 of
    short requests bounded while long prompts stream in,
  * decode throughput (tok/s over decode wall-clock),
  * preemption / prefill-chunk / decode-step counts and pool size —
    the work the scheduler did to absorb the oversubscription.

CPU wall-clock here is a smoke-level signal (the kernels are jnp paths,
not the TPU build); the counts are the stable part of the trajectory.
"""

from __future__ import annotations

import json

import numpy as np

PROMPT_LENS = (8, 72, 12, 64, 10, 80, 9, 48, 16, 96)


def run(quiet: bool = False, json_path: str = "BENCH_engine.json",
        max_new_tokens: int = 16) -> dict:
    import jax

    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.serving.engine import Engine

    cfg = reduced(get_config("llama2-110m"))
    model = build_model(cfg)
    params = model.quantize(model.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)

    max_slots, max_seq, page_size = 4, 128, 16
    full_reservation = max_slots * (max_seq // page_size)     # 32 blocks
    # 3/8 of the full reservation: deep enough oversubscription that
    # deferral alone cannot absorb it — mid-decode growth must preempt.
    n_pages = full_reservation * 3 // 8
    eng = Engine(model, params, max_slots=max_slots, max_seq=max_seq,
                 page_size=page_size, n_pages=n_pages,
                 prefill_chunk_tokens=32)
    for n in PROMPT_LENS:
        eng.submit(rng.integers(4, 500, size=n).astype(np.int32),
                   max_new_tokens=max_new_tokens, temperature=0.0)
    done = eng.run()
    ok = [r for r in done if r.error is None]
    assert len(ok) == len(PROMPT_LENS), \
        [r.error for r in done if r.error is not None]
    ttft_ms = np.array([(r.t_first_token - r.t_enqueue) for r in ok]) * 1e3

    result = {
        "requests": len(done),
        "prompt_lens": list(PROMPT_LENS),
        "max_new_tokens": max_new_tokens,
        "n_pages": n_pages,
        "full_reservation_pages": full_reservation,
        "prefill_chunk_tokens": 32,
        "ttft_ms_p50": float(np.percentile(ttft_ms, 50)),
        "ttft_ms_p99": float(np.percentile(ttft_ms, 99)),
        "decode_tok_s": eng.throughput_tok_s(),
        "tokens_out": eng.metrics["tokens_out"],
        "decode_steps": eng.metrics["decode_steps"],
        "prefill_chunks": eng.metrics["prefill_chunks"],
        "preemptions": eng.metrics["preemptions"],
    }
    with open(json_path, "w") as fh:
        json.dump(result, fh, indent=2)
    if not quiet:
        print(f"enginebench/ttft_ms_p50,{result['ttft_ms_p50']:.1f},ms")
        print(f"enginebench/ttft_ms_p99,{result['ttft_ms_p99']:.1f},ms")
        print(f"enginebench/decode_tok_s,{result['decode_tok_s']:.1f},tok/s")
        print(f"enginebench/preemptions,{result['preemptions']},count"
              f" (pool {n_pages}/{full_reservation} blocks,"
              f" {result['prefill_chunks']} chunks)")
    return result


if __name__ == "__main__":
    run()
