"""Engine-level serving benchmark: mixed traffic on an oversubscribed
pool + a shared-prefix workload over the prefix cache.

Where kernels_bench tracks single-kernel decode costs, these scenarios
exercise the scheduler subsystem end to end:

  * **mixed** — short and long prompts submitted together against a
    paged pool sized at 3/8 of the full reservation, with a chunk budget
    far below the longest prompt: chunked prefill interleaved with
    decodes, block recycling, mid-decode preemption with
    recompute-on-resume,
  * **shared_prefix** — N requests over M distinct system prompts served
    twice, prefix caching on vs off: reports the hit rate, prefill
    tokens/blocks saved, and the TTFT deltas the cache buys (CI fails if
    the hit rate silently drops to zero — see ci/run_ci.sh),
  * **parallel_sampling** — ``n_samples=4`` best-of-n requests fanning
    out over ``BlockAllocator.fork``: each group prefills its prompt
    once and its four siblings share the prompt blocks read-only
    (diverging tails un-share via COW).  Reports peak live blocks
    against the ``prompt + n*tail`` sharing bound, blocks saved by fork
    sharing (CI fails at zero), decode tok/s, and verifies each sibling
    of the probe request is bit-identical to an independent
    (seed, stream=i) rerun,
  * **shape_churn** — a stream of prompts whose lengths all differ,
    deliberately churning the ``(B, chunk_len, pos_offset)`` triples
    the pre-shape-stable engine compiled per: reports the XLA compile
    count of the chunk step (must stay at ``compile_bound`` = one per
    pool key — CI fails above it), the legacy shape-key count it
    *would* have compiled, and TTFT p50/p99 for the churny traffic,
  * **long_context** — three 512–1024-token prompts chunk-prefilled on
    a 16-token-block pool: reports the prefix K/V bytes the
    chunk-attention step reads (live tiles through the page table)
    against the legacy full-extent-gather baseline (CI fails if the
    saving is zero), the chunk step's compile count against the
    one-per-pool-key bound, and a whole-prompt bitwise-identity probe
    through the fused Pallas kernel in interpret mode,
  * **fault_tolerance** — the same traffic served fault-free, with the
    fault layer enabled-but-idle, and under a seeded FaultPlan hitting
    one request per fault class: reports goodput (surviving tokens),
    blast radius per fault, leaked blocks after the faulted drain, and
    the two bit-exactness flags CI gates on (idle fault layer and fault
    survivors must both match the fault-free streams exactly),
  * **open_loop** — continuous-arrival serving through the async
    front-end (serving/async_serving.py): a seeded Poisson schedule at
    a rate calibrated to a fraction of measured closed-loop capacity,
    requests arriving and streaming back mid-flight.  Reports goodput
    and TTFT/TPOT percentiles measured from TRUE arrival time (the
    queueing-delay-aware numbers the drain-time workloads cannot see),
    plus the closed-vs-open bit-exactness flag, the
    negative-latency-sample count (the ``t_first_token == 0.0`` filter
    regression guard) and the prefill compile count under continuous
    arrivals — all CI-gated.

Writes machine-readable JSON (``BENCH_engine.json``, emitted into the CI
artifacts dir by ci/run_ci.sh) so the trajectory of serving-level
metrics is chartable across PRs:

  * TTFT p50/p99 (ms) — chunked admission exists to keep the p99 of
    short requests bounded while long prompts stream in,
  * decode throughput (tok/s over decode wall-clock),
  * preemption / prefill-chunk / batched-call / decode-step counts and
    pool size — the work the scheduler did to absorb the load,
  * prefix-cache hit rate, cached tokens, and prefill-tokens saved.

CPU wall-clock here is a smoke-level signal (the kernels are jnp paths,
not the TPU build); the counts are the stable part of the trajectory.
"""

from __future__ import annotations

import json

import numpy as np

PROMPT_LENS = (8, 72, 12, 64, 10, 80, 9, 48, 16, 96)

# shared-prefix workload: N requests drawing on M distinct system prompts
SP_SYSTEM_PROMPTS = 3
SP_REQUESTS = 12
SP_SYSTEM_LEN = 48           # 3 full blocks of 16 -> cacheable prefix
SP_SUFFIX_LEN = 8

# parallel-sampling workload: best-of-4 requests over a 3-block prompt
PS_REQUESTS = 3
PS_N_SAMPLES = 4
PS_PROMPT_LEN = 48           # 3 full blocks of 16, shared by all siblings
PS_MAX_NEW = 16              # each sibling's divergent tail: 1 block

# shape-churn workload: every prompt length distinct, spanning several
# chunk counts under a 48-token budget -> maximal (B, len, off) churn
SC_PROMPT_LENS = (5, 23, 41, 7, 66, 14, 90, 31, 11, 53, 77, 19)
SC_CHUNK_TOKENS = 48
SC_COMPILE_BOUND = 1         # executables per pool key (docs/BENCHMARKS.md)

# fault-tolerance workload: 6 singletons + one n_samples=2 group on an
# ample pool; a seeded FaultPlan implicates one request per fault class
FT_PROMPT_LENS = (8, 20, 12, 24, 10, 16, 14)   # last one is the group
FT_MAX_NEW = 12

# spec-decode workload: greedy requests with repetitive suffixes (the
# n-gram proposer's sweet spot — prompt-lookup drafts accept whenever
# the continuation revisits the pattern) mixed with seeded sampled
# traffic, served draft-then-verify on the fused kernel (interpret mode)
SD_PATTERN_LEN = 4
SD_PATTERN_REPS = 5          # 20-token repetitive prompts
SD_GREEDY = 3
SD_SAMPLED = 2
SD_MAX_NEW = 24
SD_SPEC_TOKENS = 4
SD_COMPILE_BOUND = 1         # verify executables per pool key

# long-context workload: few LONG prompts on a small-block pool — the
# regime where chunked prefill's prefix read dominates HBM traffic (each
# chunk re-reads its whole prefix); charts prefix_attn_bytes (live tiles
# through the page table) against the legacy full-extent gather baseline
LC_PROMPT_LENS = (512, 768, 1024)
LC_CHUNK_TOKENS = 64
LC_PAGE_SIZE = 16
LC_MAX_NEW = 4
LC_COMPILE_BOUND = 1         # same per-pool-key bound as shape_churn

# open-loop workload: OL_REQUESTS requests on a seeded Poisson schedule
# whose rate is OL_LOAD_FACTOR of measured closed-loop capacity (the
# closed pass doubles as the bit-exactness reference), plus one
# malformed request mid-schedule whose t_first_token stays 0.0 — the
# latency-filter regression guard (neg_latency_samples must be 0)
OL_REQUESTS = 12
OL_MAX_NEW = 8
OL_LOAD_FACTOR = 0.8
OL_SEED = 17
OL_COMPILE_BOUND = 0         # continuous arrivals over the closed pass

# multi-chip workload: the same mixed greedy/sampled traffic served
# unsharded and over a tensor-parallel mesh (model axis = largest of
# 1/2/4 that the local device count allows).  The CI multi-device lane
# runs it under XLA_FLAGS=--xla_force_host_platform_device_count=4 and
# gates on stream bit-exactness vs the unsharded engine, zero leaked
# blocks at drain, a clean allocator audit, and the chunk step's
# one-executable-per-(pool key, mesh shape) compile bound
MC_PROMPT_LENS = (5, 19, 11, 32, 8, 23)
MC_MAX_NEW = 8
MC_COMPILE_BOUND = 1         # executables per (pool key, mesh shape)


def _build_model():
    import jax

    from repro.configs import get_config, reduced
    from repro.models import build_model

    cfg = reduced(get_config("llama2-110m"))
    model = build_model(cfg)
    params = model.quantize(model.init(jax.random.PRNGKey(0)))
    return model, params


def run_shared_prefix(model, params, quiet: bool = False,
                      max_new_tokens: int = 8) -> dict:
    """Serve SP_REQUESTS requests over SP_SYSTEM_PROMPTS shared system
    prompts twice — prefix caching on, then off — and report what the
    cache bought: hit rate, prefill tokens/blocks saved, TTFT deltas."""
    from repro.serving.async_serving import first_token_latencies
    from repro.serving.engine import Engine

    rng = np.random.default_rng(1)
    systems = [rng.integers(4, 500, size=SP_SYSTEM_LEN).astype(np.int32)
               for _ in range(SP_SYSTEM_PROMPTS)]
    prompts = [np.concatenate([
        systems[i % SP_SYSTEM_PROMPTS],
        rng.integers(4, 500, size=SP_SUFFIX_LEN).astype(np.int32)])
        for i in range(SP_REQUESTS)]

    def serve(prefix_caching: bool):
        eng = Engine(model, params, max_slots=4, max_seq=128,
                     page_size=16, prefill_chunk_tokens=64,
                     prefix_caching=prefix_caching)
        # warmup passes populate the prefix index (pass 1) and compile
        # the warm-path chunk shapes (pass 2, whose plan sequence the
        # measured pass repeats); the measured pass then shows skipped
        # prefill compute rather than skipped compilation.
        for _ in range(2):
            for p in prompts:
                eng.submit(p, max_new_tokens=max_new_tokens,
                           temperature=0.0)
            assert all(r.error is None for r in eng.run())
        stats0 = dict(eng.scheduler.prefix_stats)
        blocks0 = eng.pager.stats["hit_blocks"]
        plans0 = len(eng.plan_log)
        uids = [eng.submit(p, max_new_tokens=max_new_tokens,
                           temperature=0.0) for p in prompts]
        done = {r.uid: r for r in eng.run()}
        assert all(done[u].error is None for u in uids)
        # via the filtered helper: a request that never produced a first
        # token keeps t_first_token == 0.0 and must not contribute a
        # (hugely negative) sample to the percentiles
        ttft = first_token_latencies([done[u] for u in uids]) * 1e3
        assert len(ttft) == len(uids)
        dstats = {k: eng.scheduler.prefix_stats[k] - stats0[k]
                  for k in stats0}
        dstats["hit_blocks"] = eng.pager.stats["hit_blocks"] - blocks0
        prefill_tokens = sum(e - s for plan in eng.plan_log[plans0:]
                             for (_, s, e) in plan["prefills"])
        return eng, ttft, dstats, prefill_tokens

    warm, ttft_warm, wstats, wtokens = serve(True)
    cold, ttft_cold, _, ctokens = serve(False)

    result = {
        "requests": SP_REQUESTS,
        "distinct_system_prompts": SP_SYSTEM_PROMPTS,
        "system_len": SP_SYSTEM_LEN,
        "suffix_len": SP_SUFFIX_LEN,
        "prefix_hit_rate": wstats["hits"] / max(1, wstats["admissions"]),
        "prefix_hits": wstats["hits"],
        "admissions": wstats["admissions"],
        "cached_tokens": wstats["cached_tokens"],
        "blocks_saved": wstats["hit_blocks"],
        "prefill_tokens_warm": wtokens,
        "prefill_tokens_cold": ctokens,
        "prompt_tokens_submitted": int(sum(len(p) for p in prompts)),
        "chunk_batch_calls_warm": warm.metrics["chunk_batch_calls"],
        "prefill_chunks_warm": warm.metrics["prefill_chunks"],
        "ttft_ms_p50_warm": float(np.percentile(ttft_warm, 50)),
        "ttft_ms_p50_cold": float(np.percentile(ttft_cold, 50)),
        "ttft_ms_p99_warm": float(np.percentile(ttft_warm, 99)),
        "ttft_ms_p99_cold": float(np.percentile(ttft_cold, 99)),
    }
    if not quiet:
        print(f"enginebench/prefix_hit_rate,"
              f"{result['prefix_hit_rate']:.2f},ratio"
              f" ({result['prefix_hits']}/{result['admissions']} admissions,"
              f" {result['cached_tokens']} tokens,"
              f" {result['blocks_saved']} blocks reused)")
        print(f"enginebench/prefill_tokens_saved,"
              f"{result['prefill_tokens_cold'] - result['prefill_tokens_warm']},"
              f"tokens (warm {result['prefill_tokens_warm']}"
              f" vs cold {result['prefill_tokens_cold']})")
        print(f"enginebench/ttft_ms_p50_warm,"
              f"{result['ttft_ms_p50_warm']:.1f},ms"
              f" (cold {result['ttft_ms_p50_cold']:.1f})")
    return result


def run_parallel_sampling(model, params, quiet: bool = False) -> dict:
    """Serve PS_REQUESTS ``n_samples=4`` requests twice on one engine
    (round 1 compiles + provides the cold reference streams; round 2 is
    measured) and report what fork sharing bought.

    Every group admits once, prefills its 48-token prompt once, and fans
    out into 4 siblings whose page tables all point at the same 3 prompt
    blocks — so a group's peak footprint is ``prompt + 4*tail`` blocks
    instead of 4 full copies.  The probe request's four siblings are
    re-served as independent (seed, stream=i) requests and must match
    bit for bit (the fanout bit-exactness acceptance bar — raises on
    violation rather than reporting a quietly-wrong speedup)."""
    from repro.serving.engine import Engine

    rng = np.random.default_rng(2)
    prompts = [rng.integers(4, 500, size=PS_PROMPT_LEN).astype(np.int32)
               for _ in range(PS_REQUESTS)]
    max_slots, max_seq, page = 8, 128, 16

    def submit_all(eng):
        return [eng.submit(p, max_new_tokens=PS_MAX_NEW, temperature=1.0,
                           seed=200 + i, n_samples=PS_N_SAMPLES)
                for i, p in enumerate(prompts)]

    eng = Engine(model, params, max_slots=max_slots, max_seq=max_seq,
                 page_size=page, prefill_chunk_tokens=64)
    uids = submit_all(eng)
    cold = {r.uid: r for r in eng.run()}
    assert all(cold[u].error is None for u in uids)

    # the probe group's siblings vs independent reruns (cold vs cold:
    # identical chunk boundaries, so the streams must be bit-identical)
    probe = cold[uids[0]].outputs
    for i in range(PS_N_SAMPLES):
        solo = Engine(model, params, max_slots=max_slots, max_seq=max_seq,
                      page_size=page, prefill_chunk_tokens=64)
        solo.submit(prompts[0], max_new_tokens=PS_MAX_NEW, temperature=1.0,
                    seed=200, stream=i)
        (r,) = solo.run()
        if r.output != probe[i]:
            raise AssertionError(
                f"sibling {i} diverged from its independent rerun:\n"
                f"  group: {probe[i]}\n  rerun: {r.output}")

    # measured round: decode is compiled now; deltas isolate the round
    eng.metrics["blocks_live_peak"] = 0
    eng.metrics["blocks_saved_by_sharing_peak"] = 0
    toks0, t0 = eng.metrics["tokens_out"], eng.metrics["t_decode"]
    uids = submit_all(eng)
    done = {r.uid: r for r in eng.run()}
    assert all(done[u].error is None for u in uids)
    tok_s = ((eng.metrics["tokens_out"] - toks0)
             / max(1e-9, eng.metrics["t_decode"] - t0))

    prompt_blocks = PS_PROMPT_LEN // page
    tail_blocks = -(-(PS_PROMPT_LEN + PS_MAX_NEW) // page) - prompt_blocks
    groups_at_once = max_slots // PS_N_SAMPLES
    bound = groups_at_once * (prompt_blocks + PS_N_SAMPLES * tail_blocks)
    naive = groups_at_once * PS_N_SAMPLES * (prompt_blocks + tail_blocks)
    peak = eng.metrics["blocks_live_peak"]
    if peak > bound:
        raise AssertionError(
            f"fanout peak {peak} blocks exceeds the sharing bound {bound} "
            f"(prompt {prompt_blocks} + {PS_N_SAMPLES}*{tail_blocks} tails "
            f"x {groups_at_once} concurrent groups)")

    result = {
        "requests": PS_REQUESTS,
        "n_samples": PS_N_SAMPLES,
        "prompt_len": PS_PROMPT_LEN,
        "max_new_tokens": PS_MAX_NEW,
        "page_size": page,
        "prompt_blocks": prompt_blocks,
        "tail_blocks_per_sibling": tail_blocks,
        "concurrent_groups": groups_at_once,
        "blocks_live_peak": peak,
        "blocks_bound_shared": bound,
        "blocks_naive_unshared": naive,
        "blocks_saved_by_sharing_peak":
            eng.metrics["blocks_saved_by_sharing_peak"],
        "fanouts": eng.metrics["fanouts"],
        "cow_copies": eng.metrics["cow_copies"],
        "decode_tok_s": float(tok_s),
        "siblings_bitexact": True,
    }
    if not quiet:
        print(f"enginebench/fanout_blocks_peak,{peak},blocks"
              f" (bound {bound}, unshared would be {naive})")
        print(f"enginebench/fanout_blocks_saved,"
              f"{result['blocks_saved_by_sharing_peak']},blocks"
              f" ({result['fanouts']} fanouts,"
              f" {result['cow_copies']} COW copies)")
        print(f"enginebench/fanout_decode_tok_s,{tok_s:.1f},tok/s")
    return result


def run_shape_churn(model, params, quiet: bool = False,
                    max_new_tokens: int = 4) -> dict:
    """Serve SC_PROMPT_LENS prompts — every length distinct — and report
    what shape-stable chunked prefill bought: the chunk step's XLA
    compile count (bounded at SC_COMPILE_BOUND per pool key; CI fails
    above it) against the legacy ``(B, chunk_len, pos_offset)`` key
    count this traffic would have compiled, plus TTFT percentiles.

    The compile count spans the COLD pass (that is where compilation
    happens); TTFT is measured on a second, warm pass so the percentiles
    track steady-state prefill latency rather than the one-time compile
    the cold pass exists to bound."""
    from repro.serving.async_serving import first_token_latencies
    from repro.serving.engine import Engine, legacy_chunk_shape_keys

    rng = np.random.default_rng(7)
    prompts = [rng.integers(4, 500, size=n).astype(np.int32)
               for n in SC_PROMPT_LENS]
    eng = Engine(model, params, max_slots=4, max_seq=128, page_size=16,
                 prefill_chunk_tokens=SC_CHUNK_TOKENS,
                 prefix_caching=False)
    compiles0 = eng.prefill_compile_count()
    for p in prompts:                      # cold pass: compiles count
        eng.submit(p, max_new_tokens=max_new_tokens, temperature=0.0)
    assert all(r.error is None for r in eng.run())
    compiles = eng.prefill_compile_count() - compiles0

    uids = [eng.submit(p, max_new_tokens=max_new_tokens, temperature=0.0)
            for p in prompts]              # warm pass: TTFT percentiles
    done = {r.uid: r for r in eng.run()}
    assert all(done[u].error is None for u in uids)
    # filtered helper: no request without a first token may contribute
    ttft = first_token_latencies([done[u] for u in uids]) * 1e3
    assert len(ttft) == len(uids)

    legacy = legacy_chunk_shape_keys(eng.plan_log)

    result = {
        "requests": len(prompts),
        "prompt_lens": list(SC_PROMPT_LENS),
        "prefill_chunk_tokens": SC_CHUNK_TOKENS,
        "prefill_compiles": compiles,
        "compile_bound": SC_COMPILE_BOUND,
        "legacy_shape_keys": len(legacy),
        "prefill_chunks": eng.metrics["prefill_chunks"],
        "chunk_batch_calls": eng.metrics["chunk_batch_calls"],
        "ttft_ms_p50": float(np.percentile(ttft, 50)),
        "ttft_ms_p99": float(np.percentile(ttft, 99)),
    }
    if not quiet:
        print(f"enginebench/shape_churn_compiles,{compiles},executables"
              f" (bound {SC_COMPILE_BOUND}; legacy keying would have"
              f" compiled {result['legacy_shape_keys']})")
        print(f"enginebench/shape_churn_ttft_ms_p50,"
              f"{result['ttft_ms_p50']:.1f},ms"
              f" (p99 {result['ttft_ms_p99']:.1f})")
    return result


def run_long_context(model, params, quiet: bool = False) -> dict:
    """Serve LC_PROMPT_LENS (512–1024 token prompts) through 64-token
    chunks on a 16-token-block pool and report what the fused paged
    prefix read buys: ``prefix_attn_bytes`` (bytes the chunk-attention
    step actually touches — live tiles through the page table) vs
    ``prefix_attn_bytes_gather`` (the legacy ``max_blocks × block_size``
    materialized-gather extent), alongside TTFT p50/p99 and decode
    tok/s.  CI fails if the saving hits zero, if the chunk step's
    compile count exceeds the one-per-pool-key bound, or if the
    whole-prompt bitwise-identity probe (single chunk through the FUSED
    kernel, interpret mode, vs one-shot ``prefill``) regresses."""
    import os

    import jax.numpy as jnp

    from repro.models import transformer
    from repro.serving.async_serving import first_token_latencies
    from repro.serving.engine import Engine

    rng = np.random.default_rng(11)
    prompts = [rng.integers(4, 500, size=n).astype(np.int32)
               for n in LC_PROMPT_LENS]
    max_seq = 1088                     # longest prompt + decode headroom

    # serve with the FUSED path enabled (Pallas interpret mode — the
    # kernel genuinely executes on CPU), so prefix_attn_bytes and the
    # compile count describe the fused kernel, not the jnp oracle
    prev = os.environ.get("REPRO_FUSED_PREFILL")
    os.environ["REPRO_FUSED_PREFILL"] = "interpret"
    try:
        fused_mode = transformer.prefill_fused_mode()
        eng = Engine(model, params, max_slots=2, max_seq=max_seq,
                     page_size=LC_PAGE_SIZE,
                     prefill_chunk_tokens=LC_CHUNK_TOKENS,
                     prefix_caching=False)
        compiles0 = eng.prefill_compile_count()
        uids = [eng.submit(p, max_new_tokens=LC_MAX_NEW, temperature=0.0)
                for p in prompts]
        done = {r.uid: r for r in eng.run()}
        assert all(done[u].error is None for u in uids), \
            [done[u].error for u in uids if done[u].error is not None]
        compiles = eng.prefill_compile_count() - compiles0

        # whole-prompt bit-identity probe through the FUSED kernel: one
        # 64-token prompt as a single natural-extent chunk vs one-shot
        # prefill.  The contract is stated for f32 compute + f32 pools
        # (the bench model is quantized/bf16, where even the oracle path
        # carries a cast), so probe a small f32 build of the same config.
        import jax

        from repro.configs import get_config, reduced
        from repro.models import build_model
        f32m = build_model(reduced(get_config("llama2-110m")).with_(
            compute_dtype="float32"))
        f32p = f32m.init(jax.random.PRNGKey(0))
        probe = rng.integers(4, 500, size=LC_CHUNK_TOKENS).astype(np.int32)
        l_one, _ = f32m.prefill(f32p, {"tokens": jnp.asarray(probe)[None]},
                                max_seq=LC_CHUNK_TOKENS)
        nblk = LC_CHUNK_TOKENS // LC_PAGE_SIZE
        cache = f32m.init_paged_cache(1, block_size=LC_PAGE_SIZE,
                                      n_blocks=nblk + 1,
                                      max_blocks_per_seq=nblk)
        cache["page_table"] = jnp.asarray(
            np.arange(nblk, dtype=np.int32)[None])
        l_chunk, _ = f32m.prefill_chunk(f32p, jnp.asarray(probe),
                                        cache, 0, 0)
    finally:
        if prev is None:
            del os.environ["REPRO_FUSED_PREFILL"]
        else:
            os.environ["REPRO_FUSED_PREFILL"] = prev
    # filtered helper: no request without a first token may contribute
    ttft = first_token_latencies([done[u] for u in uids]) * 1e3
    assert len(ttft) == len(uids)
    saved = (eng.metrics["prefix_attn_bytes_gather"]
             - eng.metrics["prefix_attn_bytes"])
    bitexact = bool(np.array_equal(np.asarray(l_chunk),
                                   np.asarray(l_one)))

    result = {
        "requests": len(prompts),
        "prompt_lens": list(LC_PROMPT_LENS),
        "prefill_chunk_tokens": LC_CHUNK_TOKENS,
        "page_size": LC_PAGE_SIZE,
        "max_new_tokens": LC_MAX_NEW,
        "ttft_ms_p50": float(np.percentile(ttft, 50)),
        "ttft_ms_p99": float(np.percentile(ttft, 99)),
        "decode_tok_s": eng.throughput_tok_s(),
        "prefill_chunks": eng.metrics["prefill_chunks"],
        "chunk_batch_calls": eng.metrics["chunk_batch_calls"],
        "prefix_attn_bytes": eng.metrics["prefix_attn_bytes"],
        "prefix_attn_bytes_gather":
            eng.metrics["prefix_attn_bytes_gather"],
        "prefix_attn_bytes_saved": saved,
        "prefix_bytes_saved_frac":
            saved / max(eng.metrics["prefix_attn_bytes_gather"], 1),
        "prefill_compiles": compiles,
        "compile_bound": LC_COMPILE_BOUND,
        "fused_mode": fused_mode,
        "whole_prompt_bitexact": bitexact,
    }
    if not quiet:
        print(f"enginebench/long_context_prefix_bytes_saved,{saved},bytes"
              f" ({result['prefix_bytes_saved_frac']:.0%} of the"
              f" {result['prefix_attn_bytes_gather']}-byte gather"
              f" baseline; mode {result['fused_mode']})")
        print(f"enginebench/long_context_ttft_ms_p50,"
              f"{result['ttft_ms_p50']:.1f},ms"
              f" (p99 {result['ttft_ms_p99']:.1f})")
        print(f"enginebench/long_context_bitexact,"
              f"{int(bitexact)},bool (whole-prompt chunk via fused"
              f" kernel vs one-shot prefill)")
    return result


def run_spec_decode(model, params, quiet: bool = False) -> dict:
    """Serve greedy repetitive-suffix prompts (plus seeded sampled
    traffic) draft-then-verify and report what speculation bought:

      1. non-speculative greedy reference — the streams every gate
         compares against, and the baseline ``tokens_per_joule``,
      2. speculative greedy (n-gram proposer, fused kernel in interpret
         mode) — must be **bit-identical** to run 1 (raises otherwise),
         with ``steps_per_token`` < 1.0 (fewer per-sequence device steps
         than emitted tokens: the whole point), ``accept_ratio`` > 0,
         and the verify entry within its one-per-pool-key compile bound
         — all CI-gated (ci/run_ci.sh),
      3. mixed greedy + sampled speculative traffic — the greedy streams
         must STILL match run 1 (acceptance counts are per-row private;
         batch composition cannot leak), and the sampled requests
         exercise per-position keyed acceptance under temperature.

    Energy is the roofline model (launch/roofline.step_joules) fed by
    the engine's per-call bytes/FLOPs accounting — ``tokens_per_joule``
    is the paper's headline metric, reported for runs 1 and 2 so the
    speculation win shows up in tokens/J, not just steps."""
    import os

    from repro.models import transformer
    from repro.serving.engine import Engine

    rng = np.random.default_rng(13)
    n_req = SD_GREEDY + SD_SAMPLED
    prompts = [np.tile(rng.integers(4, 500,
                                    size=SD_PATTERN_LEN).astype(np.int32),
                       SD_PATTERN_REPS) for _ in range(n_req)]

    def mk_engine(spec: bool):
        return Engine(model, params, max_slots=4, max_seq=96, page_size=8,
                      prefill_chunk_tokens=32, prefix_caching=False,
                      spec_tokens=SD_SPEC_TOKENS if spec else 0)

    def serve(eng, idx):
        uids = [eng.submit(prompts[i], max_new_tokens=SD_MAX_NEW,
                           temperature=0.0 if i < SD_GREEDY else 1.0,
                           seed=None if i < SD_GREEDY else 400 + i)
                for i in idx]
        done = {r.uid: r for r in eng.run()}
        assert all(done[u].error is None for u in uids), \
            [done[u].error for u in uids if done[u].error is not None]
        return [done[u].output for u in uids]

    greedy_idx = list(range(SD_GREEDY))
    prev = os.environ.get("REPRO_FUSED_PREFILL")
    os.environ["REPRO_FUSED_PREFILL"] = "interpret"
    try:
        fused_mode = transformer.prefill_fused_mode()
        eng0 = mk_engine(False)                    # 1: reference
        base = serve(eng0, greedy_idx)
        eng1 = mk_engine(True)                     # 2: speculative greedy
        compiles0 = eng1.verify_compile_count()
        spec = serve(eng1, greedy_idx)
        verify_compiles = eng1.verify_compile_count() - compiles0
        eng2 = mk_engine(True)                     # 3: mixed traffic
        mixed = serve(eng2, list(range(n_req)))
    finally:
        if prev is None:
            del os.environ["REPRO_FUSED_PREFILL"]
        else:
            os.environ["REPRO_FUSED_PREFILL"] = prev

    greedy_bitexact = spec == base
    mixed_greedy_bitexact = mixed[:SD_GREEDY] == base
    if not greedy_bitexact:
        raise AssertionError(
            f"speculative greedy streams diverged:\n  base: {base}\n"
            f"  spec: {spec}")

    m0, m1 = eng0.metrics, eng1.metrics
    tpj0 = m0["tokens_out"] / max(m0["energy_joules"], 1e-12)
    tpj1 = m1["tokens_out"] / max(m1["energy_joules"], 1e-12)

    result = {
        "requests_greedy": SD_GREEDY,
        "requests_sampled": SD_SAMPLED,
        "prompt_len": SD_PATTERN_LEN * SD_PATTERN_REPS,
        "max_new_tokens": SD_MAX_NEW,
        "spec_tokens": SD_SPEC_TOKENS,
        "proposer": "ngram",
        "fused_mode": fused_mode,
        "greedy_bitexact": bool(greedy_bitexact),
        "mixed_greedy_bitexact": bool(mixed_greedy_bitexact),
        "draft_tokens": m1["draft_tokens"],
        "accepted_tokens": m1["accepted_tokens"],
        "accept_ratio": m1["accept_ratio"],
        "steps_per_token": m1["steps_per_token"],
        "steps_per_token_nonspec": m0["steps_per_token"],
        "verify_steps": m1["verify_steps"],
        "spec_rollbacks": m1["spec_rollbacks"],
        "verify_compiles": verify_compiles,
        "compile_bound": SD_COMPILE_BOUND,
        "energy_joules": m1["energy_joules"],
        "energy_joules_nonspec": m0["energy_joules"],
        "tokens_per_joule": float(tpj1),
        "tokens_per_joule_nonspec": float(tpj0),
        "mixed_accept_ratio": eng2.metrics["accept_ratio"],
        "mixed_steps_per_token": eng2.metrics["steps_per_token"],
    }
    if not quiet:
        print(f"enginebench/spec_steps_per_token,"
              f"{result['steps_per_token']:.3f},steps/token"
              f" (non-spec 1.000; accept ratio"
              f" {result['accept_ratio']:.2f} ="
              f" {result['accepted_tokens']}/{result['draft_tokens']}"
              f" drafts, {result['spec_rollbacks']} rollbacks)")
        print(f"enginebench/spec_tokens_per_joule,{tpj1:.0f},tok/J"
              f" (non-spec {tpj0:.0f}, roofline model)")
        print(f"enginebench/spec_bitexact,"
              f"{int(greedy_bitexact and mixed_greedy_bitexact)},bool"
              f" (solo {greedy_bitexact}, mixed {mixed_greedy_bitexact};"
              f" verify compiles {verify_compiles})")
    return result


def run_fault_tolerance(model, params, quiet: bool = False) -> dict:
    """Serve FT_PROMPT_LENS (6 singletons + one n_samples=2 group) three
    times and report the fault layer's acceptance bars:

      1. no fault layer (wall clock) — the reference streams,
      2. fault layer ENABLED but with an empty plan, SimClock, per-step
         allocator audit — must be bit-identical to run 1
         (``faultfree_bitexact``; CI fails otherwise: the hooks must be
         free when nothing is armed),
      3. a seeded FaultPlan implicating one request per fault class
         (transient blip, persistent step fault, NaN row, page-table
         corruption, deadline expiry) — each class must fail exactly its
         target (``blast_radius_max`` <= 1 request; a sampling group
         counts as one), the survivors' streams must match run 1 bit for
         bit (``survivors_bitexact``), and the drained pool must hold
         zero leases and a clean audit (``leaked_blocks`` == 0,
         ``audit_clean``) — all CI-gated.

    Goodput is reported as surviving-request tokens (count + fraction of
    the fault-free total); run 3 runs on the simulated clock (the
    deadline fault needs it), so its wall-clock tok/s is not measurable
    — ``decode_tok_s_faultfree`` carries run 1's real throughput."""
    from repro.serving.engine import Engine
    from repro.serving.faults import FaultPlan, SimClock

    rng = np.random.default_rng(3)
    prompts = [rng.integers(4, 500, size=n).astype(np.int32)
               for n in FT_PROMPT_LENS]

    def mk_engine(**kw):
        return Engine(model, params, max_slots=8, max_seq=64, page_size=8,
                      prefill_chunk_tokens=32, prefix_caching=False, **kw)

    def submit_all(eng, deadlines: bool = False):
        for i, p in enumerate(prompts):
            uid = i + 1
            eng.submit(p, max_new_tokens=FT_MAX_NEW, temperature=1.0,
                       seed=300 + i, n_samples=2 if uid == 7 else 1,
                       deadline_ms=250.0 if deadlines and uid == 5
                       else None)

    eng0 = mk_engine()
    submit_all(eng0)
    base = {r.uid: r for r in eng0.run()}
    assert all(r.error is None for r in base.values())
    streams0 = {u: r.outputs for u, r in base.items()}

    eng1 = mk_engine(faults=FaultPlan(), clock=SimClock(),
                     audit_interval=1)
    submit_all(eng1)
    idle = {r.uid: r for r in eng1.run()}
    faultfree_bitexact = (
        all(r.error is None for r in idle.values())
        and {u: r.outputs for u, r in idle.items()} == streams0)

    plan = (FaultPlan(seed=3)
            .step_exception(step=2, times=1)              # transient blip
            .step_exception(step=4, uid=2, times=10**6)   # -> "fault"
            .nan_logits(step=5, uid=3)                    # -> "nan"
            .corrupt_pages(step=3, uid=4)                 # -> "audit"
            .advance_clock(step=6, ms=500.0))             # -> "deadline"
    eng = mk_engine(faults=plan, clock=SimClock(), audit_interval=1)
    submit_all(eng, deadlines=True)                       # uid 5: 250 ms
    done = {r.uid: r for r in eng.run()}

    failed = {u: r.error_kind for u, r in done.items()
              if r.error is not None}
    failed_by_kind: dict = {}
    for kind in failed.values():
        failed_by_kind[kind] = failed_by_kind.get(kind, 0) + 1
    survivors = sorted(u for u in done if u not in failed)
    survivors_bitexact = all(done[u].outputs == streams0[u]
                             for u in survivors)
    audit_clean = eng.pager.audit(repair=False).clean
    leaked = (eng.pager.cfg.n_blocks - eng.pager.n_free()
              + sum(1 for rc in eng.pager.refcount if rc))
    tokens_total = sum(len(o) for r in base.values() for o in r.outputs)
    goodput_tokens = sum(len(o) for u in survivors
                         for o in done[u].outputs)

    result = {
        "requests": len(prompts),
        "prompt_lens": list(FT_PROMPT_LENS),
        "max_new_tokens": FT_MAX_NEW,
        "injected_faults": sum(1 for f in plan.faults if f.fired),
        "step_retries": eng.metrics["step_retries"],
        "requests_failed": eng.metrics["requests_failed"],
        "failed_by_kind": failed_by_kind,
        "blast_radius_max": max(failed_by_kind.values(), default=0),
        "survivors": survivors,
        "faultfree_bitexact": bool(faultfree_bitexact),
        "survivors_bitexact": bool(survivors_bitexact),
        "leaked_blocks": int(leaked),
        "audit_clean": bool(audit_clean),
        "audit_repairs": eng.metrics["audit_repairs"],
        "deadline_misses": eng.metrics["deadline_misses"],
        "nan_rows": eng.metrics["nan_rows"],
        "goodput_tokens": int(goodput_tokens),
        "tokens_total_faultfree": int(tokens_total),
        "goodput_fraction": goodput_tokens / max(1, tokens_total),
        "decode_tok_s_faultfree": eng0.throughput_tok_s(),
    }
    if not quiet:
        print(f"enginebench/fault_goodput,{result['goodput_fraction']:.2f},"
              f"ratio ({goodput_tokens}/{tokens_total} tokens from"
              f" {len(survivors)}/{len(prompts)} surviving requests)")
        print(f"enginebench/fault_blast_radius,"
              f"{result['blast_radius_max']},requests/fault"
              f" ({failed_by_kind})")
        print(f"enginebench/fault_bitexact,"
              f"{int(faultfree_bitexact and survivors_bitexact)},bool"
              f" (faultfree {faultfree_bitexact},"
              f" survivors {survivors_bitexact};"
              f" {result['leaked_blocks']} leaked blocks,"
              f" audit clean {audit_clean})")
    return result


def run_open_loop_serving(model, params, quiet: bool = False) -> dict:
    """Continuous-arrival serving under load: OL_REQUESTS requests on a
    seeded Poisson schedule served through the async front-end
    (mid-flight submission, per-step streaming, the dispatch→sync
    overlap window), measured OPEN loop — goodput plus TTFT/TPOT
    percentiles charged from TRUE arrival time, the queueing-delay-aware
    numbers the drain-style workloads structurally cannot see.

    The same arrival order submitted up front and drained closed-loop
    provides both the rate calibration (arrivals at OL_LOAD_FACTOR of
    measured capacity) and the reference streams.  One malformed request
    is inserted mid-schedule: it is rejected with ``t_first_token`` left
    at 0.0, and ``neg_latency_samples`` proves the latency filter kept
    it out of the percentiles.  CI gates: bit-exactness vs closed,
    nonzero goodput, zero negative latency samples, and no prefill
    executables beyond what the closed pass compiled."""
    import time

    from repro.serving.async_serving import poisson_arrivals, run_open_loop
    from repro.serving.engine import Engine

    rng = np.random.default_rng(OL_SEED)
    prompts = [rng.integers(4, 500, size=int(rng.integers(8, 24)))
               .astype(np.int32) for _ in range(OL_REQUESTS)]
    kws = [{"max_new_tokens": OL_MAX_NEW, "seed": 1000 + i,
            "temperature": 0.0 if i % 2 == 0 else 1.0}
           for i in range(OL_REQUESTS)]

    def make_engine():
        return Engine(model, params, max_slots=4, max_seq=128,
                      page_size=16, prefill_chunk_tokens=32)

    def streams(req):
        outs = req.outputs if req.outputs is not None else [req.output or []]
        return tuple(tuple(o) for o in outs)

    # closed-loop pass: rate calibration + bit-exactness reference
    eng_c = make_engine()
    for p, kw in zip(prompts, kws):
        eng_c.submit(p, **kw)
    t0 = time.perf_counter()
    done_c = sorted(eng_c.run(), key=lambda r: r.uid)
    t_closed = max(time.perf_counter() - t0, 1e-6)
    assert all(r.error is None for r in done_c)
    ref = [streams(r) for r in done_c]
    rate = OL_LOAD_FACTOR * OL_REQUESTS / t_closed
    # compile baseline AFTER the closed pass: the gate is that
    # continuous arrivals reuse the closed pass's executables
    compiles0 = eng_c.prefill_compile_count()

    arrivals = poisson_arrivals(OL_SEED, OL_REQUESTS, rate)
    workload = [(float(t), p, kw)
                for t, p, kw in zip(arrivals, prompts, kws)]
    # the malformed mid-schedule request: rejected at submit, its
    # t_first_token stays 0.0 — the latency-filter regression guard
    bad_at = OL_REQUESTS // 2
    workload.insert(bad_at, (workload[bad_at][0], np.zeros(0, np.int32),
                             {"max_new_tokens": OL_MAX_NEW}))

    eng_o = make_engine()
    handles, report = run_open_loop(eng_o, workload)
    valid = [h for i, h in enumerate(handles) if i != bad_at]
    bitexact = [streams(h.req) for h in valid] == ref
    rejected = handles[bad_at].req
    assert rejected.error is not None
    assert rejected.t_first_token == 0.0
    compiles = eng_o.prefill_compile_count() - compiles0

    result = {
        "requests": OL_REQUESTS,
        "invalid_requests": 1,
        "max_new_tokens": OL_MAX_NEW,
        "load_factor": OL_LOAD_FACTOR,
        "arrival_seed": OL_SEED,
        "arrival_rate_req_s": float(report.arrival_rate_req_s),
        "closed_wall_s": float(t_closed),
        "wall_s": float(report.wall_s),
        "completed_ok": report.completed_ok,
        "failed": report.failed,
        "goodput_tok_s": float(report.goodput_tok_s),
        "goodput_req_s": float(report.goodput_req_s),
        "ttft_ms_p50": report.ttft_ms["p50"],
        "ttft_ms_p95": report.ttft_ms["p95"],
        "ttft_ms_p99": report.ttft_ms["p99"],
        "tpot_ms_p50": report.tpot_ms["p50"],
        "tpot_ms_p99": report.tpot_ms["p99"],
        "neg_latency_samples": report.neg_latency_samples,
        "midflight_submits": report.midflight_submits,
        "peak_queue_depth": report.peak_queue_depth,
        "closed_vs_open_bitexact": bool(bitexact),
        "prefill_compiles": compiles,
        "compile_bound": OL_COMPILE_BOUND,
    }
    if not quiet:
        print(f"enginebench/open_loop_goodput_tok_s,"
              f"{result['goodput_tok_s']:.1f},tok/s"
              f" ({result['goodput_req_s']:.2f} req/s ok at offered"
              f" {result['arrival_rate_req_s']:.2f} req/s,"
              f" {result['midflight_submits']} mid-flight arrivals)")
        print(f"enginebench/open_loop_ttft_ms_p50,"
              f"{result['ttft_ms_p50']:.1f},ms"
              f" (p99 {result['ttft_ms_p99']:.1f}; from true arrival)")
        print(f"enginebench/open_loop_tpot_ms_p50,"
              f"{result['tpot_ms_p50']:.1f},ms"
              f" (p99 {result['tpot_ms_p99']:.1f})")
        print(f"enginebench/open_loop_bitexact,"
              f"{int(result['closed_vs_open_bitexact'])},bool"
              f" ({result['neg_latency_samples']} negative latency"
              f" samples, {result['prefill_compiles']} extra prefill"
              f" compiles)")
    return result


def run_multi_chip(model, params, quiet: bool = False) -> dict:
    """Serve MC_PROMPT_LENS (alternating greedy / seeded sampled) twice
    — unsharded, then over a tensor-parallel mesh whose model axis is
    the largest of 1/2/4 the local device count allows — and report
    what the sharded engine must hold:

      * ``streams_bitexact`` — every request's token stream from the
        mesh engine matches the unsharded engine bit for bit (the
        storage-sharded / compute-replicated contract: all collectives
        are gathers, so no float reduction is reassociated across
        devices; raises on violation),
      * ``leaked_blocks`` / ``audit_clean`` — the host-side allocator is
        device-count-agnostic: drain leaves zero leases and a clean
        audit however many devices sit under the pool,
      * ``prefill_compiles`` — the chunk step stays at
        MC_COMPILE_BOUND executables for this (pool key, mesh shape).

    On one device the mesh degenerates to model=1 (placement through
    the same device_put/constraint path, no sharding) — still a real
    gate on the mesh code path; the CI lane forces 4 host devices so
    model=4 runs everywhere."""
    import jax

    from repro.launch.mesh import make_serve_mesh
    from repro.serving.engine import Engine

    n_dev = jax.device_count()
    msize = max(n for n in (1, 2, 4) if n <= n_dev)
    mesh = make_serve_mesh(msize)
    rng = np.random.default_rng(23)
    prompts = [rng.integers(4, 500, size=n).astype(np.int32)
               for n in MC_PROMPT_LENS]

    def serve(mesh_):
        eng = Engine(model, params, max_slots=4, max_seq=96, page_size=8,
                     prefill_chunk_tokens=24, mesh=mesh_)
        compiles0 = eng.prefill_compile_count()
        uids = [eng.submit(p, max_new_tokens=MC_MAX_NEW,
                           temperature=0.0 if i % 2 == 0 else 1.0,
                           seed=500 + i)
                for i, p in enumerate(prompts)]
        done = {r.uid: r for r in eng.run()}
        assert all(done[u].error is None for u in uids), \
            [done[u].error for u in uids if done[u].error is not None]
        streams = [tuple(tuple(o) for o in done[u].outputs) for u in uids]
        return eng, streams, eng.prefill_compile_count() - compiles0

    eng0, ref, _ = serve(None)
    eng, got, compiles = serve(mesh)
    if got != ref:
        bad = [i for i, (a, b) in enumerate(zip(got, ref)) if a != b]
        raise AssertionError(
            f"mesh={msize} streams diverged from unsharded on requests "
            f"{bad}")
    leaked = (eng.pager.cfg.n_blocks - eng.pager.n_free()
              + sum(1 for rc in eng.pager.refcount if rc))
    audit_clean = eng.pager.audit(repair=False).clean

    result = {
        "requests": len(prompts),
        "prompt_lens": list(MC_PROMPT_LENS),
        "max_new_tokens": MC_MAX_NEW,
        "n_devices": n_dev,
        "mesh_model": msize,
        "streams_bitexact": True,
        "leaked_blocks": int(leaked),
        "audit_clean": bool(audit_clean),
        "prefill_compiles": compiles,
        "compile_bound": MC_COMPILE_BOUND,
        "decode_tok_s": eng.throughput_tok_s(),
        "decode_tok_s_unsharded": eng0.throughput_tok_s(),
        "tokens_out": eng.metrics["tokens_out"],
        "preemptions": eng.metrics["preemptions"],
    }
    if not quiet:
        print(f"enginebench/multi_chip_bitexact,1,bool"
              f" (mesh model={msize} over {n_dev} devices vs unsharded,"
              f" {result['tokens_out']} tokens)")
        print(f"enginebench/multi_chip_leaked_blocks,"
              f"{result['leaked_blocks']},blocks"
              f" (audit clean {audit_clean})")
        print(f"enginebench/multi_chip_compiles,{compiles},executables"
              f" (bound {MC_COMPILE_BOUND} per pool key per mesh shape)")
        print(f"enginebench/multi_chip_decode_tok_s,"
              f"{result['decode_tok_s']:.1f},tok/s"
              f" (unsharded {result['decode_tok_s_unsharded']:.1f};"
              f" CPU smoke signal, not a TPU figure)")
    return result


def run(quiet: bool = False, json_path: str = "BENCH_engine.json",
        max_new_tokens: int = 16) -> dict:
    from repro.serving.async_serving import first_token_latencies
    from repro.serving.engine import Engine

    model, params = _build_model()
    rng = np.random.default_rng(0)

    max_slots, max_seq, page_size = 4, 128, 16
    full_reservation = max_slots * (max_seq // page_size)     # 32 blocks
    # 3/8 of the full reservation: deep enough oversubscription that
    # deferral alone cannot absorb it — mid-decode growth must preempt.
    n_pages = full_reservation * 3 // 8
    eng = Engine(model, params, max_slots=max_slots, max_seq=max_seq,
                 page_size=page_size, n_pages=n_pages,
                 prefill_chunk_tokens=32)
    for n in PROMPT_LENS:
        eng.submit(rng.integers(4, 500, size=n).astype(np.int32),
                   max_new_tokens=max_new_tokens, temperature=0.0)
    done = eng.run()
    ok = [r for r in done if r.error is None]
    assert len(ok) == len(PROMPT_LENS), \
        [r.error for r in done if r.error is not None]
    # filtered helper: no request without a first token may contribute
    ttft_ms = first_token_latencies(ok) * 1e3
    assert len(ttft_ms) == len(ok)

    result = {
        "requests": len(done),
        "prompt_lens": list(PROMPT_LENS),
        "max_new_tokens": max_new_tokens,
        "n_pages": n_pages,
        "full_reservation_pages": full_reservation,
        "prefill_chunk_tokens": 32,
        "ttft_ms_p50": float(np.percentile(ttft_ms, 50)),
        "ttft_ms_p99": float(np.percentile(ttft_ms, 99)),
        "decode_tok_s": eng.throughput_tok_s(),
        "tokens_out": eng.metrics["tokens_out"],
        "decode_steps": eng.metrics["decode_steps"],
        "prefill_chunks": eng.metrics["prefill_chunks"],
        "chunk_batch_calls": eng.metrics["chunk_batch_calls"],
        "preemptions": eng.metrics["preemptions"],
        "energy_joules": eng.metrics["energy_joules"],
        "tokens_per_joule": eng.metrics["tokens_out"]
                            / max(eng.metrics["energy_joules"], 1e-12),
    }
    result["shared_prefix"] = run_shared_prefix(model, params, quiet=quiet)
    result["parallel_sampling"] = run_parallel_sampling(model, params,
                                                        quiet=quiet)
    result["shape_churn"] = run_shape_churn(model, params, quiet=quiet)
    result["long_context"] = run_long_context(model, params, quiet=quiet)
    result["fault_tolerance"] = run_fault_tolerance(model, params,
                                                    quiet=quiet)
    result["spec_decode"] = run_spec_decode(model, params, quiet=quiet)
    result["open_loop"] = run_open_loop_serving(model, params, quiet=quiet)
    result["multi_chip"] = run_multi_chip(model, params, quiet=quiet)
    with open(json_path, "w") as fh:
        json.dump(result, fh, indent=2)
    if not quiet:
        print(f"enginebench/ttft_ms_p50,{result['ttft_ms_p50']:.1f},ms")
        print(f"enginebench/ttft_ms_p99,{result['ttft_ms_p99']:.1f},ms")
        print(f"enginebench/decode_tok_s,{result['decode_tok_s']:.1f},tok/s")
        print(f"enginebench/tokens_per_joule,"
              f"{result['tokens_per_joule']:.0f},tok/J"
              f" ({result['energy_joules']:.2e} J roofline,"
              f" mixed workload)")
        print(f"enginebench/preemptions,{result['preemptions']},count"
              f" (pool {n_pages}/{full_reservation} blocks,"
              f" {result['prefill_chunks']} chunks in"
              f" {result['chunk_batch_calls']} batched calls)")
    return result


WORKLOADS = {
    "shared_prefix": run_shared_prefix,
    "parallel_sampling": run_parallel_sampling,
    "shape_churn": run_shape_churn,
    "long_context": run_long_context,
    "fault_tolerance": run_fault_tolerance,
    "spec_decode": run_spec_decode,
    "open_loop": run_open_loop_serving,
    "multi_chip": run_multi_chip,
}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", choices=[""] + sorted(WORKLOADS),
                    help="run a single workload (the CI multi-device "
                         "lane re-runs multi_chip under forced host "
                         "devices without repeating the full suite)")
    ap.add_argument("--json", default="BENCH_engine.json")
    args = ap.parse_args()
    if args.only:
        mdl, prms = _build_model()
        out = {args.only: WORKLOADS[args.only](mdl, prms)}
        with open(args.json, "w") as fh:
            json.dump(out, fh, indent=2)
    else:
        run(json_path=args.json)
