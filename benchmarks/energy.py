"""Tables 4–6 analog — power / energy per token (modeled).

This container has no power rails, so energy is *modeled* the way the
paper models FPGA power (Vivado estimates): decode on TPU v5e is
memory-bound, so

    t_token  = bytes_streamed_per_token / HBM_BW
    E_token  = t_token x P_chip        (v5e serving envelope ~ idle+HBM)

We report mWh/token for fp32 / bf16 / int8 / int4 weight streaming of the
paper's 110M config AND the assigned archs' decode cells (from the
dry-run), with the paper's measured CPU/GPU/FPGA numbers alongside.
The reproduction target is the RATIO: int8 cuts energy/token ~4x vs fp32
(the paper's 12.75x also banks on 9 W vs 42 W device envelopes).
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import get_config
from repro.launch.roofline import HBM_BW, V5E_POWER_W
from repro.launch import steps as steplib
from repro.launch.roofline import tree_bytes
from repro.models import build_model

V5E_IDLE_W = 60.0


def _mwh_per_tok(bytes_per_tok: float, power_w: float = V5E_POWER_W,
                 chips: int = 1) -> float:
    t = bytes_per_tok / (HBM_BW * chips)
    joules = t * power_w * chips
    return joules / 3.6           # 1 mWh = 3.6 J


def run(quiet: bool = False):
    rows = []
    # --- the paper's model, per weight format (batch 1, ctx 1024) -------
    cfg = get_config("llama2-110m")
    model = build_model(cfg)
    p = steplib.params_struct(model)
    fp32_bytes = tree_bytes(p)
    ctx_kv = (cfg.n_layers * 1024 * cfg.n_kv_heads * cfg.hd() * 2)
    for name, factor, kvb in [("fp32", 1.0, 4), ("bf16", 0.5, 2),
                              ("q8_0", 0.264, 2), ("q4_0", 0.141, 2)]:
        bpt = fp32_bytes * factor + ctx_kv * kvb
        rows.append((f"energy/110m_{name}", _mwh_per_tok(bpt) * 1e3,
                     "uWh/tok modeled v5e"))
    r_fp, r_q8 = rows[0][1], rows[2][1]
    rows.append(("energy/110m_q8_vs_fp32_ratio", r_fp / r_q8,
                 "x reduction (paper fpga-vs-cpu: 12.75x incl. 42W->9W "
                 "device envelope)"))
    rows.append(("energy/paper_measured_cpu", 510.0, "uWh/tok (Table 6)"))
    rows.append(("energy/paper_measured_gpu", 330.0, "uWh/tok (Table 6)"))
    rows.append(("energy/paper_measured_fpga", 40.0, "uWh/tok (Table 6)"))

    # --- assigned archs from dry-run records ----------------------------
    for f in sorted(Path("results/dryrun").glob("*decode_32k__1pod.json")):
        rec = json.loads(f.read_text())
        t_tok = rec["est_step_time_s"]
        batch = 128
        e = t_tok * V5E_POWER_W * rec["devices"] / batch / 3.6 * 1e3
        rows.append((f"energy/{rec['arch']}_decode32k", e,
                     f"uWh/tok @256 chips, dominant={rec['dominant']}"))

    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.3f},{r[2]}")
    return rows
