#!/usr/bin/env bash
# Tier-1 CI: full test suite + decode-path and engine-level benchmarks
# (interpret mode).  Everything runs on CPU — Pallas kernels execute under
# interpret=True.  Benchmark JSON (BENCH_decode.json, BENCH_engine.json)
# is emitted into $ARTIFACTS_DIR (default: artifacts/, gitignored) and
# uploaded by the workflow for trajectory tracking.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export ARTIFACTS_DIR="${ARTIFACTS_DIR:-artifacts}"
mkdir -p "$ARTIFACTS_DIR"

python -m pytest -q -x

python - <<'EOF'
import os
import sys
sys.path.insert(0, ".")
from benchmarks import engine_bench, kernels_bench
art = os.environ.get("ARTIFACTS_DIR", "artifacts")
kernels_bench.run()
kernels_bench.run_decode(json_path=os.path.join(art, "BENCH_decode.json"))
engine_bench.run(json_path=os.path.join(art, "BENCH_engine.json"))
EOF
