#!/usr/bin/env bash
# Tier-1 CI: full test suite + decode-path and engine-level benchmarks
# (interpret mode).  Everything runs on CPU — Pallas kernels execute under
# interpret=True.  Benchmark JSON (BENCH_decode.json, BENCH_engine.json)
# is emitted into $ARTIFACTS_DIR (default: artifacts/, gitignored) and
# uploaded by the workflow for trajectory tracking.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export ARTIFACTS_DIR="${ARTIFACTS_DIR:-artifacts}"
mkdir -p "$ARTIFACTS_DIR"

# Docs gate first (cheap): README/docs internal links must resolve and
# the README quickstart snippets must parse with importable imports.
python ci/check_docs.py

# Engine property suite first, as its own pinned gate: the hypothesis
# variants are derandomized with deadline=None (no deadline flakes;
# they self-skip when hypothesis is absent from the image) and their
# deterministic seeded twins run everywhere with the exact seeds baked
# into the tests.  The main run below ignores the file so the suite
# executes exactly once per CI job.
python -m pytest -q tests/test_engine_properties.py

python -m pytest -q -x --ignore=tests/test_engine_properties.py

python - <<'EOF'
import json
import os
import sys
sys.path.insert(0, ".")
from benchmarks import engine_bench, kernels_bench
art = os.environ.get("ARTIFACTS_DIR", "artifacts")
kernels_bench.run()
kernels_bench.run_decode(json_path=os.path.join(art, "BENCH_decode.json"))
engine_bench.run(json_path=os.path.join(art, "BENCH_engine.json"))

# Regression tripwire: the shared-prefix workload must actually hit the
# prefix cache — a zero hit rate means caching got silently disabled or
# the index broke, which no functional test would notice as a failure.
with open(os.path.join(art, "BENCH_engine.json")) as fh:
    bench = json.load(fh)
sp = bench["shared_prefix"]
print("CI engine-bench summary:")
print(f"  prefix_hit_rate={sp['prefix_hit_rate']:.2f} "
      f"({sp['prefix_hits']}/{sp['admissions']} admissions)")
print(f"  cached_tokens={sp['cached_tokens']} "
      f"blocks_saved={sp['blocks_saved']}")
print(f"  prefill_tokens warm={sp['prefill_tokens_warm']} "
      f"cold={sp['prefill_tokens_cold']}")
print(f"  ttft_ms_p50 warm={sp['ttft_ms_p50_warm']:.1f} "
      f"cold={sp['ttft_ms_p50_cold']:.1f}")
print(f"  mixed: preemptions={bench['preemptions']} "
      f"prefill_chunks={bench['prefill_chunks']} "
      f"in {bench['chunk_batch_calls']} batched calls")
ps = bench["parallel_sampling"]
print(f"  fanout: peak={ps['blocks_live_peak']} "
      f"bound={ps['blocks_bound_shared']} "
      f"unshared={ps['blocks_naive_unshared']} "
      f"saved={ps['blocks_saved_by_sharing_peak']} "
      f"tok_s={ps['decode_tok_s']:.1f}")
sc = bench["shape_churn"]
print(f"  shape churn: compiles={sc['prefill_compiles']} "
      f"(bound {sc['compile_bound']}, legacy keys "
      f"{sc['legacy_shape_keys']}) ttft_ms_p50={sc['ttft_ms_p50']:.1f} "
      f"p99={sc['ttft_ms_p99']:.1f}")
lc = bench["long_context"]
print(f"  long context: prefix_attn_bytes={lc['prefix_attn_bytes']} "
      f"gather={lc['prefix_attn_bytes_gather']} "
      f"saved={lc['prefix_attn_bytes_saved']} "
      f"({lc['prefix_bytes_saved_frac']:.0%}) "
      f"compiles={lc['prefill_compiles']} (bound {lc['compile_bound']}) "
      f"bitexact={lc['whole_prompt_bitexact']} "
      f"ttft_ms_p50={lc['ttft_ms_p50']:.1f}")
ft = bench["fault_tolerance"]
print(f"  fault tolerance: goodput={ft['goodput_fraction']:.2f} "
      f"({ft['goodput_tokens']}/{ft['tokens_total_faultfree']} tokens) "
      f"blast_radius_max={ft['blast_radius_max']} "
      f"failed={ft['failed_by_kind']} leaked={ft['leaked_blocks']} "
      f"bitexact={ft['faultfree_bitexact'] and ft['survivors_bitexact']}")
if sp["prefix_hit_rate"] <= 0 or sp["cached_tokens"] <= 0:
    sys.exit("FAIL: shared-prefix workload reports a zero prefix-cache "
             "hit rate — prefix caching is silently broken or disabled")
if sp["prefill_tokens_warm"] >= sp["prefill_tokens_cold"]:
    sys.exit("FAIL: prefix caching did not reduce executed prefill tokens")
# Fanout tripwire: the n_samples=4 workload must actually share blocks
# across siblings — zero savings means fork sharing silently degraded
# to per-sibling copies (the bench itself raises if any sibling's
# stream diverges from its independent rerun or the peak exceeds the
# prompt + n*tail bound).
if ps["blocks_saved_by_sharing_peak"] <= 0:
    sys.exit("FAIL: n_samples=4 fanout bench reports zero blocks saved "
             "by fork sharing")
if not ps["siblings_bitexact"]:
    sys.exit("FAIL: fanout siblings diverged from independent reruns")
# Shape-stability tripwire: the churny mixed-length workload must serve
# from a bounded set of chunk-step executables (one per pool key) — a
# count above the documented bound means some extent leaked back into
# the compile key and production traffic would recompile per shape.
if sc["prefill_compiles"] > sc["compile_bound"]:
    sys.exit(f"FAIL: shape-churn workload compiled the chunk step "
             f"{sc['prefill_compiles']}x (documented bound: "
             f"{sc['compile_bound']} per pool key; legacy shape keying "
             f"would have been {sc['legacy_shape_keys']})")
if sc["legacy_shape_keys"] <= sc["compile_bound"]:
    sys.exit("FAIL: shape-churn workload produced no shape churn — the "
             "gate is vacuous")
# Fused-prefix tripwires: the long-context workload serves through the
# fused paged chunk-attention kernel (interpret mode) — (a) the prefix
# read must touch strictly fewer bytes than the legacy full-extent
# gather (zero savings means dead-tile skipping silently broke and
# long prompts pay O(max_prefix) HBM traffic again); (b) the fused path
# must hold the same one-executable-per-pool-key bound as the oracle;
# (c) a whole-prompt single chunk through the kernel must stay
# bit-identical to one-shot prefill (f32) — the empty-prefix
# merge-weight contract.
if lc["prefix_attn_bytes_saved"] <= 0:
    sys.exit("FAIL: long-context workload saved zero prefix-attention "
             "bytes vs the materialized-gather baseline — dead-tile "
             "skipping in the fused prefill kernel is broken")
if lc["prefill_compiles"] > lc["compile_bound"]:
    sys.exit(f"FAIL: long-context fused prefill compiled "
             f"{lc['prefill_compiles']}x (documented bound: "
             f"{lc['compile_bound']} per pool key)")
if not lc["whole_prompt_bitexact"]:
    sys.exit("FAIL: whole-prompt single chunk through the fused kernel "
             "is no longer bit-identical to one-shot prefill")
# Fault-isolation tripwires: (a) a fault may fail at most its own
# request / sampling group — a larger blast radius means isolation
# regressed into batch-wide failure; (b) a faulted run must drain with
# every lease released — leaked blocks would slowly strangle the pool;
# (c) the fault layer's hooks must be free when idle, and survivors of
# a faulted run must sample the exact streams of a fault-free run (the
# per-row keyed PRNG contract) — any drift means the fault layer itself
# perturbs serving.
if ft["injected_faults"] <= 0 or ft["requests_failed"] <= 0:
    sys.exit("FAIL: fault-tolerance workload injected nothing — the "
             "gates below are vacuous")
if ft["blast_radius_max"] > 1:
    sys.exit(f"FAIL: a single fault failed {ft['blast_radius_max']} "
             f"requests ({ft['failed_by_kind']}) — blast radius must "
             f"stay within the implicated request/sampling group")
if ft["leaked_blocks"] != 0 or not ft["audit_clean"]:
    sys.exit(f"FAIL: faulted drain leaked {ft['leaked_blocks']} blocks "
             f"(audit clean: {ft['audit_clean']})")
if not ft["faultfree_bitexact"]:
    sys.exit("FAIL: enabling the fault layer with an empty plan changed "
             "token streams — the hooks are not free when idle")
if not ft["survivors_bitexact"]:
    sys.exit("FAIL: surviving requests of the faulted run diverged from "
             "the fault-free streams — fault isolation is not bit-exact")
sd = bench["spec_decode"]
print(f"  spec decode: accept_ratio={sd['accept_ratio']:.2f} "
      f"({sd['accepted_tokens']}/{sd['draft_tokens']} drafts, "
      f"{sd['spec_rollbacks']} rollbacks) "
      f"steps_per_token={sd['steps_per_token']:.3f} "
      f"compiles={sd['verify_compiles']} (bound {sd['compile_bound']}) "
      f"bitexact={sd['greedy_bitexact'] and sd['mixed_greedy_bitexact']} "
      f"tok/J={sd['tokens_per_joule']:.0f} "
      f"(non-spec {sd['tokens_per_joule_nonspec']:.0f})")
# Speculative-decoding tripwires: (a) the greedy repetitive-suffix
# workload is the n-gram proposer's sweet spot — zero acceptance means
# drafting or the acceptance walk silently broke; (b) speculation must
# actually reduce per-sequence device steps below one-per-token, or the
# whole mechanism is overhead; (c) greedy speculative streams must stay
# bit-identical to non-speculative serving, solo AND mixed with sampled
# traffic (drafts may only decide how many tokens land, never which);
# (d) the verify chunk must hold the one-executable-per-pool-key bound.
if sd["draft_tokens"] <= 0 or sd["accept_ratio"] <= 0:
    sys.exit("FAIL: spec-decode workload accepted zero draft tokens on "
             "the repetitive-suffix greedy workload — drafting or the "
             "acceptance walk is broken")
if sd["steps_per_token"] >= 1.0:
    sys.exit(f"FAIL: speculative serving took "
             f"{sd['steps_per_token']:.3f} device steps per emitted "
             f"token (>= 1.0) — speculation is pure overhead on its "
             f"own sweet-spot workload")
if not sd["greedy_bitexact"] or not sd["mixed_greedy_bitexact"]:
    sys.exit(f"FAIL: greedy speculative streams diverged from "
             f"non-speculative serving (solo {sd['greedy_bitexact']}, "
             f"mixed {sd['mixed_greedy_bitexact']})")
if sd["verify_compiles"] > sd["compile_bound"]:
    sys.exit(f"FAIL: verify chunk compiled {sd['verify_compiles']}x "
             f"(documented bound: {sd['compile_bound']} per pool key)")
ol = bench["open_loop"]
print(f"  open loop: goodput={ol['goodput_tok_s']:.1f} tok/s "
      f"({ol['goodput_req_s']:.2f} req/s ok at offered "
      f"{ol['arrival_rate_req_s']:.2f} req/s) "
      f"ttft_ms p50={ol['ttft_ms_p50']:.1f} p99={ol['ttft_ms_p99']:.1f} "
      f"tpot_ms p50={ol['tpot_ms_p50']:.1f} "
      f"midflight={ol['midflight_submits']} "
      f"bitexact={ol['closed_vs_open_bitexact']} "
      f"neg_samples={ol['neg_latency_samples']} "
      f"compiles={ol['prefill_compiles']} (bound {ol['compile_bound']})")
# Open-loop tripwires: (a) continuous Poisson arrivals must complete
# error-free requests — zero goodput means the async front-end stalled
# or every request failed; (b) no latency sample may be negative — a
# negative TTFT/TPOT means a request with t_first_token == 0.0 (never
# produced a first token) leaked past the filter and is corrupting the
# percentiles (the serve.py latency-accounting bugfix's gate); (c) the
# open-loop streams must stay bit-identical to the closed-loop run of
# the same arrival order — mid-flight arrival must never change what a
# request samples; (d) continuous arrivals must reuse the closed pass's
# prefill executables (zero extra compiles).
if ol["goodput_tok_s"] <= 0 or ol["completed_ok"] <= 0:
    sys.exit("FAIL: open-loop workload completed no error-free tokens — "
             "the continuous-arrival front-end is broken")
if ol["neg_latency_samples"] != 0:
    sys.exit(f"FAIL: open-loop workload reports "
             f"{ol['neg_latency_samples']} negative latency samples — a "
             f"request without a first token leaked into the percentiles")
if not ol["closed_vs_open_bitexact"]:
    sys.exit("FAIL: open-loop streams diverged from the closed-loop run "
             "of the same arrival order — mid-flight arrival changed "
             "what a request sampled")
if ol["prefill_compiles"] > ol["compile_bound"]:
    sys.exit(f"FAIL: continuous arrivals compiled "
             f"{ol['prefill_compiles']} extra prefill executables "
             f"(bound {ol['compile_bound']}: reuse the closed pass's)")
mc = bench["multi_chip"]
print(f"  multi chip: mesh model={mc['mesh_model']} over "
      f"{mc['n_devices']} device(s) "
      f"bitexact={mc['streams_bitexact']} "
      f"leaked={mc['leaked_blocks']} audit_clean={mc['audit_clean']} "
      f"compiles={mc['prefill_compiles']} (bound {mc['compile_bound']})")
# Multi-chip tripwires (this pass runs on however many devices the CI
# host exposes — usually one, mesh model=1 through the same placement
# path; the dedicated multi-device lane below re-runs it at model=4):
# (a) mesh streams must match unsharded serving bit for bit (the bench
# itself raises on divergence, so this guards the flag plumbing);
# (b) the host-side allocator must be device-count-agnostic — drain
# leaves zero leases and a clean audit; (c) the chunk step stays at one
# executable per (pool key, mesh shape).
if not mc["streams_bitexact"]:
    sys.exit("FAIL: mesh-sharded streams diverged from unsharded serving")
if mc["leaked_blocks"] != 0 or not mc["audit_clean"]:
    sys.exit(f"FAIL: sharded drain leaked {mc['leaked_blocks']} blocks "
             f"(audit clean: {mc['audit_clean']})")
if mc["prefill_compiles"] > mc["compile_bound"]:
    sys.exit(f"FAIL: sharded chunk step compiled "
             f"{mc['prefill_compiles']}x (documented bound: "
             f"{mc['compile_bound']} per (pool key, mesh shape))")
EOF

# ---- multi-device lane -------------------------------------------------
# Re-run the serving tiers under 4 forced host devices (the XLA_FLAGS
# must be set before the first jax import, hence fresh processes): the
# tensor-parallel tests stop self-skipping — mesh sizes 2 and 4 execute
# for real — and the multi_chip bench serves over a model=4 mesh.
echo "=== multi-device lane (XLA_FLAGS forces 4 host devices) ==="
MD_FLAGS="--xla_force_host_platform_device_count=4"

# test_analysis's SPMD-module test must RUN here, not skip: grep the
# pytest summary for the pass (a skip also exits 0, so the exit code
# alone cannot gate the un-skip requirement).
XLA_FLAGS="$MD_FLAGS ${XLA_FLAGS:-}" python -m pytest -q \
    "tests/test_analysis.py::TestHloCollectives::test_real_lowered_module" \
    | tee "$ARTIFACTS_DIR/lane_real_lowered.txt"
grep -q "1 passed" "$ARTIFACTS_DIR/lane_real_lowered.txt" || {
    echo "FAIL: test_real_lowered_module still skips under the" \
         "multi-device lane"; exit 1; }

XLA_FLAGS="$MD_FLAGS ${XLA_FLAGS:-}" python -m pytest -q \
    tests/test_sharded_serving.py tests/test_engine_properties.py \
    tests/test_compile_stability.py tests/test_analysis.py

XLA_FLAGS="$MD_FLAGS ${XLA_FLAGS:-}" python - <<'EOF'
import json
import os
import sys
sys.path.insert(0, ".")
import jax
assert jax.device_count() >= 4, \
    f"lane misconfigured: {jax.device_count()} devices"
from benchmarks import engine_bench
art = os.environ.get("ARTIFACTS_DIR", "artifacts")
mc = engine_bench.run_multi_chip(*engine_bench._build_model())
with open(os.path.join(art, "BENCH_multi_chip.json"), "w") as fh:
    json.dump(mc, fh, indent=2)
print("CI multi-device lane summary:")
print(f"  mesh model={mc['mesh_model']} over {mc['n_devices']} devices "
      f"bitexact={mc['streams_bitexact']} leaked={mc['leaked_blocks']} "
      f"audit_clean={mc['audit_clean']} "
      f"compiles={mc['prefill_compiles']} (bound {mc['compile_bound']})")
if mc["mesh_model"] != 4:
    sys.exit(f"FAIL: lane served at mesh model={mc['mesh_model']}, not 4")
if not mc["streams_bitexact"]:
    sys.exit("FAIL: model=4 streams diverged from unsharded serving")
if mc["leaked_blocks"] != 0 or not mc["audit_clean"]:
    sys.exit(f"FAIL: model=4 drain leaked {mc['leaked_blocks']} blocks "
             f"(audit clean: {mc['audit_clean']})")
if mc["prefill_compiles"] > mc["compile_bound"]:
    sys.exit(f"FAIL: model=4 chunk step compiled "
             f"{mc['prefill_compiles']}x (bound {mc['compile_bound']})")
EOF
