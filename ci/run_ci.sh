#!/usr/bin/env bash
# Tier-1 CI: full test suite + decode-path benchmarks (interpret mode).
# Everything runs on CPU — Pallas kernels execute under interpret=True and
# the decode bench writes BENCH_decode.json for trajectory tracking.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q -x

python - <<'EOF'
import sys
sys.path.insert(0, ".")
from benchmarks import kernels_bench
kernels_bench.run()
kernels_bench.run_decode()
EOF
