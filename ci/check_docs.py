#!/usr/bin/env python
"""Docs gate for ci/run_ci.sh: every internal link in README.md and
docs/*.md must resolve, and every fenced ``python`` block in README.md
must parse — with its import lines actually importable (PYTHONPATH=src)
— so the quickstart can never silently rot as modules move.

Checked:
  * markdown links ``[text](target)`` whose target is not an absolute
    URL / mailto / pure fragment: the referenced file must exist
    relative to the linking document (fragments are stripped; a
    ``#anchor`` on an existing file passes — anchor text churn is not a
    CI concern, dead files are);
  * fenced code blocks tagged ``python``: ``compile()`` the block, then
    execute just its top-level ``import``/``from`` lines to prove the
    named modules exist in this checkout.

Exit 0 on success; nonzero with a per-problem listing otherwise.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE = re.compile(r"```python\n(.*?)```", re.S)
EXTERNAL = ("http://", "https://", "mailto:")


def doc_files():
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_links(path: pathlib.Path, problems: list) -> int:
    n = 0
    for target in LINK.findall(path.read_text()):
        if target.startswith(EXTERNAL) or target.startswith("#"):
            continue
        n += 1
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            problems.append(f"{path.relative_to(ROOT)}: dead link -> "
                            f"{target}")
    return n


def check_snippets(path: pathlib.Path, problems: list) -> int:
    n = 0
    for block in FENCE.findall(path.read_text()):
        n += 1
        try:
            compile(block, f"{path.name}:snippet{n}", "exec")
        except SyntaxError as e:
            problems.append(f"{path.relative_to(ROOT)} snippet {n}: "
                            f"does not parse: {e}")
            continue
        imports = "\n".join(
            ln for ln in block.splitlines()
            if re.match(r"(import|from)\s+\w", ln))
        try:
            exec(compile(imports, f"{path.name}:snippet{n}:imports",
                         "exec"), {})
        except Exception as e:
            problems.append(f"{path.relative_to(ROOT)} snippet {n}: "
                            f"import check failed: {e!r}")
    return n


def main() -> int:
    sys.path.insert(0, str(ROOT / "src"))
    problems: list = []
    n_links = n_snips = 0
    files = doc_files()
    missing = [name for name in ("README.md", "docs/ARCHITECTURE.md",
                                 "docs/BENCHMARKS.md")
               if not (ROOT / name).exists()]
    for name in missing:
        problems.append(f"required doc missing: {name}")
    for f in files:
        n_links += check_links(f, problems)
        n_snips += check_snippets(f, problems)
    print(f"docs check: {len(files)} files, {n_links} internal links, "
          f"{n_snips} python snippets")
    if problems:
        for p in problems:
            print(f"  FAIL {p}")
        return 1
    print("  OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
