"""jit-able train / prefill / serve steps with explicit shardings.

These are the functions the dry-run lowers for every (arch x shape x mesh)
cell and the launchers run for real.  All distribution is expressed as
GSPMD in/out shardings + a few with_sharding_constraint pins; the step
bodies are the plain model functions.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.core.policy import QuantPolicy
from repro.distribution import sharding as sh
from repro.models.model import Model, build_model
from repro.optim import adamw


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — never allocated)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """Abstract input batch for one shape cell.

    train:   token/label batch (or stub embeds for vlm/audio frontends).
    prefill: prompt batch of seq_len.
    decode:  one token per sequence + a full-length cache (built separately
             via cache_specs_struct).
    """
    b, s = cell.global_batch, cell.seq_len
    f32 = jnp.dtype("float32")
    i32 = jnp.dtype("int32")
    sd = jax.ShapeDtypeStruct

    if cell.kind == "train":
        if cfg.family == "vlm":
            return {"embeds": sd((b, s, cfg.d_model), f32),
                    "labels": sd((b, s), i32)}
        if cfg.family == "audio":
            return {"frames": sd((b, cfg.enc_seq, cfg.d_model), f32),
                    "tokens": sd((b, s), i32),
                    "labels": sd((b, s), i32)}
        return {"tokens": sd((b, s), i32), "labels": sd((b, s), i32)}

    if cell.kind == "prefill":
        if cfg.family == "vlm":
            return {"embeds": sd((b, s, cfg.d_model), f32)}
        if cfg.family == "audio":
            return {"frames": sd((b, cfg.enc_seq, cfg.d_model), f32),
                    "tokens": sd((b, s), i32)}
        return {"tokens": sd((b, s), i32)}

    # decode: one new token against a seq_len cache
    return {"tokens": sd((b,), i32)}


def cache_struct(model: Model, cell: ShapeCell):
    return jax.eval_shape(lambda: model.init_cache(cell.global_batch,
                                                   cell.seq_len))


def params_struct(model: Model, quantized: bool = False,
                  policy: Optional[QuantPolicy] = None):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    ps = jax.eval_shape(model.init, key)
    if quantized:
        ps = jax.eval_shape(
            functools.partial(model.quantize, policy=policy), ps)
    return ps


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------


def make_train_step(model: Model, ocfg: adamw.AdamWConfig,
                    microbatches: int = 1):
    """Grad-accumulation train step: the global batch is split into
    ``microbatches`` sequential slices (scan), bounding activation memory
    to one microbatch while keeping the same effective batch."""
    cfg = model.cfg

    def train_step(state, batch):
        params = state["params"]

        if microbatches == 1:
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch))(params)
        else:
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]),
                batch)

            def micro(acc, mbatch):
                gsum, lsum = acc
                l, g = jax.value_and_grad(
                    lambda p: model.loss(p, mbatch))(params)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (g0, jnp.zeros(())), mb)
            k = float(microbatches)
            grads = jax.tree_util.tree_map(lambda g: g / k, gsum)
            loss = lsum / k

        params, opt, metrics, _ = adamw.apply_updates(
            params, state["opt"], grads, ocfg)
        metrics["loss"] = loss
        return {"params": params, "opt": opt}, metrics

    return train_step


def make_prefill_step(model: Model, max_seq: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_seq=max_seq)
    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return serve_step


def make_serve_sample_step(model: Model, temperature: float = 1.0):
    """Fused decode + communication-avoiding sampling: the (B, V) logits
    never leave their vocab shards — the Gumbel-max argmax reduces to a
    tiny cross-shard winner exchange (serving/sampling_distributed.py)."""
    from repro.serving.sampling_distributed import gumbel_argmax

    def serve_sample_step(params, cache, tokens, key):
        logits, cache = model.decode_step(params, cache, tokens)
        nxt = gumbel_argmax(key, logits, temperature)
        return nxt, cache

    return serve_sample_step


def jit_serve_sample_step(model: Model, mesh, cell: ShapeCell,
                          quantized: bool = True,
                          policy: Optional[QuantPolicy] = None):
    cfg = model.cfg
    pstruct = params_struct(model, quantized=quantized, policy=policy)
    batch_struct = input_specs(cfg, cell)
    cstruct = cache_struct(model, cell)

    pspecs = sh.param_specs(cfg, pstruct, mesh, mode="serve")
    cspecs = sh.cache_specs(cfg, cstruct, mesh)
    bdim = batch_struct["tokens"].shape[0]
    bspec = sh._best_batch_spec(cfg, mesh, bdim, "serve")
    tok_shard = NamedSharding(mesh, P(bspec))
    key_shard = NamedSharding(mesh, P())

    step = jax.jit(
        make_serve_sample_step(model),
        in_shardings=(sh.to_shardings(pspecs, mesh),
                      sh.to_shardings(cspecs, mesh), tok_shard, key_shard),
        out_shardings=(tok_shard, sh.to_shardings(cspecs, mesh)),
        donate_argnums=(1,))
    return step, pstruct, cstruct, batch_struct


# ---------------------------------------------------------------------------
# sharded jit wrappers
# ---------------------------------------------------------------------------


def train_state_specs(cfg: ModelConfig, pspecs, mesh, pstruct,
                      zero: bool = True):
    """Optimizer m/v inherit param specs; with ``zero`` the *data* axes
    additionally shard the first unsharded, divisible dim of every large
    state tensor (ZeRO-1-style optimizer-state sharding — Adam moments
    never need to be replicated across data parallel replicas)."""
    if not zero:
        opt = {"m": pspecs, "v": pspecs, "step": P()}
        return {"params": pspecs, "opt": opt}

    dp_all = sh.batch_axes_for(cfg, mesh, "train")
    dp = dp_all if len(dp_all) > 1 else dp_all[0]
    dsz = 1
    for a in (dp_all if isinstance(dp_all, tuple) else (dp_all,)):
        dsz *= mesh.shape[a]

    dp_set = set(dp_all if isinstance(dp_all, tuple) else (dp_all,))

    def zero_one(spec, leaf):
        if not isinstance(spec, P):
            return spec
        shape = leaf.shape
        parts = list(spec) + [None] * (len(shape) - len(spec))
        if int(np.prod(shape)) < (1 << 20):       # skip small tensors
            return spec
        used = set()
        for axis in parts:
            for a in (axis if isinstance(axis, tuple) else (axis,)):
                if a is not None:
                    used.add(a)
        if used & dp_set:
            return spec       # data axes already shard this tensor (EP-data)
        for i, axis in enumerate(parts):
            if axis is None and shape[i] % dsz == 0 and shape[i] >= dsz:
                parts[i] = dp
                return P(*parts)
        return spec

    flat_specs, treedef = jax.tree_util.tree_flatten(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_struct = treedef.flatten_up_to(pstruct)
    zspecs = treedef.unflatten([zero_one(s, l) for s, l in
                                zip(flat_specs, flat_struct)])
    opt = {"m": zspecs, "v": zspecs, "step": P()}
    return {"params": pspecs, "opt": opt}


def pick_microbatches(cell: ShapeCell, mesh, target_rows_per_dev: int = 2,
                      cfg=None) -> int:
    """Largest k such that batch splits evenly and each microbatch puts
    ~target rows on each data shard."""
    if cfg is not None and cfg.train_shard == "dp":
        dsz = 1
        for a in mesh.axis_names:
            dsz *= mesh.shape[a]
    else:
        dsz = sh._dp_size(mesh)
    rows_per_dev = max(cell.global_batch // dsz, 1)
    k = max(rows_per_dev // target_rows_per_dev, 1)
    while cell.global_batch % (k * dsz) and k > 1:
        k -= 1
    return k


def jit_train_step(model: Model, mesh, ocfg: adamw.AdamWConfig,
                   cell: ShapeCell, zero: bool = True,
                   microbatches: int = 0):
    """Returns (jitted step, state_struct, batch_struct, shardings)."""
    cfg = model.cfg
    if microbatches <= 0:
        microbatches = pick_microbatches(cell, mesh, cfg=model.cfg)
    pstruct = params_struct(model)
    ostruct = jax.eval_shape(adamw.init_state, pstruct)
    state_struct = {"params": pstruct, "opt": ostruct}
    batch_struct = input_specs(cfg, cell)

    pspecs = sh.param_specs(cfg, pstruct, mesh, mode="train")
    sspecs = train_state_specs(cfg, pspecs, mesh, pstruct, zero=zero)
    bspecs = sh.data_specs(cfg, batch_struct, mesh, mode="train")

    s_shard = sh.to_shardings(sspecs, mesh)
    b_shard = sh.to_shardings(bspecs, mesh)
    metric_shard = {"lr": NamedSharding(mesh, P()),
                    "grad_norm": NamedSharding(mesh, P()),
                    "step": NamedSharding(mesh, P()),
                    "loss": NamedSharding(mesh, P())}

    step = jax.jit(make_train_step(model, ocfg, microbatches),
                   in_shardings=(s_shard, b_shard),
                   out_shardings=(s_shard, metric_shard),
                   donate_argnums=(0,))
    return step, state_struct, batch_struct, (s_shard, b_shard)


def jit_prefill_step(model: Model, mesh, cell: ShapeCell,
                     quantized: bool = True,
                     policy: Optional[QuantPolicy] = None):
    cfg = model.cfg
    pstruct = params_struct(model, quantized=quantized, policy=policy)
    batch_struct = input_specs(cfg, cell)
    cstruct = cache_struct(model, cell)

    pspecs = sh.param_specs(cfg, pstruct, mesh, mode="serve")
    bspecs = sh.data_specs(cfg, batch_struct, mesh, mode="serve")
    cspecs = sh.cache_specs(cfg, cstruct, mesh)

    bdim = cell.global_batch
    bspec = sh.dp_axes(mesh) if bdim % sh._dp_size(mesh) == 0 else None
    vspec = "model" if cfg.padded_vocab() % mesh.shape["model"] == 0 else None
    logits_spec = P(bspec, vspec)                # (B, V@model)

    step = jax.jit(
        make_prefill_step(model, cell.seq_len),
        in_shardings=(sh.to_shardings(pspecs, mesh),
                      sh.to_shardings(bspecs, mesh)),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       sh.to_shardings(cspecs, mesh)))
    return step, pstruct, batch_struct


def jit_serve_step(model: Model, mesh, cell: ShapeCell,
                   quantized: bool = True,
                   policy: Optional[QuantPolicy] = None):
    cfg = model.cfg
    pstruct = params_struct(model, quantized=quantized, policy=policy)
    batch_struct = input_specs(cfg, cell)
    cstruct = cache_struct(model, cell)

    pspecs = sh.param_specs(cfg, pstruct, mesh, mode="serve")
    cspecs = sh.cache_specs(cfg, cstruct, mesh)
    dp = sh.dp_axes(mesh)
    bdim = batch_struct["tokens"].shape[0]
    bspec = dp if bdim % sh._dp_size(mesh) == 0 else None  # long_500k: B=1
    vspec = "model" if cfg.padded_vocab() % mesh.shape["model"] == 0 else None
    tok_shard = NamedSharding(mesh, P(bspec))
    logits_spec = NamedSharding(mesh, P(bspec, vspec))

    step = jax.jit(
        make_serve_step(model),
        in_shardings=(sh.to_shardings(pspecs, mesh),
                      sh.to_shardings(cspecs, mesh), tok_shard),
        out_shardings=(logits_spec, sh.to_shardings(cspecs, mesh)),
        donate_argnums=(1,))
    return step, pstruct, cstruct, batch_struct
