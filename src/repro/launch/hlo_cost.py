"""Collective-traffic analysis of optimized (post-SPMD) HLO text.

Parses ``compiled.as_text()``, finds every collective op, multiplies ops
inside ``while`` bodies by the loop trip count (extracted from the loop
condition's comparison constant — cost_analysis does NOT do this), and
converts each op to per-device ICI wire bytes with standard ring-algorithm
factors:

    all-reduce        2·b·(g-1)/g      (reduce-scatter + all-gather phases)
    all-gather        out·(g-1)/g      (each device receives all but its own)
    reduce-scatter    in·(g-1)/g  = out·(g-1)
    all-to-all        b·(g-1)/g
    collective-permute b

where g = replica-group size parsed from the op's replica_groups.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLL = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+)\[([0-9,]*)\][^ ]*\)?\s*"
    r"(all-gather|all-reduce|all-reduce-start|reduce-scatter|all-to-all|"
    r"collective-permute|collective-permute-start)\(")
_WHILE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALLS = re.compile(
    r"(?:to_apply|calls|condition|body|branch_computations)=\{?%?([\w\.\-]+)")
_ROOT_CMP = re.compile(
    r"ROOT\s+%?[\w\.\-]+\s*=\s*pred\[\]\s*compare\(([^)]*)\)"
    r".*direction=(LT|LE|GT|GE)")
_CONST_DEF = re.compile(r"%?([\w\.\-]+)\s*=\s*\w+\[\]\s*constant\((\d+)\)")
_GROUPS = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _split_computations(hlo: str) -> Dict[str, str]:
    comps: Dict[str, List[str]] = {}
    cur = None
    depth = 0
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line)
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = [line]
                depth = line.count("{") - line.count("}")
                if depth <= 0:
                    cur = None
        else:
            comps[cur].append(line)
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                cur = None
    return {k: "\n".join(v) for k, v in comps.items()}


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str, default: int) -> int:
    m = _GROUPS.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    return default


def _wire_bytes(op: str, out_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if op.startswith("all-reduce"):
        return 2.0 * out_bytes * (g - 1) / g
    if op == "all-gather":
        return out_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return float(out_bytes) * (g - 1)
    if op == "all-to-all":
        return out_bytes * (g - 1) / g
    return float(out_bytes)       # collective-permute


def _trip_count(cond_text: str) -> int:
    """Trip count of a scan-style loop: the ROOT ``compare(ind, const)``
    of the condition computation; const resolved within the computation."""
    consts = {name: int(val) for name, val in _CONST_DEF.findall(cond_text)}
    m = _ROOT_CMP.search(cond_text)
    if not m:
        return 1
    operands = [o.strip().lstrip("%") for o in m.group(1).split(",")]
    direction = m.group(2)
    for op in operands:
        # operand may carry a type prefix like "s32[] %name"
        name = op.split()[-1].lstrip("%")
        if name in consts:
            v = consts[name]
            return v + 1 if direction in ("LE", "GE") else v
    return 1


def collective_wire_bytes(hlo: str, default_group: int = 1) -> Dict[str, float]:
    """Per-device ICI wire bytes by collective kind, while-trip corrected."""
    comps = _split_computations(hlo)

    # map computation -> list of (op, bytes_wire)
    per_comp: Dict[str, List[Tuple[str, float]]] = {}
    # computation -> list of (callee, multiplier_kind)
    calls: Dict[str, List[Tuple[str, str]]] = {}
    whiles: Dict[str, List[Tuple[str, str]]] = {}

    for name, text in comps.items():
        ops = []
        for m in _COLL.finditer(text):
            dtype, dims, op = m.group(1), m.group(2), m.group(3)
            line = text[m.start(): text.find("\n", m.start())]
            g = _group_size(line, default_group)
            op_base = op.replace("-start", "")
            ops.append((op_base, _wire_bytes(op_base, _shape_bytes(dtype, dims), g)))
        per_comp[name] = ops
        whiles[name] = [(m.group(1), m.group(2))
                        for m in _WHILE.finditer(text)]
        callees = set(_CALLS.findall(text))
        calls[name] = [(c, "call") for c in callees]

    tally: Dict[str, float] = {}
    seen_stack = set()

    def visit(name: str, mult: float):
        if name not in comps or name in seen_stack:
            return
        seen_stack.add(name)
        for op, b in per_comp.get(name, []):
            tally[op] = tally.get(op, 0.0) + b * mult
        handled = set()
        for cond, body in whiles.get(name, []):
            trips = _trip_count(comps.get(cond, ""))
            visit(body, mult * trips)
            visit(cond, mult * trips)
            handled.add(body)
            handled.add(cond)
        for callee, _ in calls.get(name, []):
            if callee not in handled and callee != name:
                visit(callee, mult)
        seen_stack.discard(name)

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: sum everything once
        for name in comps:
            visit(name, 1.0)
    else:
        visit(entry, 1.0)

    tally["total"] = sum(v for k, v in tally.items() if k != "total")
    return tally
