"""Exact algorithmic FLOP counting by walking jaxprs.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
trip count (verified empirically — scan(10) and scan(20) of the same
matmul report identical flops), which undercounts layer-scanned models by
~n_layers.  Walking the jaxpr instead gives exact counts: ``scan`` eqns
carry an explicit ``length``; ``dot_general`` shapes give 2·M·N·K·batch;
remat recompute appears in the VJP jaxpr and is counted (so the
MODEL_FLOPS / executed-FLOPs ratio exposes recompute waste, as §Roofline
asks).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax._src import core as jcore


def _dot_general_flops(eqn) -> float:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = 1.0
    for d in lc:
        k *= lhs.shape[d]
    m = float(np.prod(out.shape)) if out.shape else 1.0
    return 2.0 * m * k


def _conv_flops(eqn) -> float:
    # rough: 2 * out_elems * (in_ch/g * prod(kernel_spatial))
    rhs = eqn.invars[1].aval
    out = eqn.outvars[0].aval
    kernel = float(np.prod(rhs.shape[2:])) if len(rhs.shape) > 2 else 1.0
    groups = eqn.params.get("feature_group_count", 1)
    return 2.0 * float(np.prod(out.shape)) * rhs.shape[1] * kernel / max(groups, 1)


_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr",
                    "branches", "fwd_jaxpr_thunk")


def flops_of_jaxpr(jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += _dot_general_flops(eqn)
        elif prim == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif prim == "scan":
            inner = flops_of_jaxpr(eqn.params["jaxpr"].jaxpr)
            total += inner * eqn.params["length"]
        elif prim == "while":
            # only bounded fori-style loops appear; treat as 1 (unused here)
            total += flops_of_jaxpr(eqn.params["body_jaxpr"].jaxpr)
        elif prim == "cond":
            branches = eqn.params["branches"]
            total += max(flops_of_jaxpr(b.jaxpr) for b in branches)
        else:
            for pname, pval in eqn.params.items():
                if isinstance(pval, jcore.ClosedJaxpr):
                    total += flops_of_jaxpr(pval.jaxpr)
                elif isinstance(pval, jcore.Jaxpr):
                    total += flops_of_jaxpr(pval)
                elif isinstance(pval, (tuple, list)):
                    for v in pval:
                        if isinstance(v, jcore.ClosedJaxpr):
                            total += flops_of_jaxpr(v.jaxpr)
    return total


def count_flops(fn, *args) -> float:
    """Global algorithmic FLOPs of fn(*args) (args may be ShapeDtypeStructs)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return flops_of_jaxpr(jaxpr.jaxpr)
