"""Roofline assembly: three terms per (arch x shape x mesh) cell.

Methodology (documented in EXPERIMENTS.md §Roofline):

  compute term    exact algorithmic FLOPs from the jaxpr walker
                  (launch/flops.py — scan-length aware; remat recompute
                  included), divided by chips × peak.
  memory term     analytic per-device HBM traffic model (below).
                  ``cost_analysis()['bytes accessed']`` counts while
                  bodies once, so it can only serve as a cross-check.
  collective term per-device ICI wire bytes from the optimized HLO with
                  while-trip correction (launch/hlo_cost.py), divided by
                  link bandwidth.

Analytic HBM traffic (per device, per step):

  train    opt update reads p,m,v and writes p,m,v (6·P·4B) + fwd reads
           P once per microbatch + bwd reads P (transposes) + remat
           re-reads P + grad write/read (2·P·4B)
           + activations: ~6 passes over the per-layer residual stream
           (write fwd, read/write remat, read bwd) × L layers.
  prefill  weight bytes (int8 + scales) + KV-cache write + ~4 activation
           passes per layer.
  decode   weight bytes + KV-cache read (+ write of 1 token) + O(B·D)
           activations — the paper's regime: weight/cache streaming IS
           the roofline.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeCell
from repro.core.quantization import QuantizedTensor

PEAK_FLOPS_BF16 = 197e12          # per chip
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9                    # B/s per chip
ICI_BW = 50e9                     # B/s per link
V5E_POWER_W = 170.0               # per-chip board power under load


def step_joules(bytes_moved: float, flops: float,
                power_w: float = V5E_POWER_W,
                hbm_bw: float = HBM_BW,
                peak_flops: float = PEAK_FLOPS_BF16) -> float:
    """Roofline energy for one device call: the call takes
    max(memory time, compute time) and the chip burns ``power_w`` for
    that long.  This is the serving-stack energy model — the engine
    feeds it per-step bytes (weights + live KV tiles + activations) and
    FLOPs, and the benchmark divides tokens by the accumulated joules
    (the paper's tokens/J metric, here from the analytic roofline
    rather than a power meter)."""
    t = max(bytes_moved / hbm_bw, flops / peak_flops)
    return t * power_w


def tree_bytes(tree) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            total += int(np.prod(leaf.q.shape)) * leaf.q.dtype.itemsize
            total += int(np.prod(leaf.scale.shape)) * 4
        else:
            total += int(np.prod(leaf.shape)) * jax.dtypes.canonicalize_dtype(
                leaf.dtype).itemsize
    return total


def per_device_bytes(struct, specs, mesh) -> float:
    """Per-device bytes of a pytree given its PartitionSpecs — divides each
    leaf by the product of its sharded axis sizes (exact for ep_data-style
    2-D-sharded experts, where the old /16 assumption was 16x off)."""
    from jax.sharding import PartitionSpec as P

    flat_specs, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_struct = treedef.flatten_up_to(struct)
    total = 0.0
    for spec, leaf in zip(flat_specs, flat_struct):
        nbytes = int(np.prod(leaf.shape)) * jax.dtypes.canonicalize_dtype(
            leaf.dtype).itemsize
        shards = 1
        if isinstance(spec, P):
            for axis in spec:
                for a in (axis if isinstance(axis, tuple) else (axis,)):
                    if a is not None:
                        shards *= mesh.shape[a]
        total += nbytes / shards
    return total


def analytic_bytes(cfg: ModelConfig, cell: ShapeCell, n_dev: int,
                   param_bytes_global: int, cache_bytes_global: int = 0,
                   microbatches: int = 1,
                   param_bytes_per_dev: float = 0.0) -> Dict[str, float]:
    """Per-device HBM traffic estimate (see module docstring)."""
    model_shards = 16                      # model axis of both meshes
    p_dev = param_bytes_per_dev or \
        param_bytes_global / model_shards  # params replicated over data
    b_loc = max(cell.global_batch // (n_dev // model_shards), 1)
    act_elem = 2                           # bf16 residual stream

    if cell.kind == "train":
        opt_traffic = 6 * (param_bytes_global / model_shards / 4)  # rough: m,v f32 ZeRO over data
        # params are f32 in train; read fwd (per microbatch), read bwd,
        # remat re-read, grad write+read
        w_traffic = (2 * microbatches + 3) * p_dev
        layers = max(cfg.n_layers, 1)
        act = 6 * layers * b_loc * cell.seq_len * cfg.d_model * act_elem
        total = w_traffic + opt_traffic + act
        return {"weights": w_traffic, "opt": opt_traffic, "acts": act,
                "total": total}

    if cell.kind == "prefill":
        layers = max(cfg.n_layers, 1)
        act = 4 * layers * b_loc * cell.seq_len * cfg.d_model * act_elem
        cache_w = cache_bytes_global / n_dev
        total = p_dev + act + cache_w
        return {"weights": p_dev, "acts": act, "cache": cache_w,
                "total": total}

    # decode: the paper's regime
    cache_r = cache_bytes_global / n_dev
    act = 8 * cfg.n_layers * b_loc * cfg.d_model * 4
    total = p_dev + cache_r + act
    return {"weights": p_dev, "cache": cache_r, "acts": act, "total": total}


def assemble(cfg: ModelConfig, cell: ShapeCell, n_dev: int,
             algo_flops_global: float, model_flops_global: float,
             mem: Dict[str, float], coll_bytes_dev: float,
             raw_cost: Dict[str, float]) -> Dict[str, Any]:
    flops_dev = algo_flops_global / n_dev
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = mem["total"] / HBM_BW
    t_coll = coll_bytes_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())
    # roofline fraction: useful model flops at peak vs. the achievable step
    # (meaningful for compute-bound cells); bw_fraction: how much of the
    # step is mandatory HBM streaming (the decode metric — the paper's
    # regime is weight/cache streaming, where t_memory IS the floor).
    ideal = (model_flops_global / n_dev) / PEAK_FLOPS_BF16
    return {
        "arch": cfg.arch_id, "shape": cell.name, "devices": n_dev,
        "bw_fraction": t_memory / step_time if step_time else 0.0,
        "algo_flops_global": algo_flops_global,
        "model_flops_global": model_flops_global,
        "useful_flop_ratio": model_flops_global / algo_flops_global
        if algo_flops_global else 0.0,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "est_step_time_s": step_time,
        "roofline_fraction": ideal / step_time if step_time else 0.0,
        "mem_breakdown": mem,
        "collective_bytes_dev": coll_bytes_dev,
        "raw_cost_analysis": raw_cost,
    }
