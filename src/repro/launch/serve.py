"""Serving launcher: quantize a (trained or fresh) model per the paper's
PTQ flow and serve batched requests with the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-110m \
      --reduced --requests 16 --bits 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import store
from repro.configs.base import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.models.model import build_model, count_params
from repro.serving.engine import Engine


def run(arch: str = "llama2-110m", use_reduced: bool = True,
        requests: int = 16, bits: int = 8, kv_int8: bool = False,
        max_seq: int = 512, max_new: int = 48, slots: int = 4,
        ckpt_dir: str = "", seed: int = 0, no_quant: bool = False,
        spec_tokens: int = 0, draft: str = "ngram"):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    if kv_int8:
        cfg = cfg.with_(kv_cache_dtype="int8")
    model = build_model(cfg)

    params = model.init(jax.random.PRNGKey(seed))
    if ckpt_dir:
        state_like = {"params": params}
        restored, step, _ = store.restore(ckpt_dir, {"params": params})
        params = restored["params"]
        print(f"[serve] loaded checkpoint step {step}")

    if not no_quant:
        t0 = time.perf_counter()
        params = model.quantize(params, QuantPolicy(bits=bits, min_size=512))
        print(f"[serve] Q{bits}_0 post-training quantization "
              f"in {time.perf_counter()-t0:.2f}s")

    eng = Engine(model, params, max_slots=slots, max_seq=max_seq, seed=seed,
                 spec_tokens=spec_tokens, draft_proposer=draft)
    rng = np.random.default_rng(seed)
    for _ in range(requests):
        plen = int(rng.integers(4, 32))
        prompt = rng.integers(4, cfg.vocab_size, size=plen).astype(np.int32)
        eng.submit(prompt, max_new_tokens=max_new)

    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    toks = eng.metrics["tokens_out"]
    print(f"[serve] {len(done)}/{requests} requests, {toks} tokens in "
          f"{wall:.2f}s -> {toks/wall:,.1f} tok/s wall, "
          f"{eng.throughput_tok_s():,.1f} tok/s decode-only")
    lat = [r.t_first_token - r.t_enqueue for r in done]
    if lat:
        print(f"[serve] TTFT p50 {np.median(lat)*1e3:.0f}ms  "
              f"p95 {np.percentile(lat, 95)*1e3:.0f}ms")
    joules = eng.metrics["energy_joules"]
    if joules > 0:
        print(f"[serve] roofline energy {joules:.3g} J -> "
              f"{toks/joules:,.0f} tok/J (model, not measured)")
    if spec_tokens > 0:
        print(f"[serve] speculation ({draft}, k={spec_tokens}): "
              f"accept_ratio {eng.metrics['accept_ratio']:.2f} "
              f"({eng.metrics['accepted_tokens']}"
              f"/{eng.metrics['draft_tokens']} drafts), "
              f"steps/token {eng.metrics['steps_per_token']:.3f}, "
              f"{eng.metrics['spec_rollbacks']} rollbacks")
    return eng, done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-110m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--bits", type=int, default=8, choices=(4, 8))
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--spec-tokens", type=int, default=0,
                    help="draft-then-verify speculation depth (0 = off)")
    ap.add_argument("--draft", default="ngram",
                    help="draft proposer kind (see serving/spec_decode.py)")
    ap.set_defaults(reduced=True)
    args = ap.parse_args()
    run(args.arch, args.reduced, args.requests, args.bits, args.kv_int8,
        args.max_seq, args.max_new, args.slots, args.ckpt_dir,
        no_quant=args.no_quant, spec_tokens=args.spec_tokens,
        draft=args.draft)


if __name__ == "__main__":
    main()
