"""Serving entrypoint: quantize a (trained or fresh) model per the
paper's PTQ flow and serve it with the continuous-batching engine —
closed batch by default, or an open-loop continuous-arrival stream with
per-step token streaming (`--open-loop`).

  # closed batch (drain-style, the original mode)
  PYTHONPATH=src python -m repro.launch.serve --arch llama2-110m \
      --reduced --requests 16 --bits 8

  # open loop: seeded Poisson arrivals at 0.85x measured capacity,
  # goodput + TTFT/TPOT percentiles from true arrival time
  PYTHONPATH=src python -m repro.launch.serve --reduced --requests 16 \
      --open-loop

  # same, streaming each token to stdout as it is produced
  PYTHONPATH=src python -m repro.launch.serve --reduced --requests 4 \
      --open-loop --stream
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import store
from repro.configs.base import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.models.model import build_model, count_params
from repro.serving.async_serving import (first_token_latencies,
                                         latency_summary_ms,
                                         poisson_arrivals, run_open_loop)
from repro.serving.engine import Engine


def _load_params(model, cfg, ckpt_dir: str, seed: int):
    """Init params, restoring from ``ckpt_dir`` when given.  The restore
    passes the template state (so quantized leaves round-trip through
    their own container type) and verifies the step it loaded is the
    latest one on disk — a stale or missing step directory should fail
    loudly here, not serve silently-old weights."""
    params = model.init(jax.random.PRNGKey(seed))
    if ckpt_dir:
        state_like = {"params": params}
        restored, step, _ = store.restore(ckpt_dir, state_like)
        latest = store.latest_step(ckpt_dir)
        if step != latest:
            raise RuntimeError(
                f"restored step {step} from {ckpt_dir} but latest on "
                f"disk is {latest}")
        params = restored["params"]
        print(f"[serve] restored checkpoint step {step} from {ckpt_dir} "
              f"(latest on disk)")
    return params


def _make_prompts(rng, cfg, n: int):
    return [rng.integers(4, cfg.vocab_size,
                         size=int(rng.integers(4, 32))).astype(np.int32)
            for _ in range(n)]


def _print_throughput(eng, toks: int, wall: float) -> None:
    # two figures, each saying what it measures: the wall-clock number
    # spans prefill + decode + host work end to end; the engine's
    # throughput_tok_s() is decode-only (tokens_out / t_decode) and is
    # what BENCH_engine.json gates as decode_tok_s.
    print(f"[serve] throughput: {toks/wall:,.1f} tok/s end-to-end "
          f"wall-clock | {eng.throughput_tok_s():,.1f} tok/s decode-only "
          f"(tokens_out/t_decode; the bench-gated figure)")


def run(arch: str = "llama2-110m", use_reduced: bool = True,
        requests: int = 16, bits: int = 8, kv_int8: bool = False,
        max_seq: int = 512, max_new: int = 48, slots: int = 4,
        ckpt_dir: str = "", seed: int = 0, no_quant: bool = False,
        spec_tokens: int = 0, draft: str = "ngram",
        open_loop: bool = False, rate: float = 0.0,
        load_factor: float = 0.85, stream: bool = False,
        stream_interval: int = 1, mesh_size: int = 0):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    if kv_int8:
        cfg = cfg.with_(kv_cache_dtype="int8")
    model = build_model(cfg)
    params = _load_params(model, cfg, ckpt_dir, seed)

    mesh = None
    if mesh_size > 0:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(mesh_size)
        print(f"[serve] tensor-parallel mesh: model={mesh_size} "
              f"({len(mesh.devices.flat)} devices; KV pool sharded on "
              f"KV heads, streams bit-identical to unsharded)")

    if not no_quant:
        t0 = time.perf_counter()
        params = model.quantize(params, QuantPolicy(bits=bits, min_size=512))
        print(f"[serve] Q{bits}_0 post-training quantization "
              f"in {time.perf_counter()-t0:.2f}s")

    def make_engine():
        return Engine(model, params, max_slots=slots, max_seq=max_seq,
                      seed=seed, spec_tokens=spec_tokens,
                      draft_proposer=draft, mesh=mesh)

    rng = np.random.default_rng(seed)
    prompts = _make_prompts(rng, cfg, requests)
    if open_loop:
        return _run_open_loop(make_engine, prompts, max_new, seed, rate,
                              load_factor, stream, stream_interval)

    eng = make_engine()
    for prompt in prompts:
        eng.submit(prompt, max_new_tokens=max_new)
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    toks = eng.metrics["tokens_out"]
    print(f"[serve] {len(done)}/{requests} requests, {toks} tokens "
          f"in {wall:.2f}s")
    _print_throughput(eng, toks, wall)
    # exclude requests that never produced a first token (errored or
    # rejected keep t_first_token == 0.0; their "latency" would be a
    # huge negative sample that corrupts the percentiles)
    lat = first_token_latencies(done)
    if len(lat):
        print(f"[serve] TTFT p50 {np.median(lat)*1e3:.0f}ms  "
              f"p95 {np.percentile(lat, 95)*1e3:.0f}ms "
              f"(from arrival, {len(lat)}/{len(done)} with first token)")
    joules = eng.metrics["energy_joules"]
    if joules > 0:
        print(f"[serve] roofline energy {joules:.3g} J -> "
              f"{toks/joules:,.0f} tok/J (model, not measured)")
    if spec_tokens > 0:
        print(f"[serve] speculation ({draft}, k={spec_tokens}): "
              f"accept_ratio {eng.metrics['accept_ratio']:.2f} "
              f"({eng.metrics['accepted_tokens']}"
              f"/{eng.metrics['draft_tokens']} drafts), "
              f"steps/token {eng.metrics['steps_per_token']:.3f}, "
              f"{eng.metrics['spec_rollbacks']} rollbacks")
    return eng, done


def _run_open_loop(make_engine, prompts, max_new: int, seed: int,
                   rate: float, load_factor: float, stream: bool,
                   stream_interval: int):
    """Continuous-arrival serving: requests arrive mid-flight on a
    seeded Poisson process and tokens stream back per step.  When no
    ``--rate`` is given, a short closed-loop calibration pass measures
    service capacity and the arrival rate is set to ``load_factor`` of
    it — loaded enough that queueing delay is visible, stable enough
    that the queue drains."""
    if rate <= 0:
        n_cal = min(4, len(prompts))
        cal = make_engine()
        for p in prompts[:n_cal]:
            cal.submit(p, max_new_tokens=max_new)
        t0 = time.perf_counter()
        cal.run()
        cal_wall = max(time.perf_counter() - t0, 1e-6)
        rate = load_factor * n_cal / cal_wall
        print(f"[serve] calibrated: {n_cal} requests in {cal_wall:.2f}s "
              f"-> open-loop arrival rate {rate:.2f} req/s "
              f"({load_factor:.0%} of measured capacity)")

    on_token = None
    if stream:
        def on_token(handle, sibling, tokens, done):
            for t in tokens:
                print(f"[stream] uid={handle.uid} sib={sibling} tok={t}")
            if done:
                tag = "ok" if handle.error is None else handle.error_kind
                print(f"[stream] uid={handle.uid} done ({tag})")

    arrivals = poisson_arrivals(seed, len(prompts), rate)
    workload = [(float(t), p, {"max_new_tokens": max_new, "seed": seed + i})
                for i, (t, p) in enumerate(zip(arrivals, prompts))]
    eng = make_engine()
    t0 = time.perf_counter()
    handles, report = run_open_loop(
        eng, workload, stream_interval_steps=stream_interval,
        on_token=on_token)
    wall = time.perf_counter() - t0
    toks = eng.metrics["tokens_out"]
    print(f"[serve] open loop: {report.completed_ok}/{report.n_requests} "
          f"ok ({report.failed} failed), {report.midflight_submits} "
          f"arrivals landed mid-flight, peak queue depth "
          f"{report.peak_queue_depth}")
    print(f"[serve] goodput {report.goodput_tok_s:,.1f} tok/s "
          f"({report.goodput_req_s:.2f} req/s) at offered "
          f"{report.arrival_rate_req_s:.2f} req/s over {report.wall_s:.2f}s")
    print(f"[serve] TTFT p50 {report.ttft_ms['p50']:.0f}ms "
          f"p99 {report.ttft_ms['p99']:.0f}ms | TPOT p50 "
          f"{report.tpot_ms['p50']:.1f}ms p99 {report.tpot_ms['p99']:.1f}ms "
          f"(from true arrival time)")
    _print_throughput(eng, toks, wall)
    return eng, [h.req for h in handles]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-110m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--bits", type=int, default=8, choices=(4, 8))
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--spec-tokens", type=int, default=0,
                    help="draft-then-verify speculation depth (0 = off)")
    ap.add_argument("--draft", default="ngram",
                    help="draft proposer kind (see serving/spec_decode.py)")
    ap.add_argument("--open-loop", action="store_true",
                    help="continuous Poisson arrivals instead of a "
                         "closed batch; reports goodput and TTFT/TPOT "
                         "percentiles from true arrival time")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="open-loop arrival rate in req/s "
                         "(0 = calibrate to --load-factor of capacity)")
    ap.add_argument("--load-factor", type=float, default=0.85,
                    help="target utilization for rate calibration")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they stream back per step")
    ap.add_argument("--stream-interval", type=int, default=1,
                    help="flush streamed tokens every N engine steps")
    ap.add_argument("--mesh", type=int, default=0,
                    help="tensor-parallel mesh size over the model axis "
                         "(0 = single-device serving; needs that many "
                         "devices, e.g. XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.set_defaults(reduced=True)
    args = ap.parse_args()
    run(args.arch, args.reduced, args.requests, args.bits, args.kv_int8,
        args.max_seq, args.max_new, args.slots, args.ckpt_dir,
        no_quant=args.no_quant, spec_tokens=args.spec_tokens,
        draft=args.draft, open_loop=args.open_loop, rate=args.rate,
        load_factor=args.load_factor, stream=args.stream,
        stream_interval=args.stream_interval, mesh_size=args.mesh)


if __name__ == "__main__":
    main()
