"""Training launcher: end-to-end driver with checkpoint/restart, heartbeat,
straggler tracking, and (simulated) elastic recovery.

On this container it runs a reduced config on the host mesh; on a real
cluster the same file runs per-host with ``jax.distributed.initialize``
(the mesh/runtime objects are identical — see runtime/health.py).

  PYTHONPATH=src python -m repro.launch.train --arch llama2-110m \
      --steps 200 --reduced --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.checkpoint import store
from repro.data.pipeline import DataConfig, SyntheticTinyStories
from repro.launch import mesh as meshlib
from repro.launch import steps as steplib
from repro.configs.base import ShapeCell
from repro.models.model import build_model, count_params
from repro.optim import adamw
from repro.runtime.health import HeartbeatMonitor, StragglerDetector


def run(arch: str = "llama2-110m", steps: int = 100, batch: int = 8,
        seq: int = 256, use_reduced: bool = True, ckpt_dir: str = "",
        ckpt_every: int = 50, seed: int = 0, log_every: int = 10,
        microbatches: int = 1, grad_compress: bool = False):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    mesh = meshlib.make_host_mesh()
    cell = ShapeCell("custom", seq, batch, "train")
    ocfg = adamw.AdamWConfig(warmup_steps=min(20, steps // 5 + 1),
                             decay_steps=max(steps, 2),
                             grad_compress_bits=8 if grad_compress else 0)

    with mesh:
        step_fn, state_struct, _, (s_shard, _) = steplib.jit_train_step(
            model, mesh, ocfg, cell, zero=False, microbatches=microbatches)

        data = SyntheticTinyStories(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=seq, batch_size=batch,
            seed=seed))
        it = data.batches()

        start_step = 0
        if ckpt_dir and store.latest_step(ckpt_dir) is not None:
            state_np, start_step, extra = store.restore(ckpt_dir, state_struct)
            state = jax.device_put(state_np, s_shard)
            if "data_state" in extra:
                data.restore(extra["data_state"])
            print(f"[train] resumed from step {start_step}")
        else:
            params = model.init(jax.random.PRNGKey(seed))
            state = {"params": params, "opt": adamw.init_state(params)}
            state = jax.device_put(state, s_shard)

        hb = HeartbeatMonitor(n_hosts=jax.process_count())
        straggle = StragglerDetector(n_hosts=jax.process_count())
        n_params = count_params(state_struct["params"])
        print(f"[train] {arch}: {n_params/1e6:.1f}M params, "
              f"{steps} steps, batch {batch} x seq {seq}")

        losses = []
        writer = None
        for s in range(start_step, steps):
            t0 = time.perf_counter()
            batch_np = next(it)
            state, metrics = step_fn(state, batch_np)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            hb.beat(jax.process_index(), s)
            straggle.record(jax.process_index(), dt)
            if s % log_every == 0 or s == steps - 1:
                tok_s = batch * seq / dt
                print(f"[train] step {s:5d} loss {loss:8.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{tok_s:,.0f} tok/s", flush=True)
            if ckpt_dir and (s + 1) % ckpt_every == 0:
                if writer is not None:
                    writer.join()
                writer = store.save(
                    ckpt_dir, s + 1, state,
                    extra={"data_state": data.state(), "loss": loss},
                    async_=True)
        if writer is not None:
            writer.join()
        if ckpt_dir:
            store.prune(ckpt_dir)
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-110m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.set_defaults(reduced=True)
    args = ap.parse_args()
    losses = run(args.arch, args.steps, args.batch, args.seq, args.reduced,
                 args.ckpt_dir, args.ckpt_every,
                 microbatches=args.microbatches,
                 grad_compress=args.grad_compress)
    print(f"[train] final loss {losses[-1]:.4f} "
          f"(start {losses[0]:.4f}, min {min(losses):.4f})")


if __name__ == "__main__":
    main()
