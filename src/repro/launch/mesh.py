"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis is
pure data parallelism across the slower inter-pod (DCN/ICI-X) links, so
only gradient all-reduces cross it.

Defined as functions, not module constants: importing this module must not
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""

from __future__ import annotations

import jax


def _mk(shape, axes):
    # AxisType landed after jax 0.4.37; Auto is the default there anyway.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (1 on this container) — used by
    smoke tests and CPU benchmarks."""
    n = len(jax.devices())
    return _mk((1, n), ("data", "model"))


def make_serve_mesh(model_size: int | None = None):
    """Serving mesh: (data=1, model=n) over the first n local devices.

    Unlike ``make_host_mesh`` this takes an explicit model-axis size so a
    4-device host can also build 1- and 2-wide meshes (the sharded-serving
    test tier compares them).  jax.make_mesh always consumes all devices,
    so build the Mesh over an explicit device subset."""
    import numpy as np

    devs = jax.devices()
    n = len(devs) if model_size is None else int(model_size)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"mesh model_size={n} needs 1..{len(devs)} devices")
    arr = np.asarray(devs[:n]).reshape(1, n)
    axes = ("data", "model")
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.sharding.Mesh(
            arr, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.sharding.Mesh(arr, axes)


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the batch dim: everything except 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]
