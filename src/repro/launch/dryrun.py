import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 TPU v5e pods, and
``jax.jit(...).lower().compile()`` must succeed for every cell.  The
compiled artifact + jaxpr yield the §Roofline inputs:

  * launch/flops.py      exact algorithmic FLOPs (scan-aware; XLA's
                         cost_analysis counts while bodies once — verified
                         — so it cannot be used directly),
  * launch/hlo_cost.py   per-device ICI wire bytes from the optimized HLO
                         with while-trip correction,
  * launch/roofline.py   analytic HBM traffic + term assembly,
  * ``memory_analysis()``  per-device allocation (fits-check; note the
                         CPU backend allocator over-reports temps vs the
                         TPU layout-aware allocator).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun
"""

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_configs, shapes_for
from repro.launch import flops as flopslib
from repro.launch import hlo_cost, roofline
from repro.launch import mesh as meshlib
from repro.launch import steps as steplib
from repro.models.model import build_model, count_params
from repro.optim import adamw


def model_flops(cfg, cell, pstruct) -> float:
    """MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (infer)."""
    n_total = count_params(pstruct)
    n_active = n_total
    if cfg.n_experts and cfg.top_k:
        n_pat = cfg.n_layers // cfg.moe_every
        per_expert = 3 * cfg.d_ff * cfg.d_model
        n_active = n_total - n_pat * (cfg.n_experts - cfg.top_k) * per_expert
    if cell.kind == "train":
        return 6.0 * n_active * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n_active * cell.global_batch * cell.seq_len
    return 2.0 * n_active * cell.global_batch      # one decode step


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               quantized: bool = True, zero: bool = True,
               cfg_overrides: dict | None = None, microbatches: int = 0,
               quant_bits: int = 8):
    """Lower + compile one cell.

    Returns (compiled, flops_fn, cfg, cell, pstruct, cstruct) where
    flops_fn() lazily computes the exact algorithmic FLOPs via jaxpr.
    """
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    cells = {c.name: c for c in shapes_for(cfg)}
    if shape_name not in cells:
        raise SystemExit(
            f"{arch} skips {shape_name} (see DESIGN.md §Arch-applicability)")
    cell = cells[shape_name]
    model = build_model(cfg)
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)

    with mesh:
        if cell.kind == "train":
            step, state_s, batch_s, _ = steplib.jit_train_step(
                model, mesh, adamw.AdamWConfig(), cell, zero=zero,
                microbatches=microbatches)
            lowered = step.lower(state_s, batch_s)
            raw = steplib.make_train_step(
                model, adamw.AdamWConfig(),
                microbatches or steplib.pick_microbatches(cell, mesh,
                                                          cfg=cfg))
            flops_fn = lambda: flopslib.count_flops(raw, state_s, batch_s)
            pstruct = state_s["params"]
            cstruct = None
        elif cell.kind == "prefill":
            from repro.core.policy import QuantPolicy
            step, pstruct, batch_s = steplib.jit_prefill_step(
                model, mesh, cell, quantized=quantized,
                policy=QuantPolicy(bits=quant_bits))
            lowered = step.lower(pstruct, batch_s)
            raw = steplib.make_prefill_step(model, cell.seq_len)
            flops_fn = lambda: flopslib.count_flops(raw, pstruct, batch_s)
            cstruct = steplib.cache_struct(model, cell)
        else:
            from repro.core.policy import QuantPolicy
            step, pstruct, cstruct, batch_s = steplib.jit_serve_step(
                model, mesh, cell, quantized=quantized,
                policy=QuantPolicy(bits=quant_bits))
            lowered = step.lower(pstruct, cstruct, batch_s["tokens"])
            raw = steplib.make_serve_step(model)
            flops_fn = lambda: flopslib.count_flops(
                raw, pstruct, cstruct, batch_s["tokens"])
        compiled = lowered.compile()
    return compiled, flops_fn, cfg, cell, pstruct, cstruct


def analyse(compiled, flops_fn, cfg, cell, pstruct, cstruct,
            n_devices: int, microbatches: int, mesh=None) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    coll = hlo_cost.collective_wire_bytes(compiled.as_text())

    algo_flops = flops_fn()
    p_bytes = roofline.tree_bytes(pstruct)
    c_bytes = roofline.tree_bytes(cstruct) if cstruct is not None else 0
    mf = model_flops(cfg, cell, pstruct)
    p_dev = 0.0
    if mesh is not None:
        from repro.distribution import sharding as shlib
        mode = "train" if cell.kind == "train" else "serve"
        pspecs = shlib.param_specs(cfg, pstruct, mesh, mode=mode)
        p_dev = roofline.per_device_bytes(pstruct, pspecs, mesh)
    membd = roofline.analytic_bytes(cfg, cell, n_devices, p_bytes, c_bytes,
                                    microbatches, param_bytes_per_dev=p_dev)

    rec = roofline.assemble(cfg, cell, n_devices, algo_flops, mf, membd,
                            coll["total"],
                            {"flops_while_once": float(cost.get("flops", 0)),
                             "bytes_while_once": float(
                                 cost.get("bytes accessed", 0))})
    rec["collective_breakdown"] = coll
    rec["param_bytes_global"] = p_bytes
    rec["cache_bytes_global"] = c_bytes
    rec["microbatches"] = microbatches
    try:
        rec["memory_analysis"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
        }
    except Exception:
        rec["memory_analysis"] = {}
    return rec


def run_cell(arch, shape, multi_pod, out_dir=None, quantized=True,
             zero=True, overrides=None, microbatches: int = 0,
             verbose=True, tag_suffix="", quant_bits: int = 8):
    t0 = time.time()
    compiled, flops_fn, cfg, cell, pstruct, cstruct = lower_cell(
        arch, shape, multi_pod, quantized=quantized, zero=zero,
        cfg_overrides=overrides, microbatches=microbatches,
        quant_bits=quant_bits)
    n_dev = 512 if multi_pod else 256
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    mb = microbatches or (steplib.pick_microbatches(cell, mesh, cfg=cfg)
                          if cell.kind == "train" else 1)
    rec = analyse(compiled, flops_fn, cfg, cell, pstruct, cstruct, n_dev, mb,
                  mesh=mesh)
    rec["compile_s"] = round(time.time() - t0, 1)
    rec["multi_pod"] = multi_pod
    if verbose:
        print(json.dumps(rec, indent=2, default=str))
    if out_dir:
        out = Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}__{shape}__{'2pod' if multi_pod else '1pod'}{tag_suffix}"
        (out / f"{tag}.json").write_text(json.dumps(rec, indent=2,
                                                    default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-quant", action="store_true",
                    help="serve cells with float weights (paper-baseline "
                         "comparison)")
    args = ap.parse_args()

    pods = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod]

    if args.all:
        targets = []
        for arch in list_configs():
            if arch == "llama2-110m":
                continue        # the paper model is benchmarked, not dry-run
            cfg = get_config(arch)
            for cell in shapes_for(cfg):
                targets.append((arch, cell.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch/--shape or --all required")
        targets = [(args.arch, args.shape)]

    failures = []
    for arch, shape in targets:
        for mp in pods:
            tag = f"{arch} x {shape} x {'2pod' if mp else '1pod'}"
            done = Path(args.out) / \
                f"{arch}__{shape}__{'2pod' if mp else '1pod'}.json"
            if args.all and done.exists():
                print(f"[skip cached] {tag}", flush=True)
                continue
            print(f"=== {tag} ===", flush=True)
            try:
                t0 = time.time()
                run_cell(arch, shape, mp, out_dir=args.out,
                         quantized=not args.no_quant, verbose=False)
                print(f"    OK ({time.time()-t0:.0f}s)", flush=True)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((tag, repr(e)[:500]))
                print(f"    FAIL {tag}: {repr(e)[:300]}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" -", t, e)
        sys.exit(1)
    print("\nall cells compiled OK")


if __name__ == "__main__":
    main()
