"""Decoder-only LM covering dense / MoE / SSM / hybrid / VLM families.

One functional model, driven entirely by ``ModelConfig``:

  * params are *stacked per layer* and iterated with ``lax.scan`` — the HLO
    stays one-block-sized regardless of depth (critical for the 512-device
    dry-run compiles on this 1-CPU container, and for TPU compile times),
  * every weight leaf may be a float array (training) or a
    ``QuantizedTensor`` (post-training-quantized serving) — ``qdot``
    dispatches, so the paper's PTQ flow reuses the same forward code,
  * decode keeps a KV cache that is optionally Q8_0-quantized per
    (position, kv-head) — the beyond-paper extension that matters at 32k+.
"""

from __future__ import annotations

import functools
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.core.qlinear import qdot, qeinsum
from repro.core.quantization import (QuantizedTensor, qt_concat,
                                     quantize_rows,
                                     qt_fold_lead_into_groups,
                                     qt_reshape_lead)
from repro.models import layers as L
from repro.models import ssm as S

Params = Any
Cache = Dict[str, Any]


def _cdt(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_norm(cfg: ModelConfig):
    p = {"gamma": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p["beta"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def _init_attn(key, cfg: ModelConfig):
    """Head-structured weights: (H, hd, D) / (D, H, hd).

    Keeping the head axis explicit lets the `model` mesh axis shard heads
    directly (GSPMD pads non-divisible head counts) instead of resharding a
    flat H*hd dim whose shard boundaries cut through heads.
    """
    hd = cfg.hd()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = _pdt(cfg)
    sc = 1.0 / (cfg.d_model ** 0.5)
    so = 1.0 / ((cfg.n_heads * hd) ** 0.5)
    return {
        "wq": (jax.random.normal(k1, (cfg.n_heads, hd, cfg.d_model)) * sc).astype(dt),
        "wk": (jax.random.normal(k2, (cfg.n_kv_heads, hd, cfg.d_model)) * sc).astype(dt),
        "wv": (jax.random.normal(k3, (cfg.n_kv_heads, hd, cfg.d_model)) * sc).astype(dt),
        "wo": (jax.random.normal(k4, (cfg.d_model, cfg.n_heads, hd)) * so).astype(dt),
    }


def _init_mlp(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    dt = _pdt(cfg)
    if cfg.mlp_type == "gelu":
        return {"w1": L.dense_init(k1, cfg.d_ff, cfg.d_model, dt),
                "w2": L.dense_init(k2, cfg.d_model, cfg.d_ff, dt)}
    return {"w1": L.dense_init(k1, cfg.d_ff, cfg.d_model, dt),
            "w3": L.dense_init(k3, cfg.d_ff, cfg.d_model, dt),
            "w2": L.dense_init(k2, cfg.d_model, cfg.d_ff, dt)}


def _init_moe(key, cfg: ModelConfig):
    k0, k1, k2, k3 = jax.random.split(key, 4)
    dt = _pdt(cfg)
    e, f, d = cfg.n_experts, cfg.d_ff, cfg.d_model
    scale = 1.0 / (d ** 0.5)
    return {
        "router": (jax.random.normal(k0, (e, d)) * scale).astype(jnp.float32),
        "w1": (jax.random.normal(k1, (e, f, d)) * scale).astype(dt),
        "w3": (jax.random.normal(k3, (e, f, d)) * scale).astype(dt),
        "w2": (jax.random.normal(k2, (e, d, f)) * (1.0 / f ** 0.5)).astype(dt),
    }


def _init_dense_block(key, cfg: ModelConfig, moe: bool):
    k1, k2 = jax.random.split(key)
    blk = {"norm1": _init_norm(cfg), "attn": _init_attn(k1, cfg),
           "norm2": _init_norm(cfg)}
    if moe:
        blk["moe"] = _init_moe(k2, cfg)
    else:
        blk["mlp"] = _init_mlp(k2, cfg)
    return blk


def _ssm_dims(cfg: ModelConfig) -> S.SSMDims:
    return S.make_ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                           cfg.ssm_head_dim, cfg.ssm_groups, cfg.conv_width)


def _init_ssm_block(key, cfg: ModelConfig):
    return {"norm1": _init_norm(cfg),
            "ssm": S.init_mamba2_params(key, _ssm_dims(cfg), _pdt(cfg))}


def init_params(cfg: ModelConfig, key) -> Params:
    kemb, kblocks, khead, kattn = jax.random.split(key, 4)
    dt = _pdt(cfg)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(kemb, (cfg.padded_vocab(), cfg.d_model))
                  * 0.02).astype(dt),
        "final_norm": _init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(khead, cfg.padded_vocab(),
                                         cfg.d_model, dt)

    fam = cfg.family
    if fam == "moe" and cfg.moe_every > 1:
        # llama4-style interleave: pattern = [dense x (k-1), moe], repeated.
        n_pat = cfg.n_layers // cfg.moe_every
        kd, km = jax.random.split(kblocks)
        dkeys = jax.random.split(kd, n_pat * (cfg.moe_every - 1))
        dkeys = dkeys.reshape((n_pat, cfg.moe_every - 1) + dkeys.shape[1:])
        mkeys = jax.random.split(km, n_pat)
        params["blocks_dense"] = jax.vmap(jax.vmap(
            lambda k: _init_dense_block(k, cfg, moe=False)))(dkeys)
        params["blocks_moe"] = jax.vmap(
            lambda k: _init_dense_block(k, cfg, moe=True))(mkeys)
    elif fam in ("dense", "vlm", "moe"):
        keys = jax.random.split(kblocks, cfg.n_layers)
        params["blocks"] = jax.vmap(
            lambda k: _init_dense_block(k, cfg, moe=(fam == "moe")))(keys)
    elif fam == "ssm":
        keys = jax.random.split(kblocks, cfg.n_layers)
        params["blocks"] = jax.vmap(
            lambda k: _init_ssm_block(k, cfg))(keys)
    elif fam == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        n_main = n_super * cfg.attn_every
        keys = jax.random.split(kblocks, cfg.n_layers)
        all_blocks = jax.vmap(lambda k: _init_ssm_block(k, cfg))(keys)
        params["blocks_main"] = jax.tree_util.tree_map(
            lambda x: x[:n_main].reshape(n_super, cfg.attn_every, *x.shape[1:]),
            all_blocks)
        params["blocks_tail"] = jax.tree_util.tree_map(
            lambda x: x[n_main:], all_blocks)
        params["shared_attn"] = _init_dense_block(kattn, cfg, moe=False)
    else:
        raise ValueError(f"family {fam} not built here (audio -> encdec.py)")
    return params


# ---------------------------------------------------------------------------
# decode-weight fusion (7 GEMVs/layer -> 4)
# ---------------------------------------------------------------------------


def _merge_head_axes(w):
    """(*lead, H, hd, D) -> (*lead, H*hd, D); works on float or quantized."""
    if isinstance(w, QuantizedTensor):
        *lead, h, hd, _ = w.q.shape
        return qt_reshape_lead(w, *lead, h * hd)
    *lead, h, hd, d = w.shape
    return w.reshape(*lead, h * hd, d)


def _fold_head_axes(w):
    """(*lead, D, H, hd) -> (*lead, D, H*hd); works on float or quantized."""
    if isinstance(w, QuantizedTensor):
        return qt_fold_lead_into_groups(w)
    *lead, d, h, hd = w.shape
    return w.reshape(*lead, d, h * hd)


def _concat_rows(ws):
    if isinstance(ws[0], QuantizedTensor):
        return qt_concat(ws, axis=-2)
    return jnp.concatenate(ws, axis=-2)


def fuse_decode_weights(params: Params, cfg: ModelConfig) -> Params:
    """Add fused decode-GEMV operands next to the per-projection weights.

    Single-token decode is HBM-bandwidth- and launch-bound: each layer runs
    7 independent quantized GEMVs (q/k/v/o + gate/up/down), each streaming
    its weight through its own kernel call and — on the integer/pallas
    strategies — re-quantizing the same activation vector.  Fusing

        wqkv = [wq; wk; wv]  ->  ((H + 2*KVH) * hd, D)
        w13  = [w1; w3]      ->  (2 * d_ff, D)
        wo_f = wo flattened  ->  (D, H * hd)

    drops that to 4 launches and quantizes the post-norm activation once
    per fused projection.  Codes/scales are concatenated structurally
    (core.quantization qt_*), never requantized, so fused and unfused
    forward passes agree to f32 summation order.

    The walk is structural: any subtree carrying {wq, wk, wv, wo} (stacked
    per layer or not) gains ``wqkv``/``wo_f``; any plain SwiGLU mlp subtree
    gains ``w13``.  MoE expert banks (which also hold w1/w3/w2 but route
    through einsum dispatch) are left alone.  The per-projection weights
    are kept — prefill still consumes the head-structured layout; a
    production build would derive one from the other at load time.
    """

    def fusable(ws):
        """All-quantized or all-float; a min_size policy can mix kinds
        (e.g. float wk beside quantized wq) — skip fusion there."""
        kinds = {isinstance(w, QuantizedTensor) for w in ws}
        if len(kinds) > 1:
            return False
        if kinds == {True} and len({(w.group_size, w.bits) for w in ws}) > 1:
            return False
        return True

    def walk(d):
        if not isinstance(d, dict):
            return d
        out = {k: walk(v) for k, v in d.items()}
        if ({"wq", "wk", "wv", "wo"} <= set(out)
                and fusable([out["wq"], out["wk"], out["wv"]])):
            out["wqkv"] = _concat_rows([_merge_head_axes(out["wq"]),
                                        _merge_head_axes(out["wk"]),
                                        _merge_head_axes(out["wv"])])
            out["wo_f"] = _fold_head_axes(out["wo"])
        if ({"w1", "w3", "w2"} <= set(out) and "router" not in out
                and fusable([out["w1"], out["w3"]])):
            out["w13"] = _concat_rows([out["w1"], out["w3"]])
        return out

    return walk(params)


# ---------------------------------------------------------------------------
# rope helpers
# ---------------------------------------------------------------------------


def _rope_cos_sin(cfg: ModelConfig, positions: jax.Array):
    """positions: (B, S) for rope, (3, B, S) for mrope; None for 'none'."""
    if cfg.rope_type == "none":
        return None
    hd = cfg.hd()
    if cfg.rope_type == "mrope":
        cos, sin = L.mrope_angles(positions, hd, cfg.rope_theta,
                                  tuple(cfg.mrope_sections))
    else:
        cos, sin = L.rope_angles(positions, hd, cfg.rope_theta)
    return cos, sin


# ---------------------------------------------------------------------------
# blocks — full-sequence (train / prefill)
# ---------------------------------------------------------------------------


def _attn_seq(p, x, cfg: ModelConfig, rope_cs, *, causal=True,
              return_kv=False):
    """x (B, S, D) -> (out, (k, v))."""
    b, s, _ = x.shape
    hd = cfg.hd()
    h = L.apply_norm(x, p["norm1"], cfg.norm_type, cfg.eps)
    q = qeinsum("bsd,hkd->bshk", h, p["attn"]["wq"])
    k = qeinsum("bsd,hkd->bshk", h, p["attn"]["wk"])
    v = qeinsum("bsd,hkd->bshk", h, p["attn"]["wv"])
    if rope_cs is not None:
        cos, sin = rope_cs                                  # (B, S, hd)
        q = L.apply_rope(q, cos[:, :, None], sin[:, :, None])
        k = L.apply_rope(k, cos[:, :, None], sin[:, :, None])
    q = q * (hd ** -0.5)
    acfg = L.AttnConfig(cfg.n_heads, cfg.n_kv_heads, hd, causal=causal,
                        q_chunk=cfg.q_chunk)
    out = L.attention_scores_blockwise(q, k, v, acfg)
    out = qeinsum("bshk,dhk->bsd", out, p["attn"]["wo"])
    return out.astype(x.dtype), ((k, v) if return_kv else None)


def _mlp_or_moe(p, x, cfg: ModelConfig, decode: bool = False):
    h = L.apply_norm(x, p["norm2"], cfg.norm_type, cfg.eps)
    if "moe" in p:
        return L.moe_mlp(p["moe"], h, n_experts=cfg.n_experts,
                         top_k=cfg.top_k, group_size=cfg.moe_group,
                         capacity_factor=cfg.capacity_factor,
                         dense_dispatch=decode).astype(x.dtype)
    if cfg.mlp_type == "gelu":
        return L.gelu_mlp(p["mlp"], h)
    return L.swiglu_mlp(p["mlp"], h)


def _dense_block_seq(p, x, cfg: ModelConfig, rope_cs, causal=True,
                     return_kv=False):
    a, kv = _attn_seq(p, x, cfg, rope_cs, causal=causal, return_kv=return_kv)
    x = x + a
    x = x + _mlp_or_moe(p, x, cfg)
    return x, kv


def _ssm_block_seq(p, x, cfg: ModelConfig, conv_state=None, ssm_state=None):
    h = L.apply_norm(x, p["norm1"], cfg.norm_type, cfg.eps)
    y, (new_conv, new_ssm) = S.mamba2_forward(
        p["ssm"], h, _ssm_dims(cfg), cfg.ssm_chunk, conv_state, ssm_state)
    return x + y, (new_conv, new_ssm)


# ---------------------------------------------------------------------------
# backbone — full sequence
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "block":
        return jax.checkpoint(fn, prevent_cse=False)
    if cfg.remat == "dots":
        # selective: keep matmul outputs, recompute elementwise — trades
        # ~(B,S,D)-sized residuals per matmul for skipping the recompute
        # of every projection in the backward pass (§Perf lever for
        # compute-dominant train cells)
        return jax.checkpoint(
            fn, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def forward_hidden(params: Params, cfg: ModelConfig, x: jax.Array,
                   positions: jax.Array, collect_cache: bool = False):
    """x: (B, S, D) input embeddings -> (hidden (B,S,D), cache_parts)."""
    rope_cs = _rope_cos_sin(cfg, positions)
    fam = cfg.family

    if fam == "moe" and cfg.moe_every > 1:
        def one(h, lp):
            h2, kv = _dense_block_seq(lp, h, cfg, rope_cs,
                                      return_kv=collect_cache)
            return h2, kv
        one = _maybe_remat(one, cfg)

        def pat_body(h, lps):
            lp_dense, lp_moe = lps
            h, kvd = lax.scan(one, h, lp_dense)
            h, kvm = one(h, lp_moe)
            return h, (kvd, kvm)
        x, cache = lax.scan(pat_body, x,
                            (params["blocks_dense"], params["blocks_moe"]))

    elif fam in ("dense", "vlm", "moe"):
        def body(h, lp):
            h2, kv = _dense_block_seq(lp, h, cfg, rope_cs,
                                      return_kv=collect_cache)
            return h2, kv
        body = _maybe_remat(body, cfg)
        x, kvs = lax.scan(body, x, params["blocks"])
        cache = kvs                                  # ((L,B,S,KVH,hd) x2) | None

    elif fam == "ssm":
        def body(h, lp):
            h2, (cs, ss) = _ssm_block_seq(lp, h, cfg)
            return h2, (cs, ss) if collect_cache else None
        body = _maybe_remat(body, cfg)
        x, cache = lax.scan(body, x, params["blocks"])

    elif fam == "hybrid":
        def inner(h, lp):
            h2, st = _ssm_block_seq(lp, h, cfg)
            return h2, st if collect_cache else None
        inner = _maybe_remat(inner, cfg)
        shared = params["shared_attn"]

        def super_body(h, lp_super):
            h, ssm_sts = lax.scan(inner, h, lp_super)
            h, kv = _dense_block_seq(shared, h, cfg, rope_cs,
                                     return_kv=collect_cache)
            return h, (ssm_sts, kv)
        super_body = _maybe_remat(super_body, cfg)
        x, (main_sts, attn_kvs) = lax.scan(super_body, x,
                                           params["blocks_main"])
        x, tail_sts = lax.scan(inner, x, params["blocks_tail"])
        cache = (main_sts, attn_kvs, tail_sts)
    else:
        raise ValueError(fam)

    x = L.apply_norm(x, params["final_norm"], cfg.norm_type, cfg.eps)
    return x, cache


def _head_weight(params: Params, cfg: ModelConfig):
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


def embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, Any]
                 ) -> jax.Array:
    """tokens -> embeddings; VLM/audio batches carry precomputed embeds
    (the modality frontend is a stub per the assignment)."""
    if "embeds" in batch:
        return batch["embeds"].astype(_cdt(cfg))
    return L.embed_lookup(params["embed"], batch["tokens"]).astype(_cdt(cfg))


def _default_positions(cfg: ModelConfig, b: int, s: int, batch):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.rope_type == "mrope":
        pos = jnp.broadcast_to(pos, (3, b, s))
    return pos


# ---------------------------------------------------------------------------
# loss (chunked cross-entropy — logits never materialize at (B,S,V))
# ---------------------------------------------------------------------------


def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            chunk: int = 512) -> jax.Array:
    tokens_in = batch.get("tokens")
    labels = batch["labels"]                        # (B, S) int32
    b, s = labels.shape
    positions = _default_positions(cfg, b, s, batch)
    x = embed_inputs(params, cfg, batch)
    hidden, _ = forward_hidden(params, cfg, x, positions)
    w = _head_weight(params, cfg)

    c = min(chunk, s)
    while s % c:
        c -= 1
    hs = hidden.reshape(b, s // c, c, cfg.d_model)
    ls = labels.reshape(b, s // c, c)

    def ce_chunk(carry, inp):
        h, y = inp                                  # (B,c,D), (B,c)
        logits = L.lm_head(w, h)                    # (B,c,V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - tgt), None

    ce_chunk = _maybe_remat(ce_chunk, cfg)
    total, _ = lax.scan(ce_chunk, jnp.zeros((), jnp.float32),
                        (jnp.moveaxis(hs, 1, 0), jnp.moveaxis(ls, 1, 0)))
    return total / (b * s)


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def _kv_int8(cfg: ModelConfig) -> bool:
    return cfg.kv_cache_dtype == "int8"


def _quantize_kv(vec: jax.Array):
    """vec (..., hd) -> int8 codes + one f32 scale per vector (group=hd)."""
    return quantize_rows(vec)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Cache:
    hd = cfg.hd() if cfg.n_heads else 0      # SSM family: no attention
    kvd = jnp.int8 if _kv_int8(cfg) else _cdt(cfg)
    cache: Cache = {"lens": jnp.zeros((batch,), jnp.int32)}

    def attn_cache(n_layers):
        c = {"k": jnp.zeros((n_layers, batch, max_seq, cfg.n_kv_heads, hd), kvd),
             "v": jnp.zeros((n_layers, batch, max_seq, cfg.n_kv_heads, hd), kvd)}
        if _kv_int8(cfg):
            c["ks"] = jnp.zeros((n_layers, batch, max_seq, cfg.n_kv_heads),
                                jnp.float32)
            c["vs"] = jnp.zeros_like(c["ks"])
        return c

    def ssm_cache(n_layers):
        d = _ssm_dims(cfg)
        gn = d.n_groups * d.state
        w1 = cfg.conv_width - 1
        conv = (jnp.zeros((n_layers, batch, w1, d.d_inner), jnp.float32),
                jnp.zeros((n_layers, batch, w1, gn), jnp.float32),
                jnp.zeros((n_layers, batch, w1, gn), jnp.float32))
        return {"conv": conv,
                "state": jnp.zeros((n_layers, batch, d.n_heads, d.head_dim,
                                    d.state), jnp.float32)}

    fam = cfg.family
    if fam == "moe" and cfg.moe_every > 1:
        n_pat = cfg.n_layers // cfg.moe_every
        full = attn_cache(cfg.n_layers)
        cache["attn_dense"] = jax.tree_util.tree_map(
            lambda x: x[: n_pat * (cfg.moe_every - 1)].reshape(
                n_pat, cfg.moe_every - 1, *x.shape[1:]), full)
        cache["attn_moe"] = attn_cache(n_pat)
    elif fam in ("dense", "vlm", "moe"):
        cache["attn"] = attn_cache(cfg.n_layers)
    elif fam == "ssm":
        cache["ssm"] = ssm_cache(cfg.n_layers)
    elif fam == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        n_main = n_super * cfg.attn_every
        cache["ssm_main"] = jax.tree_util.tree_map(
            lambda x: x.reshape(n_super, cfg.attn_every, *x.shape[1:]),
            ssm_cache(n_main))
        cache["ssm_tail"] = ssm_cache(cfg.n_layers - n_main)
        cache["attn"] = attn_cache(n_super)
    return cache


def _store_kv(cache_layer, k, v, pos, int8: bool):
    """Write (B, KVH, hd) new k/v at per-row positions into (B,S,KVH,hd)."""
    if int8:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        upd = {"k": kq, "v": vq, "ks": ks, "vs": vs}
    else:
        upd = {"k": k.astype(cache_layer["k"].dtype),
               "v": v.astype(cache_layer["v"].dtype)}

    def write(buf, new):
        # buf (B, S, ...), new (B, ...) -> write at pos[b] per row
        return jax.vmap(
            lambda bb, nn, pp: lax.dynamic_update_slice_in_dim(
                bb, nn[None], pp, axis=0))(buf, new, pos)

    return {kk: write(cache_layer[kk], upd[kk]) if kk in upd else cache_layer[kk]
            for kk in cache_layer}


def _decode_qkv(p_attn, h, cfg: ModelConfig):
    """Post-norm hidden (B, D) -> q (B, H, hd), k/v (B, KVH, hd).

    With fused weights (fuse_decode_weights) this is ONE quantized GEMV
    against ``wqkv`` instead of three — the activation vector is read (and,
    on the integer/pallas strategies, quantized) once.
    """
    b = h.shape[0]
    hd, nh, kvh = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    if "wqkv" in p_attn:
        qkv = qdot(h, p_attn["wqkv"]).astype(h.dtype)   # (B, (H+2KVH)*hd)
        q, k, v = jnp.split(qkv, [nh * hd, (nh + kvh) * hd], axis=-1)
        return (q.reshape(b, nh, hd), k.reshape(b, kvh, hd),
                v.reshape(b, kvh, hd))
    q = qeinsum("bd,hkd->bhk", h, p_attn["wq"])
    k = qeinsum("bd,hkd->bhk", h, p_attn["wk"])
    v = qeinsum("bd,hkd->bhk", h, p_attn["wv"])
    return q, k, v


def _decode_out_proj(p_attn, out, x_dtype):
    """Attention output (B, H, hd) -> residual (B, D) via wo (fused: one
    flat GEMV against ``wo_f``)."""
    b, nh, hd = out.shape
    if "wo_f" in p_attn:
        return qdot(out.reshape(b, nh * hd), p_attn["wo_f"]).astype(x_dtype)
    return qeinsum("bhk,dhk->bd", out, p_attn["wo"]).astype(x_dtype)


def _attn_decode_layer(p, x, cfg: ModelConfig, lcache, pos, rope_cs):
    """x (B, D) single position; lcache holds (B,S,KVH,hd) buffers."""
    hd = cfg.hd()
    int8 = _kv_int8(cfg)
    h = L.apply_norm(x, p["norm1"], cfg.norm_type, cfg.eps)
    q, k, v = _decode_qkv(p["attn"], h, cfg)
    if rope_cs is not None:
        cos, sin = rope_cs                                   # (B, hd)
        q = L.apply_rope(q, cos[:, None], sin[:, None])
        k = L.apply_rope(k, cos[:, None], sin[:, None])
    lcache = _store_kv(lcache, k, v, pos, int8)
    acfg = L.AttnConfig(cfg.n_heads, cfg.n_kv_heads, hd)
    out = L.attention_decode(
        q * (hd ** -0.5), lcache["k"], lcache["v"], pos + 1, acfg,
        lcache.get("ks"), lcache.get("vs"))
    x = x + _decode_out_proj(p["attn"], out, x.dtype)
    x = x + _mlp_or_moe(p, x[:, None, :], cfg, decode=True)[:, 0]
    return x, lcache


def supports_paged_cache(cfg: ModelConfig) -> bool:
    """Paged decode covers the families whose cache is one stacked attn
    bank (dense / vlm / non-interleaved moe); ssm/hybrid state and the
    llama4-style interleave keep the dense per-slot reservation."""
    return (cfg.family in ("dense", "vlm", "moe") and cfg.moe_every <= 1
            and cfg.n_heads > 0)


def init_paged_cache(cfg: ModelConfig, batch: int, *, block_size: int = 64,
                     n_blocks: int, max_blocks_per_seq: int) -> Cache:
    """Block-pool KV cache + page table (serving/paged_cache.py layout).

    Unlike :func:`init_cache`, HBM here is ``n_blocks * block_size`` rows
    total, shared by all slots through ``page_table`` — a slot owns only
    the blocks its live length needs (allocator is host-side, in the
    engine).  ``page_table`` rows are -1 where unassigned."""
    if not supports_paged_cache(cfg):
        raise ValueError(f"paged cache unsupported for family {cfg.family}")
    hd = cfg.hd()
    kvd = jnp.int8 if _kv_int8(cfg) else _cdt(cfg)
    pool_shape = (cfg.n_layers, n_blocks, block_size, cfg.n_kv_heads, hd)
    attn = {"k": jnp.zeros(pool_shape, kvd), "v": jnp.zeros(pool_shape, kvd)}
    if _kv_int8(cfg):
        attn["ks"] = jnp.zeros(pool_shape[:-1], jnp.float32)
        attn["vs"] = jnp.zeros_like(attn["ks"])
    return {"lens": jnp.zeros((batch,), jnp.int32),
            "page_table": jnp.full((batch, max_blocks_per_seq), -1,
                                   jnp.int32),
            "attn": attn}


def _serve_mesh_helpers(cfg: ModelConfig, mesh):
    """with_sharding_constraint helpers for the storage-sharded /
    compute-replicated tensor-parallel serving scheme (mesh=None ->
    identity fns, zero cost on the single-device path).

    The scheme: the paged KV pool shards its KV-heads dim over `model`
    (per-head attention math is local — heads only mix at the wo
    contraction), weights are *stored* sharded (serve-mode param specs)
    but constrained replicated at use, and the attention output is
    constrained replicated before the wo contraction.  Every collective
    this induces is an all-gather — pure data movement, never
    arithmetic — so sharded streams stay bit-identical to unsharded
    ones (the head_dim contraction itself is never split, keeping every
    floating-point reduction in single-device summation order).

    Returns ``(crep, cpool)``: ``crep(tree)`` constrains every array
    leaf replicated; ``cpool(attn, lead)`` pins pool buffers' KV-heads
    dim to `model`, where ``lead`` counts leading unsharded dims (3 for
    the stacked (L, NB, BS, KVH, hd) pool, 2 for a per-layer slice
    inside the scan)."""
    if mesh is None:
        return (lambda t: t), (lambda attn, lead=3: attn)
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.distribution.sharding import pool_model_axis
    rep = NamedSharding(mesh, PartitionSpec())
    kvh_ax = pool_model_axis(cfg, mesh)

    def crep(t):
        return jax.tree_util.tree_map(
            lambda a: lax.with_sharding_constraint(a, rep), t)

    def cpool(attn, lead=3):
        out = {}
        for kk, buf in attn.items():
            pad = [None] * lead
            spec = (PartitionSpec(*pad, kvh_ax, None) if kk in ("k", "v")
                    else PartitionSpec(*pad, kvh_ax))
            out[kk] = lax.with_sharding_constraint(
                buf, NamedSharding(mesh, spec))
        return out

    return crep, cpool


def _attn_decode_layer_paged(p, x, cfg: ModelConfig, lcache, pt, pos,
                             rope_cs, crep=None, cpool=None):
    """One decode layer against the block pool.

    lcache: {"k"/"v": (NB, BS, KVH, hd), ["ks"/"vs": (NB, BS, KVH)]};
    pt: (B, MB) int32 page table; pos: (B,) current lengths.  The new
    token's K/V scatter into each slot's current (block, offset); released
    slots (page_table row -1) scatter out-of-bounds and are dropped, so a
    dead slot can never corrupt blocks reassigned to other sequences."""
    hd = cfg.hd()
    int8 = _kv_int8(cfg)
    h = L.apply_norm(x, p["norm1"], cfg.norm_type, cfg.eps)
    q, k, v = _decode_qkv(p["attn"], h, cfg)
    if rope_cs is not None:
        cos, sin = rope_cs
        q = L.apply_rope(q, cos[:, None], sin[:, None])
        k = L.apply_rope(k, cos[:, None], sin[:, None])

    nb, bs = lcache["k"].shape[0], lcache["k"].shape[1]
    mb = pt.shape[1]
    blk_idx = jnp.clip(pos // bs, 0, mb - 1)              # (B,)
    blk_off = pos % bs
    blk_id = jnp.take_along_axis(pt, blk_idx[:, None], axis=1)[:, 0]
    safe = jnp.where(blk_id < 0, nb, blk_id)              # nb = OOB -> drop

    lcache = dict(lcache)
    if int8:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        lcache["k"] = lcache["k"].at[safe, blk_off].set(kq, mode="drop")
        lcache["v"] = lcache["v"].at[safe, blk_off].set(vq, mode="drop")
        lcache["ks"] = lcache["ks"].at[safe, blk_off].set(ks, mode="drop")
        lcache["vs"] = lcache["vs"].at[safe, blk_off].set(vs, mode="drop")
    else:
        lcache["k"] = lcache["k"].at[safe, blk_off].set(
            k.astype(lcache["k"].dtype), mode="drop")
        lcache["v"] = lcache["v"].at[safe, blk_off].set(
            v.astype(lcache["v"].dtype), mode="drop")

    if cpool is not None:
        lcache = cpool(lcache, 2)
    acfg = L.AttnConfig(cfg.n_heads, cfg.n_kv_heads, hd)
    out = L.paged_attention_decode(
        q * (hd ** -0.5), lcache["k"], lcache["v"], pt, pos + 1, acfg,
        lcache.get("ks"), lcache.get("vs"))
    if crep is not None:
        # heads mix in the wo contraction: gather them whole first so the
        # reduction keeps single-device summation order (bitwise contract)
        out = crep(out)
    x = x + _decode_out_proj(p["attn"], out, x.dtype)
    x = x + _mlp_or_moe(p, x[:, None, :], cfg, decode=True)[:, 0]
    return x, lcache


def _decode_step_paged(params: Params, cfg: ModelConfig, cache: Cache,
                       tokens: jax.Array, positions, mesh=None
                       ) -> Tuple[jax.Array, Cache]:
    b = tokens.shape[0]
    crep, cpool = _serve_mesh_helpers(cfg, mesh)
    if mesh is not None:
        params = crep(params)
        tokens = crep(tokens)
        cache = dict(cache)
        cache["lens"] = crep(cache["lens"])
        cache["page_table"] = crep(cache["page_table"])
        cache["attn"] = cpool(cache["attn"], 3)
    pos = cache["lens"] if positions is None else positions
    if mesh is not None and positions is not None:
        pos = crep(pos)
    x = L.embed_lookup(params["embed"], tokens).astype(_cdt(cfg))
    rp = pos if cfg.rope_type != "mrope" else jnp.broadcast_to(pos, (3, b))
    rope_cs = _rope_cos_sin(cfg, rp)
    pt = cache["page_table"]
    lcrep = crep if mesh is not None else None
    lcpool = cpool if mesh is not None else None

    def body(h, inp):
        lp, lc = inp
        return _attn_decode_layer_paged(lp, h, cfg, lc, pt, pos, rope_cs,
                                        crep=lcrep, cpool=lcpool)

    x, new_attn = lax.scan(body, x, (params["blocks"], cache["attn"]))
    x = L.apply_norm(x, params["final_norm"], cfg.norm_type, cfg.eps)
    logits = L.lm_head(_head_weight(params, cfg), x)
    if mesh is not None:
        logits = crep(logits)
        new_attn = cpool(new_attn, 3)
    new_cache = dict(cache)
    new_cache["attn"] = new_attn
    # a slot with no first block is released/empty: pin its length at 0 so
    # it never re-grows an attention window over garbage between reuses
    live = pt[:, 0] >= 0
    new_cache["lens"] = jnp.where(live, pos + 1, 0)
    return logits, new_cache


def _ssm_decode_layer(p, x, cfg: ModelConfig, conv_state, ssm_state):
    h = L.apply_norm(x, p["norm1"], cfg.norm_type, cfg.eps)
    y, (cs, ss) = S.mamba2_decode_step(p["ssm"], h, _ssm_dims(cfg),
                                       conv_state, ssm_state)
    return x + y, (cs, ss)


def decode_step(params: Params, cfg: ModelConfig, cache: Cache,
                tokens: jax.Array, positions: Optional[jax.Array] = None,
                mesh=None) -> Tuple[jax.Array, Cache]:
    """tokens (B,) int32 -> (logits (B, V) f32, updated cache).

    A cache carrying a ``page_table`` (init_paged_cache) routes through the
    paged decode path; the dense per-slot reservation is the default.
    ``mesh`` (paged path only) applies the storage-sharded /
    compute-replicated serving constraints — see
    :func:`_serve_mesh_helpers`; dense decode ignores it."""
    if "page_table" in cache:
        return _decode_step_paged(params, cfg, cache, tokens, positions,
                                  mesh=mesh)
    b = tokens.shape[0]
    pos = cache["lens"] if positions is None else positions  # (B,) int32
    x = L.embed_lookup(params["embed"], tokens).astype(_cdt(cfg))

    rp = pos if cfg.rope_type != "mrope" else \
        jnp.broadcast_to(pos, (3, b))
    rope_cs = _rope_cos_sin(cfg, rp)

    fam = cfg.family
    new_cache = dict(cache)
    if fam == "moe" and cfg.moe_every > 1:
        def one(h, inp):
            lp, lc = inp
            return _attn_decode_layer(lp, h, cfg, lc, pos, rope_cs)

        def pat_body(h, inp):
            (lp_dense, lp_moe), (lc_dense, lc_moe) = inp
            h, lc_dense2 = lax.scan(one, h, (lp_dense, lc_dense))
            h, lc_moe2 = one(h, (lp_moe, lc_moe))
            return h, (lc_dense2, lc_moe2)

        x, (nd, nm) = lax.scan(
            pat_body, x,
            ((params["blocks_dense"], params["blocks_moe"]),
             (cache["attn_dense"], cache["attn_moe"])))
        new_cache["attn_dense"] = nd
        new_cache["attn_moe"] = nm

    elif fam in ("dense", "vlm", "moe"):
        def body(h, inp):
            lp, lc = inp
            h2, lc2 = _attn_decode_layer(lp, h, cfg, lc, pos, rope_cs)
            return h2, lc2
        x, new_attn = lax.scan(body, x, (params["blocks"], cache["attn"]))
        new_cache["attn"] = new_attn

    elif fam == "ssm":
        def body(h, inp):
            lp, (cs, ss) = inp
            h2, (cs2, ss2) = _ssm_decode_layer(lp, h, cfg, cs, ss)
            return h2, (cs2, ss2)
        x, (ncs, nss) = lax.scan(
            body, x, (params["blocks"],
                      (cache["ssm"]["conv"], cache["ssm"]["state"])))
        new_cache["ssm"] = {"conv": ncs, "state": nss}

    elif fam == "hybrid":
        shared = params["shared_attn"]

        def inner(h, inp):
            lp, (cs, ss) = inp
            h2, st = _ssm_decode_layer(lp, h, cfg, cs, ss)
            return h2, st

        def super_body(h, inp):
            lp_super, (ssm_c, attn_c) = inp
            h, ssm_c2 = lax.scan(inner, h, (lp_super, ssm_c))
            h, attn_c2 = _attn_decode_layer(shared, h, cfg, attn_c, pos,
                                            rope_cs)
            return h, (ssm_c2, attn_c2)

        main_sts = (cache["ssm_main"]["conv"], cache["ssm_main"]["state"])
        x, (nmain, nattn) = lax.scan(
            super_body, x,
            (params["blocks_main"], (main_sts, cache["attn"])))
        x, ntail = lax.scan(
            inner, x, (params["blocks_tail"],
                       (cache["ssm_tail"]["conv"], cache["ssm_tail"]["state"])))
        new_cache["ssm_main"] = {"conv": nmain[0], "state": nmain[1]}
        new_cache["ssm_tail"] = {"conv": ntail[0], "state": ntail[1]}
        new_cache["attn"] = nattn
    else:
        raise ValueError(fam)

    x = L.apply_norm(x, params["final_norm"], cfg.norm_type, cfg.eps)
    logits = L.lm_head(_head_weight(params, cfg), x)
    new_cache["lens"] = pos + 1
    return logits, new_cache


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            max_seq: Optional[int] = None) -> Tuple[jax.Array, Cache]:
    """Process a full prompt, build the cache, return last-token logits."""
    if "embeds" in batch:
        b, s = batch["embeds"].shape[:2]
    else:
        b, s = batch["tokens"].shape
    max_seq = max_seq or s
    positions = _default_positions(cfg, b, s, batch)
    x = embed_inputs(params, cfg, batch)
    hidden, parts = forward_hidden(params, cfg, x, positions,
                                   collect_cache=True)

    cache = init_cache(cfg, b, max_seq)
    cache["lens"] = jnp.full((b,), s, jnp.int32)
    int8 = _kv_int8(cfg)

    def fill_attn(dst, kv):
        # k/v buffers: (…lead, S, KVH, hd); scales: (…lead, S, KVH).
        k, v = kv
        dst = dict(dst)
        if int8:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            dst["k"] = dst["k"].at[..., :s, :, :].set(kq)
            dst["v"] = dst["v"].at[..., :s, :, :].set(vq)
            dst["ks"] = dst["ks"].at[..., :s, :].set(ks)
            dst["vs"] = dst["vs"].at[..., :s, :].set(vs)
            return dst
        dst["k"] = dst["k"].at[..., :s, :, :].set(k.astype(dst["k"].dtype))
        dst["v"] = dst["v"].at[..., :s, :, :].set(v.astype(dst["v"].dtype))
        return dst

    fam = cfg.family
    if fam == "moe" and cfg.moe_every > 1:
        kvd, kvm = parts           # (n_pat, me-1, B,S,…) and (n_pat, B,S,…)
        cache["attn_dense"] = fill_attn(cache["attn_dense"], kvd)
        cache["attn_moe"] = fill_attn(cache["attn_moe"], kvm)
    elif fam in ("dense", "vlm", "moe"):
        cache["attn"] = fill_attn(cache["attn"], parts)
    elif fam == "ssm":
        conv, st = parts
        cache["ssm"] = {"conv": conv, "state": st}
    elif fam == "hybrid":
        main_sts, attn_kvs, tail_sts = parts
        cache["ssm_main"] = {"conv": main_sts[0], "state": main_sts[1]}
        cache["ssm_tail"] = {"conv": tail_sts[0], "state": tail_sts[1]}
        cache["attn"] = fill_attn(cache["attn"], attn_kvs)

    logits = L.lm_head(_head_weight(params, cfg), hidden[:, -1])
    return logits, cache


def prefill_chunk(params: Params, cfg: ModelConfig, tokens_chunk: jax.Array,
                  cache: Cache, slot: int, pos_offset: int
                  ) -> Tuple[jax.Array, Cache]:
    """Prefill one prompt chunk of slot ``slot`` directly into a paged
    cache (Sarathi/vLLM-style chunked prefill).

    ``tokens_chunk`` holds ``c`` tokens at global positions
    ``pos_offset .. pos_offset + c - 1``.  The chunk's K/V rows are
    written into the slot's pool blocks at their (block, offset)
    coordinates, and its queries attend the ``pos_offset`` prefix rows
    already in the pool (read back through the page table, dequantized
    for int8 pools) plus the chunk itself, causally — ``pos_offset`` is
    *data*, threaded into rope, the causal mask and the prefix-validity
    mask (layers.attention_chunk_merge is the jnp oracle;
    kernels/flash_prefill.py carries the same per-row offsets via
    scalar prefetch on TPU).  Numerics contract, verified by
    tests/test_scheduler.py:

      * a single chunk covering the whole prompt is **bit-identical** to
        the one-shot :func:`prefill` (an empty prefix segment merges
        with weight exactly zero);
      * composed over multiple chunks, every query still reduces over
        exactly the prefix-plus-own-chunk key set — the prefix and chunk
        segments are reduced separately and merged by softmax
        renormalization, so float pools match one-shot KV rows and
        logits to last-ulp reassociation tolerance with identical
        greedy streams;
      * for int8 pools the stored codes match within the +-1 code that
        last-ulp projection differences can tip across a rounding
        boundary; cross-chunk attention additionally reads the
        requantized prefix — the same approximation the decode path
        already lives with.

    The caller must have grown the slot's block list to cover
    ``pos_offset + c`` tokens and republished ``cache["page_table"]``
    before calling (the serving scheduler does both).  Returns the
    chunk's last-position logits ``(1, V)`` and the updated cache with
    ``lens[slot] = pos_offset + c``.

    This is the single-sequence view of :func:`prefill_chunk_batch`
    (B = 1); see there for the jit/donation story.
    """
    toks = jnp.asarray(tokens_chunk, jnp.int32).reshape(1, -1)
    return prefill_chunk_batch(params, cfg, toks, cache, [slot], pos_offset)


def prefill_chunk_batch(params: Params, cfg: ModelConfig,
                        tokens_chunks: jax.Array, cache: Cache,
                        slots, pos_offsets,
                        page_table=None,
                        chunk_lens=None, mesh=None
                        ) -> Tuple[jax.Array, Cache]:
    """Prefill one prompt chunk for up to B sequences in ONE device call —
    **shape-stable**: rows may carry *different* chunk lengths and
    position offsets, so the engine batches every chunk of a step (and
    pads to a fixed ``(max_slots, prefill_chunk_tokens)`` extent) instead
    of grouping by shape.

    ``tokens_chunks`` is ``(B, c)``; ``slots`` lists B slot ids, distinct
    where valid — a negative slot marks a padding row that computes
    nothing visible (its KV writes and ``lens`` update are dropped, its
    logits row is garbage).  ``pos_offsets`` is an int or per-row (B,)
    array of each row's global start position; ``chunk_lens`` (None = all
    rows full) gives each row's valid token count — rows are masked past
    it.  Returns per-row last-valid-position logits ``(B, V)`` and the
    updated cache with ``lens[slot] = pos_offset + chunk_len`` per valid
    row.

    Everything data-like is *traced*: offsets, lengths, slot ids, block
    coordinates and each row's full page-table row (the prefix is read as
    a masked gather over the whole row rather than a ``pos_offset``-sized
    slice).  The jit compile key is therefore just the padded ``(B, c)``
    extent plus the pool shapes — **one compile per pool key**, however
    traffic mixes chunk lengths, offsets, or batch composition
    (tests/test_compile_stability.py asserts the bound; the engine
    reports it via :func:`prefill_chunk_compiles`).

    Numerics: masked keys carry exactly-zero probability mass and padded
    rows/positions never write, so a padded call is bit-identical to the
    equivalent unpadded per-shape calls — and the whole-prompt single
    chunk stays bit-identical to one-shot :func:`prefill` (f32; int8
    pools additionally match code-for-code).  For the MoE family,
    capacity-limited routing is batch-dependent (it already was under
    shape-grouped batching) — the exactness contract is stated for the
    families whose per-token compute is row-independent.

    The traced body is jitted with the cache **donated** so each call
    updates the pool in place instead of copying it.  ``page_table`` may
    carry the caller's host-side copy of ``cache["page_table"]`` (the
    engine publishes both from the same allocator state) to spare a
    device readback per call.
    """
    args = _chunk_call_args(tokens_chunks, cache, slots, pos_offsets,
                            page_table, chunk_lens)
    return _prefill_chunk_fn(cfg, prefill_fused_mode(), mesh=mesh)(
        params, cache, *args)


def verify_chunk_batch(params: Params, cfg: ModelConfig,
                       tokens_chunks: jax.Array, cache: Cache,
                       slots, pos_offsets,
                       page_table=None,
                       chunk_lens=None, mesh=None
                       ) -> Tuple[jax.Array, Cache]:
    """Multi-token speculative *verify* step: exactly
    :func:`prefill_chunk_batch` — same traced addressing, same fused /
    oracle prefix read, same KV scatter — but returning logits for **all**
    ``c`` chunk positions, ``(B, c, V)``, instead of only each row's last.

    The draft tokens of each row are fed as a k-token "chunk" at
    ``pos_offset = kv_len``; position ``j``'s logits row conditions on
    the prefix plus draft tokens ``< j``, which is what the acceptance
    rule samples from.  Rows past ``chunk_lens`` are masked as in
    prefill; their logits are garbage and must not be read.

    Kept as a *separate* jit entry (see :func:`_prefill_chunk_fn`'s
    ``all_logits`` flag) so the wide prefill extent never materializes a
    ``(B, prefill_chunk_tokens, V)`` logits tensor: the engine pads
    verify calls to the narrow ``(max_slots, spec_tokens + 1)`` extent
    and this entry holds its own one-executable-per-pool-key bound,
    probed by :func:`verify_chunk_compiles`.
    """
    args = _chunk_call_args(tokens_chunks, cache, slots, pos_offsets,
                            page_table, chunk_lens)
    return _prefill_chunk_fn(cfg, prefill_fused_mode(), True, mesh=mesh)(
        params, cache, *args)


def _chunk_call_args(tokens_chunks, cache: Cache, slots, pos_offsets,
                     page_table, chunk_lens):
    """Host-side (concrete) addressing shared by the prefill and verify
    chunk entries: each row's chunk lives at fixed (block, offset)
    coordinates in its own leased blocks; positions past the row's valid
    length scatter out of bounds (dropped), so padding can never write
    into a block another sequence leases."""
    if "page_table" not in cache:
        raise ValueError("prefill_chunk requires a paged cache "
                         "(init_paged_cache)")
    toks = jnp.asarray(tokens_chunks, jnp.int32)
    b, c = toks.shape
    slots = np.asarray(slots, np.int32).reshape(-1)
    offs = np.broadcast_to(np.asarray(pos_offsets, np.int32), (b,))
    lens = (np.full((b,), c, np.int32) if chunk_lens is None
            else np.asarray(chunk_lens, np.int32).reshape(-1))
    valid = slots >= 0
    live = slots[valid]
    if len(set(live.tolist())) != len(live):
        raise ValueError(f"slots {slots} must be distinct where valid")
    nb, bs = cache["attn"]["k"].shape[1], cache["attn"]["k"].shape[2]
    max_slots = cache["lens"].shape[0]

    pt = np.asarray(cache["page_table"] if page_table is None
                    else page_table)
    mb = pt.shape[1]
    # vectorized over rows — this runs on the host critical path every
    # step and used to be a Python loop scaling with max_slots
    live_row = valid & (lens > 0)                       # rows that write
    rows = pt[np.where(live_row, slots, 0)]             # (b, mb)
    gpos = offs[:, None] + np.arange(c, dtype=np.int32)[None]     # (b, c)
    in_len = np.arange(c, dtype=np.int32)[None] < lens[:, None]   # (b, c)
    row_blk = np.take_along_axis(rows, np.minimum(gpos // bs, mb - 1),
                                 axis=1)                # (b, c)
    mask = in_len & live_row[:, None]
    bad = ((row_blk < 0) | (gpos >= mb * bs)) & mask
    if bad.any():
        i = int(np.argmax(bad.any(axis=1)))
        raise ValueError(f"slot {slots[i]} page table does not cover "
                         f"rows [{offs[i]}, {offs[i] + lens[i]}) — "
                         "allocate blocks before prefill_chunk")
    chunk_blk = np.where(mask, row_blk, nb).astype(np.int32)
    chunk_off = np.where(mask, gpos % bs, 0).astype(np.int32)
    pt_rows = np.where(live_row[:, None],               # -1 -> 0; masked
                       np.maximum(rows, 0), 0).astype(np.int32)
    safe_slots = np.where(valid, slots, max_slots)     # OOB -> lens drop

    return (toks,
            jnp.asarray(chunk_blk),
            jnp.asarray(chunk_off),
            jnp.asarray(pt_rows),
            jnp.asarray(safe_slots),
            jnp.asarray(offs),
            jnp.asarray(np.where(valid, lens, 0)))


def prefill_fused_mode() -> str:
    """Which prefix-attention path chunked prefill uses.

    ``"kernel"`` runs the fused Pallas kernel
    (`kernels.paged_prefill_attention`): the prefix is read through the
    page table inside the kernel's index_map — O(prefix) live tiles, no
    materialized gather.  ``"oracle"`` keeps the jnp gather +
    `layers.attention_chunk_merge` reference.  ``"interpret"`` is the
    kernel in Pallas interpret mode (CPU-executable — what the parity
    tests and the bench's bit-identity probe run).

    Policy: the ``REPRO_FUSED_PREFILL`` env var (kernel/oracle/interpret,
    with on/1 and off/0 aliases) wins; default is the kernel on real TPU
    backends and the oracle elsewhere — the same dispatch rule as the
    decode kernels, so CPU test/bench numerics are unchanged by default.
    """
    v = os.environ.get("REPRO_FUSED_PREFILL", "").strip().lower()
    if v in ("kernel", "on", "1", "true"):
        return "kernel"
    if v in ("oracle", "off", "0", "false"):
        return "oracle"
    if v == "interpret":
        return "interpret"
    return "kernel" if jax.default_backend() == "tpu" else "oracle"


def prefill_chunk_compiles(cfg: ModelConfig, mesh=None) -> int:
    """How many distinct XLA executables back the chunked-prefill step
    for ``cfg`` so far in this process — the shape-stability probe.

    Counts the jit-cache entries of the traced chunk body (one per
    distinct padded extent + pool shape, i.e. per *pool key*).  The
    engine snapshots it into ``metrics["prefill_compiles"]`` /
    ``plan_log``; tests and the shape-churn benchmark assert it stays at
    one per pool key while traffic churns chunk lengths and offsets.

    ``mesh`` selects that mesh's own jitted entry — the contract under
    tensor parallelism is one executable per (pool key, mesh shape), and
    because each mesh owns a separate jit cache, churning one mesh can
    never recompile another's executables."""
    return _prefill_chunk_fn(cfg, prefill_fused_mode(),
                             mesh=mesh)._cache_size()


def verify_chunk_compiles(cfg: ModelConfig, mesh=None) -> int:
    """Same probe as :func:`prefill_chunk_compiles` for the verify entry
    (the ``all_logits=True`` twin of the chunk step).  The engine pads
    every verify call to one fixed ``(max_slots, spec_tokens + 1)``
    extent, so this too must stay at one executable per pool key."""
    return _prefill_chunk_fn(cfg, prefill_fused_mode(), True,
                             mesh=mesh)._cache_size()


@functools.lru_cache(maxsize=None)
def _prefill_chunk_fn(cfg: ModelConfig, mode: str = "oracle",
                      all_logits: bool = False, mesh=None):
    """Build (once per config + prefix-path mode) the jitted,
    cache-donating chunk step.

    All extents inside are data: ``offs``/``lens`` drive rope, the
    causal mask, key validity, the KV scatter and the ``lens`` update,
    so the compile key is only the padded shapes.  The prefix is read
    either by gathering each row's whole page-table row and masking keys
    at positions ``>= offs[row]`` (mode "oracle"), or through the fused
    `kernels.paged_prefill_attention` Pallas kernel whose index_map
    dereferences the page table under scalar prefetch and skips dead
    tiles (mode "kernel"/"interpret") — see :func:`prefill_fused_mode`.
    Either way the per-row offsets/lengths stay traced, so the
    one-compile-per-pool-key bound holds for both paths.

    ``all_logits=True`` builds the speculative-verify twin: identical
    body, but the head projects every chunk position — ``(B, c, V)`` —
    instead of gathering each row's last valid position first.  It lives
    under its own lru/jit entry so verify's narrow padded extent never
    shares (or churns) the prefill executable.

    ``mesh`` applies the storage-sharded / compute-replicated serving
    constraints (:func:`_serve_mesh_helpers`) and — being part of the
    lru key — gives every mesh its own jitted entry, so the compile
    contract is one executable per (pool key, mesh shape) and meshes
    never invalidate each other."""
    hd = cfg.hd()
    kvh = cfg.n_kv_heads
    int8 = _kv_int8(cfg)
    fused = mode != "oracle"
    if fused:
        from repro.kernels import ops as KO
    acfg = L.AttnConfig(cfg.n_heads, kvh, hd, causal=True,
                        q_chunk=cfg.q_chunk)
    crep, cpool = _serve_mesh_helpers(cfg, mesh)

    @functools.partial(jax.jit, donate_argnums=(1,))
    def run(params, cache, toks, chunk_blk, chunk_off, pt_rows, slots,
            offs, lens):
        if mesh is not None:
            params = crep(params)
            toks, chunk_blk, chunk_off, pt_rows, slots, offs, lens = crep(
                (toks, chunk_blk, chunk_off, pt_rows, slots, offs, lens))
            cache = dict(cache)
            cache["lens"] = crep(cache["lens"])
            cache["page_table"] = crep(cache["page_table"])
            cache["attn"] = cpool(cache["attn"], 3)
        b, c = toks.shape
        bs = cache["attn"]["k"].shape[2]
        mb = pt_rows.shape[1]

        q_pos = offs[:, None] + jnp.arange(c, dtype=jnp.int32)[None]
        positions = q_pos
        if cfg.rope_type == "mrope":
            positions = jnp.broadcast_to(positions, (3, b, c))
        rope_cs = _rope_cos_sin(cfg, positions)
        x = embed_inputs(params, cfg, {"tokens": toks})

        # key validity: pool row t sits at global position t (page
        # tables are position-ordered) and is live strictly below the
        # row's offset (the chunk's own keys are attended in float,
        # pre-quantization); a chunk key is live below the row's valid
        # length.
        pfx_valid = jnp.arange(mb * bs, dtype=jnp.int32)[None] < offs[:, None]
        chunk_valid = jnp.arange(c, dtype=jnp.int32)[None] < lens[:, None]

        def body(h, inp):
            lp, lc = inp
            hn = L.apply_norm(h, lp["norm1"], cfg.norm_type, cfg.eps)
            q = qeinsum("bsd,hkd->bshk", hn, lp["attn"]["wq"])
            k = qeinsum("bsd,hkd->bshk", hn, lp["attn"]["wk"])
            v = qeinsum("bsd,hkd->bshk", hn, lp["attn"]["wv"])
            if rope_cs is not None:
                cos, sin = rope_cs
                q = L.apply_rope(q, cos[:, :, None], sin[:, :, None])
                k = L.apply_rope(k, cos[:, :, None], sin[:, :, None])
            if fused:
                # prefix read through the page table inside the kernel's
                # index_map: O(offs) live tiles fetched, dead tiles
                # skipped, int8 dequantized in-kernel.  Under a mesh the
                # pool is gathered whole first — the Pallas kernel
                # addresses the full KVH extent, not a shard.
                lck = crep(lc) if mesh is not None else lc
                pfx_state = KO.paged_prefill_attention(
                    q * (hd ** -0.5), lck["k"], lck["v"], pt_rows, offs,
                    lens, lck["ks"] if int8 else None,
                    lck["vs"] if int8 else None,
                    interpret=(mode == "interpret"))
                out = L.attention_chunk_merge(q * (hd ** -0.5), None,
                                              None, k, v, acfg, q_pos,
                                              None, chunk_valid,
                                              pfx_state=pfx_state)
            else:
                # each row gathers ITS page-table row (shared blocks may
                # appear in several rows — reads never conflict); dead or
                # not-yet-written positions are masked via k_valid
                kp = lc["k"][pt_rows].reshape(b, mb * bs, kvh, hd)
                vp = lc["v"][pt_rows].reshape(b, mb * bs, kvh, hd)
                if int8:
                    kp = kp.astype(jnp.float32) * lc["ks"][pt_rows].reshape(
                        b, mb * bs, kvh)[..., None]
                    vp = vp.astype(jnp.float32) * lc["vs"][pt_rows].reshape(
                        b, mb * bs, kvh)[..., None]
                out = L.attention_chunk_merge(q * (hd ** -0.5),
                                              kp.astype(k.dtype),
                                              vp.astype(v.dtype), k, v,
                                              acfg, q_pos, pfx_valid,
                                              chunk_valid)
            if mesh is not None:
                # heads mix here: gather them whole so the wo reduction
                # keeps single-device summation order (bitwise contract)
                out = crep(out)
            out = qeinsum("bshk,dhk->bsd", out, lp["attn"]["wo"])
            h = h + out.astype(h.dtype)
            h = h + _mlp_or_moe(lp, h, cfg)

            lc = dict(lc)
            if int8:
                kq_, ks_ = _quantize_kv(k)
                vq_, vs_ = _quantize_kv(v)
                lc["k"] = lc["k"].at[chunk_blk, chunk_off].set(
                    kq_, mode="drop")
                lc["v"] = lc["v"].at[chunk_blk, chunk_off].set(
                    vq_, mode="drop")
                lc["ks"] = lc["ks"].at[chunk_blk, chunk_off].set(
                    ks_, mode="drop")
                lc["vs"] = lc["vs"].at[chunk_blk, chunk_off].set(
                    vs_, mode="drop")
            else:
                lc["k"] = lc["k"].at[chunk_blk, chunk_off].set(
                    k.astype(lc["k"].dtype), mode="drop")
                lc["v"] = lc["v"].at[chunk_blk, chunk_off].set(
                    v.astype(lc["v"].dtype), mode="drop")
            if mesh is not None:
                lc = cpool(lc, 2)
            return h, lc

        x, new_attn = lax.scan(body, x, (params["blocks"], cache["attn"]))
        x = L.apply_norm(x, params["final_norm"], cfg.norm_type, cfg.eps)
        if all_logits:
            logits = L.lm_head(_head_weight(params, cfg), x)   # (b, c, V)
        else:
            last = jnp.clip(lens - 1, 0, c - 1)
            logits = L.lm_head(_head_weight(params, cfg),
                               x[jnp.arange(b), last])
        new_cache = dict(cache)
        new_cache["attn"] = cpool(new_attn, 3) if mesh is not None \
            else new_attn
        new_cache["lens"] = cache["lens"].at[slots].set(offs + lens,
                                                       mode="drop")
        if mesh is not None:
            logits = crep(logits)
            new_cache["lens"] = crep(new_cache["lens"])
        return logits, new_cache

    return run
