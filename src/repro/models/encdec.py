"""Whisper-style encoder–decoder backbone (audio family).

Per the assignment, the conv frontend is a STUB: inputs are precomputed
frame embeddings (B, enc_seq, D).  The transformer backbone is real:
bidirectional encoder (LayerNorm + GELU, MHA) and a causal decoder with
self- and cross-attention, learned decoder positions, tied LM head.

Serving keeps two caches: the growing self-attention KV cache and the
fixed cross-attention KV computed once from the encoder output — both are
int8-quantizable like the decoder-only models.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core.qlinear import qdot, qeinsum
from repro.models import layers as L
from repro.models.transformer import (_cdt, _init_attn, _init_mlp,
                                      _init_norm, _kv_int8, _maybe_remat,
                                      _pdt, _quantize_kv, _store_kv)

Params = Any
Cache = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_enc_block(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"norm1": _init_norm(cfg), "attn": _init_attn(k1, cfg),
            "norm2": _init_norm(cfg), "mlp": _init_mlp(k2, cfg)}


def _init_dec_block(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": _init_norm(cfg), "attn": _init_attn(k1, cfg),
            "norm_x": _init_norm(cfg), "cross": _init_attn(k2, cfg),
            "norm2": _init_norm(cfg), "mlp": _init_mlp(k3, cfg)}


def init_params(cfg: ModelConfig, key) -> Params:
    ke, kd, kemb, kpe, kpd = jax.random.split(key, 5)
    dt = _pdt(cfg)
    enc_keys = jax.random.split(ke, cfg.n_enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": (jax.random.normal(kemb, (cfg.padded_vocab(), cfg.d_model))
                  * 0.02).astype(dt),
        "enc_pos": (jax.random.normal(kpe, (cfg.enc_seq, cfg.d_model))
                    * 0.02).astype(jnp.float32),
        "dec_pos": (jax.random.normal(kpd, (cfg.max_pos, cfg.d_model))
                    * 0.02).astype(jnp.float32),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg))(dec_keys),
        "enc_final_norm": _init_norm(cfg),
        "final_norm": _init_norm(cfg),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames (B, S_enc, D) stub embeddings -> encoder hidden states."""
    s = frames.shape[1]
    x = frames.astype(_cdt(cfg)) + params["enc_pos"][:s].astype(_cdt(cfg))

    def body(h, lp):
        a, _ = _enc_attn(lp, h, cfg)
        h = h + a
        h = h + L.gelu_mlp(lp["mlp"], L.apply_norm(h, lp["norm2"],
                                                   cfg.norm_type, cfg.eps))
        return h, None

    body = _maybe_remat(body, cfg)
    x, _ = lax.scan(body, x, params["enc_blocks"])
    return L.apply_norm(x, params["enc_final_norm"], cfg.norm_type, cfg.eps)


def _enc_attn(p, x, cfg: ModelConfig):
    b, s, _ = x.shape
    hd = cfg.hd()
    h = L.apply_norm(x, p["norm1"], cfg.norm_type, cfg.eps)
    q = qeinsum("bsd,hkd->bshk", h, p["attn"]["wq"]) * (hd ** -0.5)
    k = qeinsum("bsd,hkd->bshk", h, p["attn"]["wk"])
    v = qeinsum("bsd,hkd->bshk", h, p["attn"]["wv"])
    acfg = L.AttnConfig(cfg.n_heads, cfg.n_kv_heads, hd, causal=False,
                        q_chunk=cfg.q_chunk)
    out = L.attention_scores_blockwise(q, k, v, acfg)
    out = qeinsum("bshk,dhk->bsd", out, p["attn"]["wo"])
    return out.astype(x.dtype), None


# ---------------------------------------------------------------------------
# decoder — full-sequence (train / prefill)
# ---------------------------------------------------------------------------


def _cross_kv(p, enc_hidden, cfg: ModelConfig):
    b, se, _ = enc_hidden.shape
    hd = cfg.hd()
    k = qeinsum("bsd,hkd->bshk", enc_hidden, p["cross"]["wk"])
    v = qeinsum("bsd,hkd->bshk", enc_hidden, p["cross"]["wv"])
    return k, v


def _dec_block_seq(p, x, enc_hidden, cfg: ModelConfig, collect: bool):
    b, s, _ = x.shape
    hd = cfg.hd()
    # self attention (causal)
    h = L.apply_norm(x, p["norm1"], cfg.norm_type, cfg.eps)
    q = qeinsum("bsd,hkd->bshk", h, p["attn"]["wq"]) * (hd ** -0.5)
    k = qeinsum("bsd,hkd->bshk", h, p["attn"]["wk"])
    v = qeinsum("bsd,hkd->bshk", h, p["attn"]["wv"])
    acfg = L.AttnConfig(cfg.n_heads, cfg.n_kv_heads, hd, causal=True,
                        q_chunk=cfg.q_chunk)
    a = L.attention_scores_blockwise(q, k, v, acfg)
    x = x + qeinsum("bshk,dhk->bsd", a, p["attn"]["wo"]).astype(x.dtype)

    # cross attention (non-causal, to encoder states)
    hx = L.apply_norm(x, p["norm_x"], cfg.norm_type, cfg.eps)
    qx = qeinsum("bsd,hkd->bshk", hx, p["cross"]["wq"]) * (hd ** -0.5)
    kx, vx = _cross_kv(p, enc_hidden, cfg)
    xcfg = L.AttnConfig(cfg.n_heads, cfg.n_kv_heads, hd, causal=False,
                        q_chunk=cfg.q_chunk)
    cx = L.attention_scores_blockwise(qx, kx, vx, xcfg)
    x = x + qeinsum("bshk,dhk->bsd", cx, p["cross"]["wo"]).astype(x.dtype)

    # mlp
    x = x + L.gelu_mlp(p["mlp"], L.apply_norm(x, p["norm2"], cfg.norm_type,
                                              cfg.eps))
    kv = (k, v, kx, vx) if collect else None
    return x, kv


def decoder_hidden(params: Params, cfg: ModelConfig, tokens: jax.Array,
                   enc_hidden: jax.Array, collect_cache: bool = False):
    b, s = tokens.shape
    x = L.embed_lookup(params["embed"], tokens).astype(_cdt(cfg))
    x = x + params["dec_pos"][:s].astype(_cdt(cfg))

    def body(h, lp):
        h2, kv = _dec_block_seq(lp, h, enc_hidden, cfg, collect_cache)
        return h2, kv

    body = _maybe_remat(body, cfg)
    x, kvs = lax.scan(body, x, params["dec_blocks"])
    x = L.apply_norm(x, params["final_norm"], cfg.norm_type, cfg.eps)
    return x, kvs


def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            chunk: int = 512) -> jax.Array:
    """batch: frames (B, S_enc, D), tokens (B, S), labels (B, S)."""
    enc_hidden = encode(params, cfg, batch["frames"])
    hidden, _ = decoder_hidden(params, cfg, batch["tokens"], enc_hidden)
    labels = batch["labels"]
    b, s = labels.shape
    w = params["embed"]
    c = min(chunk, s)
    while s % c:
        c -= 1
    hs = jnp.moveaxis(hidden.reshape(b, s // c, c, cfg.d_model), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, s // c, c), 1, 0)

    def ce_chunk(carry, inp):
        h, y = inp
        logits = L.lm_head(w, h)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - tgt), None

    ce_chunk = _maybe_remat(ce_chunk, cfg)
    total, _ = lax.scan(ce_chunk, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (b * s)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Cache:
    hd = cfg.hd()
    kvd = jnp.int8 if _kv_int8(cfg) else _cdt(cfg)
    nl = cfg.n_layers

    def buf(seq):
        c = {"k": jnp.zeros((nl, batch, seq, cfg.n_kv_heads, hd), kvd),
             "v": jnp.zeros((nl, batch, seq, cfg.n_kv_heads, hd), kvd)}
        if _kv_int8(cfg):
            c["ks"] = jnp.zeros((nl, batch, seq, cfg.n_kv_heads), jnp.float32)
            c["vs"] = jnp.zeros_like(c["ks"])
        return c

    return {"lens": jnp.zeros((batch,), jnp.int32),
            "self": buf(max_seq), "cross": buf(cfg.enc_seq)}


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            max_seq: Optional[int] = None) -> Tuple[jax.Array, Cache]:
    """Encode audio, teacher-force the prompt tokens, build both caches."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_seq = max_seq or s
    enc_hidden = encode(params, cfg, batch["frames"])
    hidden, kvs = decoder_hidden(params, cfg, tokens, enc_hidden,
                                 collect_cache=True)
    k, v, kx, vx = kvs
    cache = init_cache(cfg, b, max_seq)
    cache["lens"] = jnp.full((b,), s, jnp.int32)
    int8 = _kv_int8(cfg)

    def fill(dst, kk, vv, upto):
        dst = dict(dst)
        if int8:
            kq, ks = _quantize_kv(kk)
            vq, vs = _quantize_kv(vv)
            dst["k"] = dst["k"].at[:, :, :upto].set(kq)
            dst["v"] = dst["v"].at[:, :, :upto].set(vq)
            dst["ks"] = dst["ks"].at[:, :, :upto].set(ks)
            dst["vs"] = dst["vs"].at[:, :, :upto].set(vs)
        else:
            dst["k"] = dst["k"].at[:, :, :upto].set(kk.astype(dst["k"].dtype))
            dst["v"] = dst["v"].at[:, :, :upto].set(vv.astype(dst["v"].dtype))
        return dst

    cache["self"] = fill(cache["self"], k, v, s)
    cache["cross"] = fill(cache["cross"], kx, vx, kx.shape[2])
    logits = L.lm_head(params["embed"], hidden[:, -1])
    return logits, cache


def decode_step(params: Params, cfg: ModelConfig, cache: Cache,
                tokens: jax.Array, positions: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Cache]:
    b = tokens.shape[0]
    pos = cache["lens"] if positions is None else positions
    hd = cfg.hd()
    int8 = _kv_int8(cfg)
    x = L.embed_lookup(params["embed"], tokens).astype(_cdt(cfg))
    x = x + params["dec_pos"][pos].astype(_cdt(cfg))
    enc_len = cache["cross"]["k"].shape[2]

    def body(h, inp):
        lp, (self_c, cross_c) = inp
        hh = L.apply_norm(h, lp["norm1"], cfg.norm_type, cfg.eps)
        q = qeinsum("bd,hkd->bhk", hh, lp["attn"]["wq"])
        k = qeinsum("bd,hkd->bhk", hh, lp["attn"]["wk"])
        v = qeinsum("bd,hkd->bhk", hh, lp["attn"]["wv"])
        self_c = _store_kv(self_c, k, v, pos, int8)
        acfg = L.AttnConfig(cfg.n_heads, cfg.n_kv_heads, hd)
        a = L.attention_decode(q * (hd ** -0.5), self_c["k"], self_c["v"],
                               pos + 1, acfg, self_c.get("ks"),
                               self_c.get("vs"))
        h = h + qeinsum("bhk,dhk->bd", a, lp["attn"]["wo"]).astype(h.dtype)

        hx = L.apply_norm(h, lp["norm_x"], cfg.norm_type, cfg.eps)
        qx = qeinsum("bd,hkd->bhk", hx, lp["cross"]["wq"])
        cx = L.attention_decode(qx * (hd ** -0.5), cross_c["k"], cross_c["v"],
                                enc_len, acfg, cross_c.get("ks"),
                                cross_c.get("vs"))
        h = h + qeinsum("bhk,dhk->bd", cx, lp["cross"]["wo"]).astype(h.dtype)
        h = h + L.gelu_mlp(lp["mlp"],
                           L.apply_norm(h, lp["norm2"], cfg.norm_type,
                                        cfg.eps))
        return h, (self_c, cross_c)

    x, (new_self, new_cross) = lax.scan(
        body, x, (params["dec_blocks"], (cache["self"], cache["cross"])))
    x = L.apply_norm(x, params["final_norm"], cfg.norm_type, cfg.eps)
    logits = L.lm_head(params["embed"], x)
    new_cache = dict(cache)
    new_cache["self"] = new_self
    new_cache["cross"] = new_cross
    new_cache["lens"] = pos + 1
    return logits, new_cache
