"""Mamba2 (SSD — state-space duality) block, chunked-parallel + recurrent.

Used by ``mamba2-370m`` (pure SSM) and ``zamba2-1.2b`` (hybrid).  Training/
prefill use the chunked SSD algorithm (quadratic within a chunk, linear
across chunks); decode uses the O(1)-per-token recurrence — this is what
makes the ``long_500k`` cell tractable where full attention is skipped.

The projections (in_proj / out_proj) are the quantization targets (the
paper's technique applies to every large matmul operand); the SSM dynamics
parameters (A_log, dt_bias, D_skip, conv) stay fp32 exactly like the
paper's RMSNorm weights — small, error-sensitive state.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.qlinear import qdot
from repro.models.layers import dense_init, rms_norm


class SSMDims(NamedTuple):
    d_model: int
    d_inner: int       # expand * d_model
    head_dim: int      # P
    n_heads: int       # d_inner // P
    n_groups: int      # G (B/C groups)
    state: int         # N
    conv_width: int    # temporal conv kernel

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.state

    @property
    def in_proj_dim(self) -> int:
        # z, x, B, C, dt
        return 2 * self.d_inner + 2 * self.n_groups * self.state + self.n_heads


def make_ssm_dims(d_model: int, state: int, expand: int = 2,
                  head_dim: int = 64, n_groups: int = 1,
                  conv_width: int = 4) -> SSMDims:
    d_inner = expand * d_model
    return SSMDims(d_model=d_model, d_inner=d_inner, head_dim=head_dim,
                   n_heads=d_inner // head_dim, n_groups=n_groups,
                   state=state, conv_width=conv_width)


def init_mamba2_params(key, dims: SSMDims, dtype=jnp.float32):
    """Split projections (wz/wx/wB/wC/wdt instead of one fused in_proj).

    The fused Mamba in_proj concatenates [z | x | B | C | dt] along its
    output dim; tensor-parallel sharding of that dim would cut through the
    five segments at unaligned offsets.  Splitting keeps each projection
    independently shardable (z/x/dt on the `model` axis, B/C replicated —
    they are tiny); XLA is free to re-fuse the matmuls since they share the
    same activation operand.
    """
    ks = jax.random.split(key, 8)
    h = dims.n_heads
    gn = dims.n_groups * dims.state
    conv = lambda k, c: (jax.random.normal(k, (c, dims.conv_width))
                         * (1.0 / math.sqrt(dims.conv_width))).astype(jnp.float32)
    return {
        "wz": dense_init(ks[0], dims.d_inner, dims.d_model, dtype),
        "wx": dense_init(ks[1], dims.d_inner, dims.d_model, dtype),
        "wB": dense_init(ks[2], gn, dims.d_model, dtype),
        "wC": dense_init(ks[3], gn, dims.d_model, dtype),
        "wdt": dense_init(ks[4], h, dims.d_model, jnp.float32),
        "out_proj": dense_init(ks[5], dims.d_model, dims.d_inner, dtype),
        "conv_x": conv(ks[6], dims.d_inner),
        "conv_B": conv(ks[7], gn),
        "conv_C": conv(jax.random.fold_in(key, 99), gn),
        "conv_x_bias": jnp.zeros((dims.d_inner,), jnp.float32),
        "conv_B_bias": jnp.zeros((gn,), jnp.float32),
        "conv_C_bias": jnp.zeros((gn,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),
        "D_skip": jnp.ones((h,), jnp.float32),
        "norm": {"gamma": jnp.ones((dims.d_inner,), jnp.float32)},
    }


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 init_state: jax.Array | None = None) -> jax.Array:
    """x (B, S, C), w (C, W): causal depthwise conv along S."""
    bsz, s, c = x.shape
    wdt = w.shape[1]
    if init_state is None:
        pad = jnp.zeros((bsz, wdt - 1, c), x.dtype)
    else:
        pad = init_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                # (B, S+W-1, C)
    out = jnp.zeros_like(x)
    for i in range(wdt):
        out = out + xp[:, i: i + s, :] * w[:, i]
    return out + b


# ---------------------------------------------------------------------------
# chunked SSD (training / prefill)
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, B, C, chunk: int = 128):
    """Chunked state-space-duality scan (Dao & Gu 2024, alg. in §6).

    x:  (b, s, h, p)   dt: (b, s, h)   A: (h,) negative
    B/C: (b, s, g, n)  heads are split per group (h = g * hp).
    Returns y (b, s, h, p) and final state (b, h, p, n).
    """
    b, s, h, p = x.shape
    g, n = B.shape[-2:]
    hp = h // g
    q = min(chunk, s)
    while s % q:
        q -= 1
    c = s // q

    f32 = jnp.float32
    xdt = x.astype(f32) * dt[..., None].astype(f32)            # (b,s,h,p)
    dA = dt.astype(f32) * A.astype(f32)                       # (b,s,h) log-decay

    # chunked views; head axis split (g, hp)
    xc = xdt.reshape(b, c, q, g, hp, p)
    dAc = dA.reshape(b, c, q, g, hp)
    Bc = B.astype(f32).reshape(b, c, q, g, n)
    Cc = C.astype(f32).reshape(b, c, q, g, n)

    seg = jnp.cumsum(dAc, axis=2)                              # (b,c,q,g,hp)
    seg_last = seg[:, :, -1]                                   # (b,c,g,hp)

    # --- intra-chunk (quadratic within q) ---
    ldiff = seg[:, :, :, None] - seg[:, :, None, :, :]         # (b,c,i,j,g,hp)
    mask = jnp.tril(jnp.ones((q, q), bool))
    # mask in log space BEFORE exp: exp of a masked +large diff would be inf
    # and inf*0 poisons the backward pass with NaNs.
    ldiff = jnp.where(mask[None, None, :, :, None, None], ldiff, -jnp.inf)
    L = jnp.exp(ldiff)
    cb = jnp.einsum("bcign,bcjgn->bcijg", Cc, Bc)              # (b,c,i,j,g)
    y_intra = jnp.einsum("bcijg,bcijgh,bcjghp->bcighp", cb, L, xc)

    # --- inter-chunk state passing ---
    decay_end = jnp.exp(seg_last[:, :, None] - seg)            # (b,c,q,g,hp)
    s_chunk = jnp.einsum("bcqghp,bcqgn->bcghpn", xc * decay_end[..., None], Bc)
    chunk_decay = jnp.exp(seg_last)                            # (b,c,g,hp)

    def scan_fn(hstate, inp):
        s_c, dec = inp                                         # per chunk
        out = hstate                                           # state before chunk
        hstate = hstate * dec[..., None, None] + s_c
        return hstate, out

    init = jnp.zeros((b, g, hp, p, n), f32)
    final_state, h_before = lax.scan(
        scan_fn, init,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_before = jnp.moveaxis(h_before, 0, 1)                    # (b,c,g,hp,p,n)

    y_inter = jnp.einsum("bcign,bcghpn->bcighp", Cc, h_before) \
        * jnp.exp(seg)[..., None]
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state.reshape(b, h, p, n)


def ssd_recurrent_ref(x, dt, A, B, C):
    """O(s·n) token-by-token recurrence — oracle for ssd_chunked tests."""
    b, s, h, p = x.shape
    g, n = B.shape[-2:]
    hp = h // g
    f32 = jnp.float32

    def step(hstate, inp):
        xt, dtt, Bt, Ct = inp          # (b,h,p),(b,h),(b,g,n),(b,g,n)
        dA = jnp.exp(dtt.astype(f32) * A.astype(f32))          # (b,h)
        Bh = jnp.repeat(Bt, hp, axis=1)                        # (b,h,n)
        Ch = jnp.repeat(Ct, hp, axis=1)
        hstate = hstate * dA[..., None, None] + \
            (xt.astype(f32) * dtt[..., None].astype(f32))[..., None] * Bh[:, :, None, :]
        y = jnp.sum(hstate * Ch[:, :, None, :], axis=-1)       # (b,h,p)
        return hstate, y

    init = jnp.zeros((b, h, p, n), f32)
    final, ys = lax.scan(step, init,
                         (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
                          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------


def mamba2_forward(p, x, dims: SSMDims, chunk: int = 128,
                   conv_state=None, ssm_state=None):
    """x (B, S, D) -> (y (B, S, D), (conv_state, ssm_state)) — prefill/train."""
    bsz, s, _ = x.shape
    d_in, h = dims.d_inner, dims.n_heads
    z = qdot(x, p["wz"])                                        # (B,S,d_in)
    xin = qdot(x, p["wx"])
    Bin = qdot(x, p["wB"])                                      # (B,S,GN)
    Cin = qdot(x, p["wC"])
    dt_raw = qdot(x, p["wdt"])                                  # (B,S,H)

    cs_x, cs_B, cs_C = (None, None, None) if conv_state is None else conv_state
    xc = _causal_conv(xin, p["conv_x"], p["conv_x_bias"], cs_x)
    Bc = _causal_conv(Bin, p["conv_B"], p["conv_B_bias"], cs_B)
    Cc = _causal_conv(Cin, p["conv_C"], p["conv_C_bias"], cs_C)
    new_conv_state = (_conv_tail(xin, cs_x, dims.conv_width),
                      _conv_tail(Bin, cs_B, dims.conv_width),
                      _conv_tail(Cin, cs_C, dims.conv_width))

    xs = jax.nn.silu(xc).reshape(bsz, s, h, dims.head_dim)
    B = jax.nn.silu(Bc).reshape(bsz, s, dims.n_groups, dims.state)
    C = jax.nn.silu(Cc).reshape(bsz, s, dims.n_groups, dims.state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if ssm_state is not None:
        # prefill continuing from a state: fold the carried state in by
        # treating it as chunk -1 — handled via ssd on fresh state plus
        # decayed contribution of the carry (exact).
        y, final = ssd_chunked(xs, dt, A, B, C, chunk)
        seg_all = jnp.cumsum(dt * A, axis=1)                   # (B,S,H)
        hp = h // dims.n_groups
        Ch = C.repeat(hp, axis=2) if dims.n_groups > 1 else \
            jnp.broadcast_to(C, (bsz, s, h, dims.state))
        carry_y = jnp.einsum("bshn,bhpn->bshp", Ch.astype(jnp.float32),
                             ssm_state.astype(jnp.float32)) \
            * jnp.exp(seg_all)[..., None]
        y = y + carry_y.astype(y.dtype)
        total_decay = jnp.exp(seg_all[:, -1])                  # (B,H)
        final = final + ssm_state * total_decay[..., None, None]
    else:
        y, final = ssd_chunked(xs, dt, A, B, C, chunk)

    y = y + xs * p["D_skip"][:, None]
    y = y.reshape(bsz, s, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm"]["gamma"])
    out = qdot(y, p["out_proj"]).astype(x.dtype)
    return out, (new_conv_state, final)


def _conv_tail(pre_conv, conv_state, conv_width: int):
    """Last (conv_width-1) *pre-conv* inputs — the decode conv state."""
    w1 = conv_width - 1
    s = pre_conv.shape[1]
    if s >= w1:
        return pre_conv[:, s - w1:, :]
    prev = conv_state if conv_state is not None else \
        jnp.zeros((pre_conv.shape[0], w1, pre_conv.shape[2]), pre_conv.dtype)
    return jnp.concatenate([prev, pre_conv], axis=1)[:, -w1:, :]


def _conv_step(new_col, conv_state, w, bias):
    """new_col (B, C); conv_state (B, W-1, C) -> (out (B, C), new state)."""
    window = jnp.concatenate([conv_state, new_col[:, None, :]], axis=1)
    out = jnp.sum(window * w.T[None], axis=1) + bias
    return out, window[:, 1:, :]


def mamba2_decode_step(p, x, dims: SSMDims, conv_state, ssm_state):
    """x (B, D) one token; conv_state = (x, B, C) ring buffers
    (B, W-1, ·); ssm_state (B, H, P, N).  Returns (y (B, D), new states)."""
    b = x.shape[0]
    d_in, h = dims.d_inner, dims.n_heads
    z = qdot(x, p["wz"])                                        # (B, d_in)
    xin = qdot(x, p["wx"])
    Bin = qdot(x, p["wB"])
    Cin = qdot(x, p["wC"])
    dt_raw = qdot(x, p["wdt"])                                  # (B, H)

    cs_x, cs_B, cs_C = conv_state
    xc, cs_x = _conv_step(xin, cs_x, p["conv_x"], p["conv_x_bias"])
    Bc, cs_B = _conv_step(Bin, cs_B, p["conv_B"], p["conv_B_bias"])
    Cc, cs_C = _conv_step(Cin, cs_C, p["conv_C"], p["conv_C_bias"])
    new_conv_state = (cs_x, cs_B, cs_C)

    xs = jax.nn.silu(xc).reshape(b, h, dims.head_dim)
    B = jax.nn.silu(Bc).reshape(b, dims.n_groups, dims.state)
    C = jax.nn.silu(Cc).reshape(b, dims.n_groups, dims.state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                        # (B,H)

    hp = h // dims.n_groups
    Bh = jnp.repeat(B, hp, axis=1)                              # (B,H,N)
    Ch = jnp.repeat(C, hp, axis=1)
    new_state = ssm_state * dA[..., None, None] + \
        (xs.astype(jnp.float32) * dt[..., None])[..., None] * Bh[:, :, None, :]
    y = jnp.sum(new_state * Ch[:, :, None, :], axis=-1)        # (B,H,P)
    y = y + xs.astype(jnp.float32) * p["D_skip"][:, None]
    y = y.reshape(b, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm"]["gamma"])
    out = qdot(y, p["out_proj"]).astype(x.dtype)
    return out, (new_conv_state, new_state)
