"""Shared neural-net layers, functional style.

Everything here is a pure function ``f(params_subtree, inputs, cfg) -> out``.
Weights are stored contraction-last ``(out, in)`` so ``repro.core.qlinear.qdot``
can transparently take either float (training) or QuantizedTensor (serving)
leaves — the paper's PTQ flow means one code path serves both.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.qlinear import as_float, qdot

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    # gamma stays fp32 — the paper keeps RMSNorm params un-quantized.
    return (x32 * lax.rsqrt(ms + eps) * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, p, kind: str, eps: float = 1e-5):
    if kind == "rmsnorm":
        return rms_norm(x, p["gamma"], eps)
    return layer_norm(x, p["gamma"], p["beta"], eps)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_angles(positions: jax.Array, head_dim: int, theta: float
                ) -> tuple[jax.Array, jax.Array]:
    """positions (…,) -> cos/sin (…, head_dim) in rotate-half layout."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (…, half)
    ang = jnp.concatenate([ang, ang], axis=-1)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., H, D); cos/sin broadcastable (..., 1, D)."""
    d = x.shape[-1]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : d // 2], x32[..., d // 2:]
    rot = jnp.concatenate([-x2, x1], axis=-1)
    return (x32 * cos + rot * sin).astype(x.dtype)


def mrope_angles(positions: jax.Array, head_dim: int, theta: float,
                 sections: tuple[int, ...]) -> tuple[jax.Array, jax.Array]:
    """Qwen2-VL multimodal RoPE.

    positions: (3, …) — temporal / height / width position streams.
    ``sections`` gives the number of *rotation pairs* per stream (summing to
    head_dim // 2); each frequency band takes its position from its stream.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # stream index per frequency band
    stream = jnp.repeat(
        jnp.arange(len(sections)),
        jnp.array(sections),
        total_repeat_length=half)                             # (half,)
    pos = positions.astype(jnp.float32)                       # (3, …)
    pos_per_band = jnp.take(pos, stream, axis=0)              # (half, …)? no:
    # take along stream axis: result (half, …) -> move to (…, half)
    pos_per_band = jnp.moveaxis(pos_per_band, 0, -1)
    ang = pos_per_band * freqs
    ang = jnp.concatenate([ang, ang], axis=-1)
    return jnp.cos(ang), jnp.sin(ang)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


class AttnConfig(NamedTuple):
    n_heads: int
    n_kv_heads: int
    head_dim: int
    causal: bool = True
    q_chunk: int = 1024       # blockwise-attention chunk (memory bound)
    window: int = 0           # >0: sliding-window attention


def attention_scores_blockwise(q, k, v, cfg: AttnConfig,
                               q_offset: int = 0) -> jax.Array:
    """Memory-efficient causal attention: scan over query chunks.

    q: (B, S, H, D) pre-scaled; k/v: (B, T, KVH, D).  Scores for one chunk
    are (B, H, qc, T) — never the full S×T square.  The scan body is
    rematerialized in the backward pass (wrapped by the caller's remat
    policy), which is what bounds training memory at 4k–32k context.

    GQA KV heads are broadcast to the full H before the einsum: with the
    head axis TP-sharded this keeps every contraction head-uniform (no
    (KVH, HQ) re-grouping of a sharded dim), and XLA fuses the broadcast
    into the dot so no repeated KV is materialized.
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    kvh = cfg.n_kv_heads
    hq = h // kvh
    qc = min(cfg.q_chunk, s)
    while s % qc:
        qc -= 1
    n_chunks = s // qc

    kg = jnp.repeat(k, hq, axis=2).astype(q.dtype)      # (B, T, H, D)
    vg = jnp.repeat(v, hq, axis=2).astype(q.dtype)
    qg = q.reshape(b, n_chunks, qc, h, d)

    # checkpoint: the backward pass recomputes scores/softmax per chunk
    # instead of saving (B,H,qc,T) f32 residuals for every chunk — this is
    # what keeps training memory flat in T (flash-attention-style remat).
    @jax.checkpoint
    def chunk_fn(carry, inputs):
        qi, idx = inputs                                # (B, qc, H, D)
        scores = jnp.einsum("bqhd,bthd->bhqt", qi.astype(jnp.float32),
                            kg.astype(jnp.float32))
        qpos = q_offset + idx * qc + jnp.arange(qc)[:, None]
        kpos = jnp.arange(t)[None, :]
        mask = jnp.ones((qc, t), bool)
        if cfg.causal:
            mask &= kpos <= qpos
        if cfg.window > 0:
            mask &= kpos > qpos - cfg.window
        scores = jnp.where(mask[None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqt,bthd->bqhd", p.astype(q.dtype), vg)
        return carry, out

    _, outs = lax.scan(chunk_fn, None,
                       (jnp.moveaxis(qg, 1, 0), jnp.arange(n_chunks)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, d)
    return out


def attention_chunk_merge(q, k_pfx, v_pfx, k_chunk, v_chunk,
                          cfg: AttnConfig, q_pos, pfx_valid,
                          chunk_valid, pfx_state=None) -> jax.Array:
    """Shape-stable chunked-prefill attention: a fixed-extent *prefix*
    segment merged with the chunk's own keys by exact softmax
    renormalization.

    q: (B, C, H, D) pre-scaled chunk queries at global positions
    ``q_pos`` (B, C); k/v_chunk: (B, C, KVH, D) the chunk's own
    (pre-quantization) keys, live where ``chunk_valid`` (B, C);
    k/v_pfx: (B, P, KVH, D) the row's gathered pool extent — pool row
    ``t`` sits at global position ``t`` — live where ``pfx_valid``
    (B, P).  All extents are traced data, so chunk length, position
    offset and batch padding never enter the compile key.

    Numerics contract (what makes budget-padded serving trustworthy):

      * the chunk segment is element-for-element
        :func:`attention_scores_blockwise` — same einsums, same f32
        softmax — and each segment's masked keys get *exactly zero*
        probability mass (``exp(-1e30 - m)`` underflows to 0);
      * the two segments merge as ``w_p * out_p + w_c * out_c`` with
        ``w = alpha * l / (alpha_p l_p + alpha_c l_c)`` (flash-style
        max/denominator renormalization).  An all-masked prefix gives
        ``alpha_p == 0.0`` and ``w_c == l_c / l_c == 1.0`` *exactly*, so
        a zero-offset row is **bit-identical** to the plain blockwise
        oracle — which is how the whole-prompt chunk stays bit-identical
        to one-shot prefill while the compile count stays shape-stable;
      * a fully-padded row (everything masked) degrades to finite
        garbage that the caller discards — the pool never holds
        non-finite values, so no NaNs can leak through the ``0 * out_p``
        term.

    Rows with a non-empty prefix reassociate the softmax reduction
    (prefix and chunk are reduced separately, then merged), so they
    match a concatenated-key reference to last-ulp tolerance rather
    than bitwise — the same tolerance class multi-chunk prefill already
    carries vs one-shot.

    ``pfx_state`` replaces the gathered prefix segment with a
    pre-computed flash state ``(out_p, m_p, l_p)`` — out_p (B, C, H, D),
    m_p/l_p (B, H, C, 1), the layout `kernels.ops.paged_prefill_attention`
    returns — and ``k_pfx``/``v_pfx``/``pfx_valid`` may then be None.
    The merge arithmetic is identical either way, and the empty-prefix
    state (out=0, m=-1e30, l=0) reproduces the exact ``w_c == 1.0``
    bit-identity above, so the fused kernel inherits both contracts.
    """
    b, c, h, d = q.shape
    kvh = cfg.n_kv_heads
    hq = h // kvh
    qc = min(cfg.q_chunk, c)
    while c % qc:
        qc -= 1
    n_chunks = c // qc

    kgc = jnp.repeat(k_chunk, hq, axis=2).astype(q.dtype)   # (B, C, H, D)
    vgc = jnp.repeat(v_chunk, hq, axis=2).astype(q.dtype)
    if pfx_state is None:
        p_len = k_pfx.shape[1]
        kgp = jnp.repeat(k_pfx, hq, axis=2).astype(q.dtype)  # (B, P, H, D)
        vgp = jnp.repeat(v_pfx, hq, axis=2).astype(q.dtype)
        k_pos_p = jnp.arange(p_len, dtype=jnp.int32)[None]   # pool rows
    k_pos_c = q_pos                                          # chunk keys
    qg = q.reshape(b, n_chunks, qc, h, d)
    qp = q_pos.reshape(b, n_chunks, qc)

    def segment(qi, qpos, kg, vg, k_pos, k_valid, causal):
        """Masked softmax attention over one key segment; returns the
        normalized output plus (max, denominator) for the merge."""
        scores = jnp.einsum("bqhd,bthd->bhqt", qi.astype(jnp.float32),
                            kg.astype(jnp.float32))
        mask = k_valid[:, None, :]                           # (B, qc?, T)
        if causal:
            mask = mask & (k_pos[:, None, :] <= qpos[:, :, None])
        if cfg.window > 0:
            mask = mask & (k_pos[:, None, :] > qpos[:, :, None] - cfg.window)
        scores = jnp.where(mask[:, None], scores, -1e30)
        m = jnp.max(scores, axis=-1, keepdims=True)          # (B,H,qc,1)
        e = jnp.exp(scores - m)
        l = jnp.sum(e, axis=-1, keepdims=True)
        p = e / l
        out = jnp.einsum("bhqt,bthd->bqhd", p.astype(q.dtype), vg)
        return out, m, l

    def merge(out_c, m_c, l_c, out_p, m_p, l_p):
        m = jnp.maximum(m_p, m_c)
        a_p = jnp.exp(m_p - m) * l_p
        a_c = jnp.exp(m_c - m) * l_c
        l = a_p + a_c
        w_p = jnp.moveaxis(a_p / l, 1, 2)               # (B, qc, H, 1)
        w_c = jnp.moveaxis(a_c / l, 1, 2)
        return w_p * out_p + w_c * out_c

    if pfx_state is None:
        @jax.checkpoint
        def chunk_fn(carry, inputs):
            qi, qpos = inputs                           # (B,qc,H,D), (B,qc)
            out_c, m_c, l_c = segment(qi, qpos, kgc, vgc, k_pos_c,
                                      chunk_valid, cfg.causal)
            # prefix keys sit strictly below every live query position, so
            # validity already implies causality; the window (if any) still
            # applies
            out_p, m_p, l_p = segment(qi, qpos, kgp, vgp, k_pos_p,
                                      pfx_valid, False)
            return carry, merge(out_c, m_c, l_c, out_p, m_p, l_p)

        xs = (jnp.moveaxis(qg, 1, 0), jnp.moveaxis(qp, 1, 0))
    else:
        out_p_all, m_p_all, l_p_all = pfx_state
        # slice the precomputed state to the same per-q-chunk scan layout
        op = jnp.moveaxis(out_p_all.reshape(b, n_chunks, qc, h, d), 1, 0)
        mp = jnp.moveaxis(m_p_all.reshape(b, h, n_chunks, qc, 1), 2, 0)
        lp = jnp.moveaxis(l_p_all.reshape(b, h, n_chunks, qc, 1), 2, 0)

        @jax.checkpoint
        def chunk_fn(carry, inputs):
            qi, qpos, out_p, m_p, l_p = inputs
            out_c, m_c, l_c = segment(qi, qpos, kgc, vgc, k_pos_c,
                                      chunk_valid, cfg.causal)
            return carry, merge(out_c, m_c, l_c, out_p, m_p, l_p)

        xs = (jnp.moveaxis(qg, 1, 0), jnp.moveaxis(qp, 1, 0), op, mp, lp)

    _, outs = lax.scan(chunk_fn, None, xs)
    return jnp.moveaxis(outs, 0, 1).reshape(b, c, h, d)


def attention_decode(q, k_cache, v_cache, length, cfg: AttnConfig,
                     k_scale=None, v_scale=None) -> jax.Array:
    """Single-position attention against a cache (jnp path — shardable).

    q: (B, H, D) pre-scaled; caches (B, S, KVH, D); length (B,) or scalar.
    Optional per-(position, kv-head) scales dequantize an int8 cache.
    """
    b, h, d = q.shape
    s = k_cache.shape[1]
    kvh = cfg.n_kv_heads
    hq = h // kvh
    qg = q.reshape(b, kvh, hq, d).astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale[..., None]
        vf = vf * v_scale[..., None]
    scores = jnp.einsum("bkhd,bskd->bkhs", qg, kf)
    pos = jnp.arange(s)[None, :]
    lens = jnp.broadcast_to(jnp.asarray(length), (b,))[:, None]
    mask = (pos < lens)[:, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(mask, p, 0.0)
    out = jnp.einsum("bkhs,bskd->bkhd", p, vf)
    return out.reshape(b, h, d).astype(q.dtype)


def paged_attention_decode(q, k_pool, v_pool, page_table, length,
                           cfg: AttnConfig, ks_pool=None, vs_pool=None
                           ) -> jax.Array:
    """Single-position attention against a paged block pool (jnp path).

    q: (B, H, D) pre-scaled; k/v_pool: (NB, BS, KVH, D); page_table (B, MB)
    int32 (-1 = unassigned; such blocks read pool block 0 and are masked by
    ``length``); length (B,).  Gathers each row's blocks into a contiguous
    view and defers to :func:`attention_decode` — the numerics the Pallas
    kernel (kernels/paged_decode_attention.py) matches, which on TPU fuses
    this gather into its BlockSpec index_map instead of materializing it.
    """
    nb, bs, kvh, d = k_pool.shape
    b, mb = page_table.shape
    safe = jnp.maximum(page_table, 0)
    k = k_pool[safe].reshape(b, mb * bs, kvh, d)
    v = v_pool[safe].reshape(b, mb * bs, kvh, d)
    ks = vs = None
    if ks_pool is not None:
        ks = ks_pool[safe].reshape(b, mb * bs, kvh)
        vs = vs_pool[safe].reshape(b, mb * bs, kvh)
    return attention_decode(q, k, v, length, cfg, ks, vs)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_mlp(p, x) -> jax.Array:
    """w1/w3: (F, D); w2: (D, F) — SwiGLU as in Llama (paper-faithful).

    When ``w13`` (the fused [w1; w3] from fuse_decode_weights) is present,
    gate and up projections run as ONE GEMV — identical math, since each
    output row's dot product is independent of the others."""
    if "w13" in p:
        h13 = qdot(x, p["w13"])
        f = h13.shape[-1] // 2
        h = jax.nn.silu(h13[..., :f]) * h13[..., f:]
    else:
        h = jax.nn.silu(qdot(x, p["w1"])) * qdot(x, p["w3"])
    return qdot(h.astype(x.dtype), p["w2"]).astype(x.dtype)


def gelu_mlp(p, x) -> jax.Array:
    """w1: (F, D); w2: (D, F) — whisper-style."""
    h = jax.nn.gelu(qdot(x, p["w1"]))
    return qdot(h.astype(x.dtype), p["w2"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style grouped einsum dispatch)
# ---------------------------------------------------------------------------


def moe_mlp(p, x, *, n_experts: int, top_k: int, group_size: int = 512,
            capacity_factor: float = 1.25, dense_dispatch: bool = False
            ) -> jax.Array:
    """Token-choice MoE with capacity-bounded einsum dispatch.

    p: router (E, D); w1/w3 (E, F, D); w2 (E, D, F).
    x: (B, S, D).

    ``dense_dispatch`` computes *every* expert for every token and mixes by
    gate weight — wasteful in FLOPs but optimal in HBM bytes when the batch
    is small and decode is bandwidth-bound (every expert's weights are read
    regardless); used by the decode path.
    """
    b, s, d = x.shape
    e = n_experts
    router = p["router"].astype(jnp.float32)
    logits = jnp.einsum("bsd,ed->bse", x.astype(jnp.float32), router)
    gates, idx = lax.top_k(logits, top_k)                  # (B,S,K)
    gates = jax.nn.softmax(gates, axis=-1)

    if dense_dispatch:
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)     # (B,S,K,E)
        combine = jnp.einsum("bske,bsk->bse", onehot, gates)   # (B,S,E)
        h1 = jnp.einsum("bsd,efd->bsef", x.astype(jnp.float32),
                        as_float(p["w1"]))
        h3 = jnp.einsum("bsd,efd->bsef", x.astype(jnp.float32),
                        as_float(p["w3"]))
        hh = jax.nn.silu(h1) * h3
        y = jnp.einsum("bsef,edf,bse->bsd", hh, as_float(p["w2"]), combine)
        return y.astype(x.dtype)

    # ---- grouped GShard dispatch --------------------------------------
    g_sz = min(group_size, s)
    while s % g_sz:
        g_sz -= 1
    g = (b * s) // g_sz
    cap = max(int(capacity_factor * g_sz * top_k / e), 1)
    # round capacity to a multiple of 4 for tiling friendliness
    cap = (cap + 3) & ~3

    xg = x.reshape(g, g_sz, d)
    gates_g = gates.reshape(g, g_sz, top_k)
    idx_g = idx.reshape(g, g_sz, top_k)

    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(idx_g, e, dtype=jnp.int32)        # (G,Sg,K,E)
    flat = onehot.reshape(g, g_sz * top_k, e)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat                # (G,Sg*K,E)
    pos = jnp.sum(pos_in_e * flat, axis=-1).reshape(g, g_sz, top_k)
    keep = pos < cap
    gates_kept = jnp.where(keep, gates_g, 0.0)

    # dispatch (G, Sg, E, C): 1 where token routed to slot (e, c)
    oh_e = jax.nn.one_hot(idx_g, e, dtype=jnp.float32)          # (G,Sg,K,E)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                          dtype=jnp.float32)                    # (G,Sg,K,C)
    disp = jnp.einsum("gske,gskc->gsec", oh_e, oh_c)
    combine = jnp.einsum("gske,gskc,gsk->gsec", oh_e, oh_c, gates_kept)

    xin = jnp.einsum("gsec,gsd->egcd", disp.astype(x.dtype), xg)  # (E,G,C,D)
    h1 = jnp.einsum("egcd,efd->egcf", as_float(xin), as_float(p["w1"]))
    h3 = jnp.einsum("egcd,efd->egcf", as_float(xin), as_float(p["w3"]))
    hh = jax.nn.silu(h1) * h3
    yo = jnp.einsum("egcf,edf->egcd", hh, as_float(p["w2"]))       # (E,G,C,D)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(jnp.float32), yo)
    return y.reshape(b, s, d).astype(x.dtype)





# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def embed_lookup(table, tokens: jax.Array) -> jax.Array:
    """table (V, D) possibly quantized; tokens int32 (…,)."""
    from repro.core.quantization import QuantizedTensor, _unpack_nibbles
    if isinstance(table, QuantizedTensor):
        q = jnp.take(table.q, tokens, axis=0)     # (…, D) int8 / (…, D/2) q4
        if table.bits == 4:
            q = _unpack_nibbles(q)
        s = jnp.take(table.scale, tokens, axis=0)              # (…, G)
        g = table.orig_dim // table.group_size
        qf = q.reshape(*q.shape[:-1], g, table.group_size).astype(jnp.float32)
        return (qf * s[..., None]).reshape(*qf.shape[:-2], table.orig_dim)
    return jnp.take(table, tokens, axis=0)


def lm_head(w, x) -> jax.Array:
    """w: (V, D) (often tied with the embedding); x (…, D) -> logits f32."""
    return qdot(x, w).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, out_dim: int, in_dim: int, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (out_dim, in_dim)) * scale).astype(dtype)
