"""Model facade: one entry point per architecture family.

``build_model(cfg)`` returns a ``Model`` bundle of pure functions with a
uniform signature across all 10 assigned architectures, so the launcher,
serving stack, dry-run and tests never branch on family.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import QuantPolicy, quantize_params
from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, Dict[str, Any]], jax.Array]
    prefill: Callable[..., Tuple[jax.Array, Any]]
    decode_step: Callable[..., Tuple[jax.Array, Any]]
    init_cache: Callable[[int, int], Any]
    # paged serving (vLLM-style block pool); None for families whose cache
    # is not a single attn bank (ssm/hybrid/audio/interleaved-moe).
    init_paged_cache: Optional[Callable[..., Any]] = None
    # chunked prefill into the paged pool (serving/scheduler.py):
    # prefill_chunk(params, tokens_chunk, cache, slot, pos_offset)
    # -> (last-position logits, updated cache).  None when paging is
    # unsupported.
    prefill_chunk: Optional[Callable[..., Tuple[jax.Array, Any]]] = None
    # shape-stable batched chunk execution: prefill_chunk_batch(params,
    # tokens (B, c), cache, slots, pos_offsets, chunk_lens=...) ->
    # ((B, V) logits, cache) — one device call for ALL of a step's
    # chunks; rows carry their own (chunk_len, pos_offset) as data and
    # negative slots mark padding rows, so the engine pads to one fixed
    # extent and the compile count stays one per pool key.
    prefill_chunk_batch: Optional[Callable[..., Tuple[jax.Array, Any]]] = None
    # shape-stability probe: distinct XLA compiles of the chunk step so
    # far (transformer.prefill_chunk_compiles); None when unpaged.
    # Accepts mesh= — each mesh owns its own jit cache, so the bound is
    # one executable per (pool key, mesh shape).
    prefill_compile_count: Optional[Callable[..., int]] = None
    # speculative verify: the all-positions-logits twin of
    # prefill_chunk_batch — verify_chunk_batch(params, tokens (B, c),
    # cache, slots, pos_offsets, chunk_lens=...) -> ((B, c, V) logits,
    # cache) — with its own compile probe; None when unpaged.
    verify_chunk_batch: Optional[Callable[..., Tuple[jax.Array, Any]]] = None
    verify_compile_count: Optional[Callable[..., int]] = None

    def quantize(self, params, policy: Optional[QuantPolicy] = None,
                 fuse_decode: bool = True):
        """Post-training quantization (the paper's §3.2 flow).

        ``fuse_decode`` additionally builds the fused decode GEMV operands
        (wqkv / w13 / wo_f — see transformer.fuse_decode_weights) so the
        serving decode step runs 4 weight GEMVs per layer instead of 7."""
        qp = quantize_params(params, policy or QuantPolicy())
        if fuse_decode and self.cfg.family != "audio":
            qp = transformer.fuse_decode_weights(qp, self.cfg)
        return qp


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_params(cfg, key),
            loss=lambda p, b: encdec.lm_loss(p, cfg, b),
            prefill=lambda p, b, **kw: encdec.prefill(p, cfg, b, **kw),
            decode_step=lambda p, c, t, **kw: encdec.decode_step(
                p, cfg, c, t, **kw),
            init_cache=lambda bsz, seq: encdec.init_cache(cfg, bsz, seq),
        )
    paged = chunk = chunk_batch = compiles = None
    verify_batch = verify_compiles = None
    if transformer.supports_paged_cache(cfg):
        paged = lambda bsz, **kw: transformer.init_paged_cache(cfg, bsz, **kw)
        chunk = lambda p, t, c, slot, off: transformer.prefill_chunk(
            p, cfg, t, c, slot, off)
        chunk_batch = lambda p, t, c, slots, offs, page_table=None, \
            chunk_lens=None, mesh=None: transformer.prefill_chunk_batch(
                p, cfg, t, c, slots, offs, page_table=page_table,
                chunk_lens=chunk_lens, mesh=mesh)
        compiles = lambda mesh=None: transformer.prefill_chunk_compiles(
            cfg, mesh=mesh)
        verify_batch = lambda p, t, c, slots, offs, page_table=None, \
            chunk_lens=None, mesh=None: transformer.verify_chunk_batch(
                p, cfg, t, c, slots, offs, page_table=page_table,
                chunk_lens=chunk_lens, mesh=mesh)
        verify_compiles = lambda mesh=None: transformer.verify_chunk_compiles(
            cfg, mesh=mesh)
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_params(cfg, key),
        loss=lambda p, b: transformer.lm_loss(p, cfg, b),
        prefill=lambda p, b, **kw: transformer.prefill(p, cfg, b, **kw),
        decode_step=lambda p, c, t, **kw: transformer.decode_step(
            p, cfg, c, t, **kw),
        init_cache=lambda bsz, seq: transformer.init_cache(cfg, bsz, seq),
        init_paged_cache=paged,
        prefill_chunk=chunk,
        prefill_chunk_batch=chunk_batch,
        prefill_compile_count=compiles,
        verify_chunk_batch=verify_batch,
        verify_compile_count=verify_compiles,
    )


def count_params(params) -> int:
    import math
    from repro.core.quantization import QuantizedTensor
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            total += math.prod(leaf.shape)     # python ints: no overflow
        else:
            total += math.prod(leaf.shape) if leaf.shape else 1
    return total
