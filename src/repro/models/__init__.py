"""Model zoo: dense / MoE / SSM / hybrid / VLM / enc-dec backbones."""

from repro.models.model import Model, build_model, count_params

__all__ = ["Model", "build_model", "count_params"]
