"""Synthetic TinyStories-style data pipeline.

The paper evaluates on TinyStories (Karpathy's 110M llama2.c model).  No
dataset ships with this container, so the pipeline generates a *synthetic
language* with TinyStories-like statistics: a small vocabulary of "words"
with Zipfian frequencies, Markov bigram structure, and sentence/story
delimiters.  It is deterministic (seeded), infinite, shardable per host,
and exercises every real pipeline concern: tokenization, document packing,
shuffling windows, per-host sharding, and checkpointable iterator state.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int = 32000
    seq_len: int = 1024
    batch_size: int = 8            # per-host batch
    seed: int = 0                  # stream position seed (per host / eval)
    language_seed: int = 42        # fixes the synthetic LANGUAGE (bigram
                                   # structure) — train and eval streams
                                   # must share it or perplexity is
                                   # measured against a different language
    n_special: int = 4             # pad=0, bos=1, eos=2, sep=3
    zipf_a: float = 1.1            # word-frequency skew
    mean_doc_len: int = 180        # tokens per "story"
    host_id: int = 0
    n_hosts: int = 1


PAD, BOS, EOS, SEP = 0, 1, 2, 3


class SyntheticTinyStories:
    """Deterministic Markov-bigram token stream packed into fixed windows.

    State (``state()``/``restore()``) is a tiny tuple, checkpointed with
    the train state so restarts resume the exact stream position.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._rng = np.random.default_rng(
            [cfg.seed, cfg.host_id])
        v = cfg.vocab_size - cfg.n_special
        # Zipfian unigram distribution over the non-special vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._unigram = probs / probs.sum()
        # sparse bigram tendencies: each word prefers a small successor set
        g = np.random.default_rng(cfg.language_seed)
        self._succ = g.integers(0, v, size=(v, 8))
        self._step = 0
        self._buf = np.empty(0, np.int32)

    # -- iterator state ----------------------------------------------------
    def state(self) -> dict:
        return {"rng": self._rng.bit_generator.state, "step": self._step,
                "buf": self._buf.tolist()}

    def restore(self, st: dict) -> None:
        self._rng.bit_generator.state = st["rng"]
        self._step = int(st["step"])
        self._buf = np.asarray(st.get("buf", []), np.int32)

    # -- generation ---------------------------------------------------------
    def _doc(self) -> np.ndarray:
        cfg = self.cfg
        n = max(8, int(self._rng.exponential(cfg.mean_doc_len)))
        v = cfg.vocab_size - cfg.n_special
        out = np.empty(n, np.int32)
        w = int(self._rng.choice(v, p=self._unigram))
        for i in range(n):
            out[i] = w + cfg.n_special
            if self._rng.random() < 0.7:       # follow bigram structure
                w = int(self._succ[w, self._rng.integers(0, 8)])
            else:                              # or resample from unigram
                w = int(self._rng.choice(v, p=self._unigram))
        return out

    def _next_window(self) -> np.ndarray:
        """Next packed window (documents joined with BOS/EOS)."""
        need = self.cfg.seq_len + 1            # inputs + shifted labels
        while len(self._buf) < need:
            doc = self._doc()
            self._buf = np.concatenate(
                [self._buf, [BOS], doc, [EOS]]).astype(np.int32)
        out = self._buf[:need]
        self._buf = self._buf[need:]
        return out

    def batches(self) -> Iterator[dict]:
        """{'tokens': (B, S), 'labels': (B, S)} int32, per host shard."""
        cfg = self.cfg
        while True:
            window = np.stack([self._next_window()
                               for _ in range(cfg.batch_size)])
            self._step += 1
            yield {"tokens": window[:, :-1].astype(np.int32),
                   "labels": window[:, 1:].astype(np.int32)}


def eval_batches(cfg: DataConfig, n_batches: int = 8) -> list:
    """A fixed held-out set (different seed stream) for perplexity evals."""
    ecfg = dataclasses.replace(cfg, seed=cfg.seed + 10_000)
    it = SyntheticTinyStories(ecfg).batches()
    return [next(it) for _ in range(n_batches)]
