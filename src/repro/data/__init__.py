"""Data pipeline: synthetic TinyStories-like stream, packing, sharding."""
from repro.data.pipeline import DataConfig, SyntheticTinyStories, eval_batches
