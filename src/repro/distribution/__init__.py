"""Distribution: sharding rules for params, data, caches."""
