"""Sharding rules: pytree paths -> PartitionSpec, per mode (train / serve).

The mesh is (data=16, model=16), optionally with a leading pure-DP "pod"
axis.  Scheme (DESIGN.md §4):

TRAIN / PREFILL (Megatron-style TP over `model`):
  * embedding + LM head: vocab on `model` (the chunked CE loss all-reduces
    logsumexp stats across vocab shards),
  * attention: q heads on `model` (head-structured weights — GSPMD pads
    when H % 16 != 0); KV heads sharded only when divisible, else
    replicated (small; blockwise attention broadcasts them to H),
  * MLP: column-parallel w1/w3, row-parallel w2,
  * MoE: experts on `model` (EP),
  * Mamba2: d_inner and everything aligned with it (heads, conv channels,
    gated-norm gamma) on `model`; B/C projections replicated (tiny),
  * batch on (`pod`, `data`).

SERVE (decode): identical except
  * attention projections shard the d_model *contraction*
    (``serve_attn_shard='din'``): at a few rows per chip every matmul is a
    GEMV, so row-parallel + one small all-reduce beats head-column
    sharding whose KV heads don't divide the axis,
  * the KV cache shards its *sequence* axis on `model` (flash-decode
    sequence parallelism) unless KVH divides the axis.
Both serve choices are hillclimb knobs (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantizedTensor


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def dp_axes(mesh) -> Any:
    """The batch-carrying mesh axes: ('pod','data') multi-pod, 'data' else."""
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return axes if len(axes) > 1 else axes[0]


def _pad(spec_tail: tuple, rank: int) -> P:
    """Left-pad with None for stacked leading (layer/superblock) dims."""
    return P(*([None] * (rank - len(spec_tail)) + list(spec_tail)))


def _rule(path: str, rank: int, cfg: ModelConfig, model_size: int,
          mode: str) -> P:
    if mode == "train" and cfg.train_shard == "dp":
        # pure data parallelism: params replicated, batch over ALL axes —
        # the right regime for small models where TP collectives dominate
        # (hillclimbed on whisper-small, EXPERIMENTS.md §Perf)
        return P(*([None] * rank))

    kv_div = cfg.n_kv_heads > 0 and cfg.n_kv_heads % model_size == 0

    if re.search(r"^(embed|lm_head)$", path):
        return P("model", None)
    if re.search(r"enc_pos|dec_pos", path):
        return P(None, None)
    if re.search(r"norm|gamma|beta", path):
        if "/ssm/" in path:                      # gated-norm gamma (d_inner,)
            return _pad(("model",), rank)
        return _pad((None,), rank)

    # --- attention (head-structured: wq/wk/wv (H, hd, D), wo (D, H, hd)) ---
    # Explicit NamedShardings must divide exactly, so the head axis is
    # only sharded when H % model == 0; otherwise fall back to sharding
    # head_dim (always a multiple of 16 here) — costs a rope halo
    # exchange + per-projection all-reduce, logged as a §Perf finding.
    h_div = cfg.n_heads > 0 and cfg.n_heads % model_size == 0
    if re.search(r"/(attn|cross)/w[qkv]$", path):
        is_kv = path.endswith("wk") or path.endswith("wv")
        if mode == "serve" and cfg.serve_attn_shard == "din":
            return _pad((None, None, "model"), rank)
        if is_kv:
            # KV must be layout-consistent with Q: replicated when Q is
            # head-sharded (the broadcast to H then slices locally —
            # a KV/Q axis mismatch triggers involuntary full
            # rematerialization in SPMD), hd-sharded when Q is.
            if kv_div:
                return _pad(("model", None, None), rank)
            if h_div:
                return _pad((None, None, None), rank)
            if cfg.hd() % model_size == 0:
                return _pad((None, "model", None), rank)
            return _pad((None, None, None), rank)
        if h_div:
            return _pad(("model", None, None), rank)
        if cfg.hd() % model_size == 0:
            return _pad((None, "model", None), rank)
        return _pad((None, None, None), rank)
    if re.search(r"/(attn|cross)/wo$", path):
        if h_div:
            return _pad((None, "model", None), rank)
        if cfg.hd() % model_size == 0:
            return _pad((None, None, "model"), rank)
        return _pad((None, None, None), rank)

    # --- MoE (E leading: expert parallelism) ---
    if path.endswith("router"):
        return _pad((None, None), rank)
    if re.search(r"/moe/w[13]$", path):
        if cfg.moe_shard == "ep_data":
            # FSDP-EP: experts over `data`, d_ff over `model` — the only
            # layout where a ~400B MoE fits 16 GB/chip (params, grads and
            # Adam moments all shard over BOTH axes; expert-gradient sync
            # is free since data shards own disjoint experts)
            return _pad(("data", "model", None), rank)
        return _pad(("model", None, None), rank)
    if re.search(r"/moe/w2$", path):
        if cfg.moe_shard == "ep_data":
            return _pad(("data", None, "model"), rank)
        return _pad(("model", None, None), rank)

    # --- fused decode GEMV operands (transformer.fuse_decode_weights) ---
    # wqkv ((H+2KVH)*hd, D), w13 (2*d_ff, D), wo_f (D, H*hd).  Serving
    # stores these sharded for per-device weight-memory scaling; the
    # serve-mode attention choice follows serve_attn_shard like the
    # unfused projections (din = contraction sharded).
    if re.search(r"/attn/wqkv$", path):
        if mode == "serve" and cfg.serve_attn_shard == "din":
            return _pad((None, "model"), rank)
        return _pad(("model", None), rank)
    if re.search(r"/attn/wo_f$", path):
        if mode == "serve" and cfg.serve_attn_shard == "din":
            return _pad((None, "model"), rank)
        return _pad(("model", None), rank)
    if re.search(r"/mlp/w13$", path):
        return _pad(("model", None), rank)

    # --- dense MLP ---
    if re.search(r"/mlp/w[13]$", path):
        return _pad(("model", None), rank)
    if re.search(r"/mlp/w2$", path):
        return _pad((None, "model"), rank)

    # --- Mamba2 ---
    if re.search(r"/ssm/w[zx]$", path):
        return _pad(("model", None), rank)
    if re.search(r"/ssm/w[BC]$", path):
        return _pad((None, None), rank)
    if re.search(r"/ssm/wdt$", path):            # heads follow d_inner shards
        return _pad(("model", None), rank)
    if re.search(r"conv_x_bias$", path):
        return _pad(("model",), rank)
    if re.search(r"conv_[BC]_bias$", path):
        return _pad((None,), rank)
    if re.search(r"conv_x$", path):
        return _pad(("model", None), rank)
    if re.search(r"conv_[BC]$", path):
        return _pad((None, None), rank)
    if re.search(r"A_log$|dt_bias$|D_skip$", path):
        return _pad(("model",), rank)
    if path.endswith("out_proj"):
        return _pad((None, "model"), rank)

    return P(*([None] * rank))


def sanitize(spec: P, shape: tuple, mesh) -> P:
    """Null out any spec entry whose dim doesn't divide the axis size —
    explicit NamedShardings must divide exactly (no GSPMD padding at the
    jit boundary).

    Degrades, never raises: an over-long spec is truncated to the
    array's rank and axis names the mesh doesn't carry fall back to
    replication.  Serving calls this mid-admission (paged-pool layouts
    with odd KV-head counts or tiny block sizes), where raising would
    turn a spec mismatch into a failed request."""
    parts = list(spec)[:len(shape)] + \
        [None] * max(0, len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, parts):
        if axis is None:
            out.append(None)
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        if any(a not in mesh.shape for a in axes):
            out.append(None)
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(axis if size > 0 and dim % size == 0 else None)
    return P(*out)


def _spec_for_leaf(path: str, leaf, cfg, mesh, mode: str):
    model_size = mesh.shape["model"]
    if isinstance(leaf, QuantizedTensor):
        # codes keep the float weight's spec; scales shrink the grouped
        # last axis (and Q4 packs it 2:1) — sanitize drops entries that
        # no longer divide.
        spec = _rule(path, len(leaf.q.shape), cfg, model_size, mode)
        return QuantizedTensor(
            q=sanitize(spec, leaf.q.shape, mesh),
            scale=sanitize(spec, leaf.scale.shape, mesh),
            group_size=leaf.group_size, bits=leaf.bits, orig_dim=leaf.orig_dim)
    spec = _rule(path, len(leaf.shape), cfg, model_size, mode)
    return sanitize(spec, leaf.shape, mesh)


def param_specs(cfg: ModelConfig, params: Any, mesh, mode: str = "train"
                ) -> Any:
    """Pytree of PartitionSpec matching ``params`` (arrays or ShapeDtype)."""
    def visit(path, leaf):
        return _spec_for_leaf(_path_str(path), leaf, cfg, mesh, mode)

    return jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda x: isinstance(x, QuantizedTensor))


def _dp_size(mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        if a != "model":
            n *= mesh.shape[a]
    return n


def batch_axes_for(cfg: ModelConfig, mesh, mode: str = "train"):
    """Mesh axes carrying the batch dim.  Pure-DP training uses ALL axes
    (the model axis holds no params); otherwise everything but `model`.
    Falls back to fewer axes until the product divides nothing is the
    caller's job (see ``_best_batch_spec``)."""
    if mode == "train" and cfg.train_shard == "dp":
        return tuple(mesh.axis_names)
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return axes


def _best_batch_spec(cfg: ModelConfig, mesh, bdim: int, mode: str):
    """Largest suffix of the batch axes whose product divides ``bdim``."""
    axes = batch_axes_for(cfg, mesh, mode)
    while axes:
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if bdim % size == 0:
            return axes if len(axes) > 1 else axes[0]
        axes = axes[1:]          # drop the outermost (pod first)
    return None


def data_specs(cfg: ModelConfig, batch: Any, mesh, mode: str = "train"
               ) -> Any:
    """Input batch: batch dim over the batch axes; m-rope positions are
    (3, B, S) so the batch dim sits second.  A batch smaller than the
    batch axes (long_500k: B=1) is replicated — the data axis sits idle
    for a single-request latency shape."""

    def visit(path, leaf):
        p = _path_str(path)
        r = len(leaf.shape)
        if r == 0:
            return P()
        if "positions" in p and r == 3:          # m-rope (3, B, S)
            return P(None, _best_batch_spec(cfg, mesh, leaf.shape[1], mode),
                     None)
        return P(_best_batch_spec(cfg, mesh, leaf.shape[0], mode),
                 *([None] * (r - 1)))

    return jax.tree_util.tree_map_with_path(visit, batch)


def pool_model_axis(cfg: ModelConfig, mesh) -> Any:
    """The mesh axis the paged KV pool shards over, or None.

    The pool shards its KV-heads dim — per-head attention math is local
    (heads only mix at the wo contraction), so a KVH split keeps every
    floating-point reduction on one device and the engine's bitwise
    stream contract intact.  Degrades to replication when KVH doesn't
    divide the model axis (odd head counts)."""
    msize = mesh.shape.get("model", 1)
    if msize <= 1:
        # sharding over a size-1 axis is replication; GSPMD normalizes
        # it away on jit outputs, so naming the axis here would make the
        # initial device_put placement miss the steady-state executable
        return None
    if cfg.n_kv_heads > 0 and cfg.n_kv_heads % msize == 0:
        return "model"
    return None


def _canon(spec: P) -> P:
    """Drop trailing Nones.  PartitionSpec compares as a tuple, and
    jit-normalized output shardings come back without trailing Nones — an
    explicit-trailing-None device_put spec would differ from the first
    step's output sharding in the donated-cache jit key and buy a
    spurious second executable per mesh."""
    parts = list(spec)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def paged_cache_specs(cfg: ModelConfig, cache: Any, mesh) -> Any:
    """Paged-pool sharding: KV pool (L, N, bs, KVH, hd) splits KVH over
    `model` (see ``pool_model_axis``); int8 scale pools (L, N, bs, KVH)
    follow; page_table / lens are host-authored control state and stay
    replicated.  Specs are canonical (no trailing Nones) so the engine's
    initial device_put placement hits the same executable as the steady
    state where the donated cache cycles through jit outputs."""
    kvh_ax = pool_model_axis(cfg, mesh)

    def visit(path, leaf):
        p = _path_str(path)
        if p.endswith("/k") or p.endswith("/v"):
            return _canon(sanitize(P(None, None, None, kvh_ax, None),
                                   leaf.shape, mesh))
        if p.endswith("/ks") or p.endswith("/vs"):
            return _canon(sanitize(P(None, None, None, kvh_ax),
                                   leaf.shape, mesh))
        return P()

    return jax.tree_util.tree_map_with_path(visit, cache)


def cache_specs(cfg: ModelConfig, cache: Any, mesh) -> Any:
    """Decode-state sharding.

    Attention K/V (…lead, B, S, KVH, hd): KVH on `model` when divisible,
    else S on `model` (flash-decode SP).  SSM state (…, B, H, P, N): heads
    on `model`.  Conv ring buffers: channels on `model` for the x buffer
    (path …/conv/0), replicated for tiny B/C buffers.
    """
    if isinstance(cache, dict) and "page_table" in cache:
        return paged_cache_specs(cfg, cache, mesh)

    dp = dp_axes(mesh)
    dsz = _dp_size(mesh)
    msize = mesh.shape["model"]
    kv_div = cfg.n_kv_heads > 0 and cfg.n_kv_heads % msize == 0

    def bspec(bdim):
        return dp if bdim % dsz == 0 else None

    def visit(path, leaf):
        p = _path_str(path)
        r = len(leaf.shape)
        if p.endswith("lens"):
            return P(bspec(leaf.shape[0]))
        if p.endswith("/k") or p.endswith("/v"):
            lead = r - 4                         # (…, B, S, KVH, hd)
            b, s = leaf.shape[lead], leaf.shape[lead + 1]
            if kv_div:
                tail = (None, "model", None)
            elif s % msize == 0:
                tail = ("model", None, None)     # flash-decode SP over S
            else:
                tail = (None, None, None)
            return P(*([None] * lead + [bspec(b)] + list(tail)))
        if p.endswith("/ks") or p.endswith("/vs"):
            lead = r - 3                         # (…, B, S, KVH)
            b, s = leaf.shape[lead], leaf.shape[lead + 1]
            if kv_div:
                tail = (None, "model")
            elif s % msize == 0:
                tail = ("model", None)
            else:
                tail = (None, None)
            return P(*([None] * lead + [bspec(b)] + list(tail)))
        if p.endswith("state"):                  # (…, B, H, P, N)
            lead = r - 4
            h = leaf.shape[lead + 1]
            return P(*([None] * lead +
                       [bspec(leaf.shape[lead]),
                        "model" if h % msize == 0 else None, None, None]))
        if "/conv/" in p:                        # (…, B, W-1, C)
            lead = r - 3
            ch = "model" if p.endswith("/0") and \
                leaf.shape[-1] % msize == 0 else None
            return P(*([None] * lead + [bspec(leaf.shape[lead]), None, ch]))
        return P(*[bspec(leaf.shape[0])] + [None] * (r - 1))

    return jax.tree_util.tree_map_with_path(visit, cache)


def to_shardings(tree_of_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))
