"""Fault-tolerance runtime: heartbeats, stragglers, elastic replanning."""
from repro.runtime.health import (ElasticPlan, HeartbeatMonitor,
                                  StragglerDetector, plan_elastic)
