"""Fault-tolerance runtime: heartbeats, straggler detection, elastic resize.

This container has one real device, so the *mechanisms* are implemented
against an abstract host registry and unit-tested with simulated clocks
and injected failures; the launcher wires the same objects to real hosts
(heartbeat = per-host file/RPC timestamp).

Three mechanisms (DESIGN.md §4, "design for 1000+ nodes"):

  HeartbeatMonitor    every host stamps a monotonic counter each step;
                      hosts silent for > ``timeout_steps`` are suspects.
                      **Launcher-only**: nothing in-process consumes it
                      (a single-host engine cannot miss its own beat).
  StragglerDetector   per-step durations; hosts slower than
                      ``threshold`` x the rolling median get flagged —
                      the launcher re-slices their data shard (work
                      stealing) or schedules them for replacement.
                      The serving engine also runs one single-host
                      instance (``record_slow``) and surfaces flagged
                      steps as ``Engine.metrics["slow_steps"]``.
  ElasticPlan         given the dead-host set, computes the largest
                      usable (pod, data) slice that preserves the model
                      axis (TP groups must stay whole), and the
                      re-sharding plan for the data axis: which
                      checkpoint shards each surviving host reloads.
                      **Launcher-only**, like HeartbeatMonitor.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Dict, List, Optional, Sequence, Set


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    last_step: int = -1


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, timeout_s: float = 60.0,
                 clock=time.monotonic):
        self._clock = clock
        self.timeout_s = timeout_s
        now = clock()
        self.hosts: Dict[int, HostState] = {
            h: HostState(h, now) for h in range(n_hosts)}

    def beat(self, host_id: int, step: int) -> None:
        st = self.hosts[host_id]
        st.last_beat = self._clock()
        st.last_step = max(st.last_step, step)

    def dead_hosts(self) -> Set[int]:
        now = self._clock()
        return {h for h, st in self.hosts.items()
                if now - st.last_beat > self.timeout_s}

    def max_step(self) -> int:
        return max((st.last_step for st in self.hosts.values()), default=-1)


class StragglerDetector:
    """Rolling-median step-time comparison (per host)."""

    def __init__(self, n_hosts: int, window: int = 16,
                 threshold: float = 1.8):
        self.window = window
        self.threshold = threshold
        self._times: Dict[int, deque] = defaultdict(
            lambda: deque(maxlen=window))

    def record(self, host_id: int, step_time_s: float) -> None:
        self._times[host_id].append(step_time_s)

    def record_slow(self, host_id: int, step_time_s: float) -> bool:
        """Record one step and return True when it is a straggler step
        *relative to this host's own rolling median* — the single-host
        form of :meth:`stragglers` (which needs a fleet to compare
        against).  The comparison runs before the sample joins the
        window, so one slow step cannot hide itself by dragging the
        median up; it stays False until the window is half warm."""
        ts = self._times[host_id]
        slow = (len(ts) >= max(self.window // 2, 2)
                and step_time_s > self.threshold * self._median(ts))
        ts.append(step_time_s)
        return slow

    def _median(self, xs: Sequence[float]) -> float:
        s = sorted(xs)
        return s[len(s) // 2]

    def stragglers(self) -> Set[int]:
        per_host = {h: self._median(ts) for h, ts in self._times.items()
                    if len(ts) >= max(self.window // 2, 2)}
        if len(per_host) < 2:
            return set()
        fleet = self._median(list(per_host.values()))
        return {h for h, m in per_host.items()
                if m > self.threshold * fleet}


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Result of re-planning after failures."""
    usable_hosts: tuple
    new_data_size: int          # shrunk data axis
    new_pod_size: int
    reassigned_shards: dict     # data-shard index -> host id


def plan_elastic(n_pods: int, hosts_per_pod: int, model_hosts: int,
                 dead: Set[int]) -> Optional[ElasticPlan]:
    """Shrink the data axis to exclude dead hosts.

    Host topology: host id = ((pod * data_size) + data_idx) — each
    "host row" owns one data-parallel slice holding all 16 model shards
    (model groups never split across hosts here, matching the v5e pod
    slicing where a TP=16 group is one tray).

    A dead host kills its data slice; the plan drops it, renumbers the
    data axis, and maps every surviving slice to a checkpoint shard.  If
    a whole pod dies, the pod axis shrinks instead.  Returns None if
    nothing survives.
    """
    alive_by_pod: Dict[int, List[int]] = {}
    for pod in range(n_pods):
        rows = [pod * hosts_per_pod + r for r in range(hosts_per_pod)]
        alive_by_pod[pod] = [h for h in rows if h not in dead]

    pods_alive = {p: rows for p, rows in alive_by_pod.items() if rows}
    if not pods_alive:
        return None
    # keep the data axis uniform across pods: min alive rows per pod
    new_data = min(len(rows) for rows in pods_alive.values())
    # prefer power-of-two/divisor sizes so global batch still divides
    while new_data > 1 and hosts_per_pod % new_data:
        new_data -= 1
    usable = []
    reassign = {}
    shard = 0
    for p, rows in sorted(pods_alive.items()):
        for h in rows[:new_data]:
            usable.append(h)
            reassign[shard] = h
            shard += 1
    return ElasticPlan(usable_hosts=tuple(usable),
                       new_data_size=new_data,
                       new_pod_size=len(pods_alive),
                       reassigned_shards=reassign)
