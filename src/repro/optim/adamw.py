"""AdamW + schedule + clipping + (beyond-paper) gradient compression.

No optax in this environment — a small, shardable implementation.  The
optimizer state pytree mirrors the params, so every param PartitionSpec
applies verbatim to m/v (ZeRO-style further sharding of optimizer state
over the data axis is applied by the caller via spec rewrite — see
``zero_specs``).

Gradient compression (int8 + error feedback) implements the paper's core
trick — Q8_0 symmetric group quantization — on the *gradient all-reduce*
path: a distributed-optimization extension of HLSTransform's idea.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_compress_bits: int = 0    # 0 = off; 8 = int8 error-feedback


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to lr_min."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params: Any) -> dict:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def compress_decompress(g: jax.Array, err: jax.Array, group: int = 256):
    """Q8_0 round-trip with error feedback — models the compressed
    all-reduce: what survives the wire is the int8 codes + scales."""
    flat = (g.astype(jnp.float32) + err).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % group
    fp = jnp.pad(flat, (0, pad)).reshape(-1, group)
    absmax = jnp.max(jnp.abs(fp), axis=-1, keepdims=True)
    scale = absmax / 127.0
    inv = jnp.where(absmax > 0, 127.0 / absmax, 0.0)
    q = jnp.clip(jnp.round(fp * inv), -127, 127)
    deq = (q * scale).reshape(-1)[:n].reshape(g.shape)
    new_err = (flat[:n].reshape(g.shape) - deq)
    return deq.astype(g.dtype), new_err


def apply_updates(params: Any, opt_state: dict, grads: Any,
                  cfg: AdamWConfig, compress_err: Optional[Any] = None):
    """One AdamW step; returns (params, opt_state, metrics, new_err)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    new_err = compress_err
    if cfg.grad_compress_bits == 8 and compress_err is not None:
        gflat, treedef = jax.tree_util.tree_flatten(grads)
        eflat = treedef.flatten_up_to(compress_err)
        outs = [compress_decompress(g, e) for g, e in zip(gflat, eflat)]
        grads = treedef.unflatten([o[0] for o in outs])
        new_err = treedef.unflatten([o[1] for o in outs])

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree_util.tree_map(upd, params, grads, opt_state["m"],
                                 opt_state["v"])
    params2 = jax.tree_util.tree_map(lambda _, o: o[0], params, out)
    m2 = jax.tree_util.tree_map(lambda _, o: o[1], params, out)
    v2 = jax.tree_util.tree_map(lambda _, o: o[2], params, out)
    metrics = {"lr": lr, "grad_norm": gnorm, "step": step}
    return params2, {"m": m2, "v": v2, "step": step}, metrics, new_err
