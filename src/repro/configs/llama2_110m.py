"""Llama-2 110M (TinyStories) — the paper's own evaluation model.

Karpathy llama2.c dims (paper Appendix A.1): 12 layers, d_model 768,
12 heads, 12 KV heads, 1024 context, 32000 vocab SentencePiece.
This is the config the paper-faithful quality/throughput/energy
benchmarks run against.
"""
from repro.configs.base import ModelConfig, register


@register("llama2-110m")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="llama2-110m", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=2048, vocab_size=32000, head_dim=64,
        rope_theta=1e4, compute_dtype="float32",
    )
