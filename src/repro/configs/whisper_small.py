"""Whisper-small — encoder-decoder audio backbone [arXiv:2212.04356].

Conv frontend is a stub: input_specs() provides precomputed frame
embeddings (B, 1500, D).  12 encoder + 12 decoder layers, LayerNorm+GELU,
learned decoder positions (no RoPE), MHA (kv = heads).
"""
from repro.configs.base import ModelConfig, register


@register("whisper-small")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-small", family="audio",
        n_layers=12, n_enc_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=3072, vocab_size=51865, head_dim=64,
        rope_type="none", norm_type="layernorm", mlp_type="gelu",
        enc_seq=1504,      # 1500 frames padded to a TP-divisible length
        train_shard="dp",  # 242M params: pure DP beats TP collectives
        frontend="audio_stub", tie_embeddings=True,
    )
