"""Architecture configs — one module per assigned arch (+ the paper's 110M)."""
from repro.configs.base import (LM_SHAPES, ModelConfig, ShapeCell,
                                get_config, list_configs, reduced,
                                shapes_for)

__all__ = ["LM_SHAPES", "ModelConfig", "ShapeCell", "get_config",
           "list_configs", "reduced", "shapes_for"]
