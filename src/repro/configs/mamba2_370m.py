"""Mamba2-370M — attention-free SSD [arXiv:2405.21060].

Sub-quadratic: runs the long_500k cell (state-space recurrence decode).
The paper technique (Q8_0 weight quantization) applies to in/out
projections; SSM dynamics params stay fp32 (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig, register


@register("mamba2-370m")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
        conv_width=4, rope_type="none", subquadratic=True,
    )
