"""Qwen3-30B-A3B — MoE, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig, register


@register("qwen3-moe-30b-a3b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
        d_ff=768, vocab_size=151936, head_dim=64,
        n_experts=128, top_k=8,
        rope_theta=1e6, param_dtype="bfloat16",
        moe_shard="ep_data",
    )
