"""Zamba2-1.2B — Mamba2 backbone + shared attention block [arXiv:2411.15242].

Hybrid: 38 Mamba2 layers with ONE shared attention+MLP block applied after
every 6th SSM layer (params shared across applications, as in Zamba2).
Sub-quadratic end-to-end: runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, register


@register("zamba2-1.2b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32000, head_dim=64,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
        conv_width=4, attn_every=6, rope_theta=1e4, subquadratic=True,
    )
