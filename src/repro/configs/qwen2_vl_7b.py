"""Qwen2-VL-7B — VLM backbone with M-RoPE [arXiv:2409.12191].

Vision frontend is a stub per the assignment: input_specs() provides
precomputed patch embeddings (B, S, D) plus (3, B, S) m-rope positions.
"""
from repro.configs.base import ModelConfig, register


@register("qwen2-vl-7b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen2-vl-7b", family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab_size=152064, head_dim=128,
        rope_theta=1e6, rope_type="mrope", mrope_sections=(16, 24, 24),
        frontend="vision_stub",
    )
