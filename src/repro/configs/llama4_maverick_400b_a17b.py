"""Llama-4-Maverick 400B-A17B — interleaved MoE, 128 experts top-1
[hf:meta-llama/Llama-4-Maverick-17B-128E].

moe_every=2 (alternating dense / MoE layers, llama4 interleave) puts the
total at ~400B with ~17B active — matching the name; an all-MoE stack at
these dims would be ~775B.  Multimodal early fusion is out of scope (text
backbone only, per the assignment's LM-family framing).
"""
from repro.configs.base import ModelConfig, register


@register("llama4-maverick-400b-a17b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab_size=202048, head_dim=128,
        n_experts=128, top_k=1, moe_every=2,
        rope_theta=5e5, param_dtype="bfloat16", moe_shard="ep_data",
    )
