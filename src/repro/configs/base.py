"""Model / run configuration schema and the architecture registry."""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # positions / attention
    rope_theta: float = 1e4
    rope_type: str = "rope"     # rope | mrope | none
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    q_chunk: int = 512          # blockwise-attention query chunk

    # norm / activation
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    mlp_type: str = "swiglu"    # swiglu | gelu

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_group: int = 512
    capacity_factor: float = 1.25
    moe_every: int = 1          # llama4-style interleave: MoE every k-th layer

    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0         # hybrid: shared attn block every k ssm layers

    # encoder-decoder (audio)
    n_enc_layers: int = 0
    enc_seq: int = 1500         # whisper 30 s of frames
    max_pos: int = 32_768       # learned positional table (enc-dec decoder)
    frontend: str = "none"      # none | audio_stub | vision_stub

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "block"        # none | block
    tie_embeddings: bool = True
    eps: float = 1e-5

    # serving
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8
    subquadratic: bool = False  # may run long_500k
    serve_attn_shard: str = "din"      # din | heads (decode TP for attn)

    # distribution strategy knobs (per-arch; hillclimb targets)
    moe_shard: str = "model"    # model: EP over TP axis | ep_data: experts
                                # over the data axis + F over model (FSDP-EP:
                                # required when total params >> TP-axis HBM)
    train_shard: str = "tp"     # tp: Megatron TP over `model` | dp: pure
                                # data parallel over ALL axes (small models
                                # where TP collectives dominate compute)

    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def padded_vocab(self, multiple: int = 256) -> int:
        """Embedding/LM-head table rows, padded so the vocab dim divides
        every TP axis (Megatron's make_vocab_size_divisible_by).  Labels
        and tokens always stay < vocab_size; padded logits participate in
        the softmax like any never-observed token."""
        return -(-self.vocab_size // multiple) * multiple

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


LM_SHAPES = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import the module of the same name to trigger registration
        import importlib
        mod = name.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[name]()


def list_configs() -> list[str]:
    import importlib
    import pkgutil
    import repro.configs as pkg
    for mod in pkgutil.iter_modules(pkg.__path__):
        if mod.name not in ("base", "__init__"):
            importlib.import_module(f"repro.configs.{mod.name}")
    return sorted(_REGISTRY)


def shapes_for(cfg: ModelConfig) -> list[ShapeCell]:
    """The assigned shape cells that apply to this architecture.

    ``long_500k`` needs sub-quadratic sequence mixing — runs only for
    SSM/hybrid archs (see DESIGN.md §Arch-applicability for the skip notes).
    """
    cells = []
    for c in LM_SHAPES:
        if c.name == "long_500k" and not cfg.subquadratic:
            continue
        cells.append(c)
    return cells


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (full configs are only
    ever lowered, never instantiated)."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab_size=512,
        q_chunk=64,
        ssm_chunk=32,
        moe_group=64,
    )
    if cfg.rope_type == "mrope":
        kw.update(mrope_sections=(4, 6, 6))   # half of the reduced head_dim
    if cfg.n_experts:
        kw.update(n_experts=8, top_k=min(cfg.top_k, 2))
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16)
    if cfg.attn_every:
        kw.update(n_layers=5, attn_every=2)
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2, enc_seq=64)
    return cfg.with_(**kw)
