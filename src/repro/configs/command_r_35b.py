"""Command-R 35B — dense GQA, no biases [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ModelConfig, register


@register("command-r-35b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="command-r-35b", family="dense",
        n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22528, vocab_size=256000, head_dim=128,
        rope_theta=8e6,
    )
