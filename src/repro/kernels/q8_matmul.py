"""Grouped-int8 matmul Pallas kernel — the paper's matmul engine on TPU.

The FPGA design streams 64 int8 weights per cycle over AXI4 into on-chip
buffers and MACs them against a resident activation vector, rescaling each
group by ``xs * ws``.  The TPU-native rendering:

  * "burst reads"  -> BlockSpec-driven HBM->VMEM tiles of the int8 weight
                      matrix; the Pallas grid double-buffers them (the
                      paper's `#pragma pipeline`).
  * "unrolling"    -> each grid step issues batched 128-lane int8 dots on
                      the MXU (depth = the quant group, 64) instead of the
                      FPGA's replicated MAC trees.
  * "partitioning" -> accumulator + per-group partials live in VMEM
                      scratch, sized by the block shapes below.

Exact semantics (matches ``repro.core.quantization.qmatmul_ref``):

    out[m, n] = sum_g  f32( dot_int32(xq[m, g, :], wq[n, g, :]) )
                       * xs[m, g] * ws[n, g]

int8 x int8 products accumulate in int32 inside each group of
``group_size`` (exact — no rounding), groups combine in f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import compiler_params


def _kernel(xq_ref, xs_ref, wq_ref, ws_ref, o_ref, *, group_size: int,
            n_k_blocks: int):
    """One (bm, bn) output tile; grid dim 2 walks the K blocks."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bm, bk = xq_ref.shape
    bn = wq_ref.shape[0]
    g_blk = bk // group_size

    xq = xq_ref[...].reshape(bm, g_blk, group_size)
    wq = wq_ref[...].reshape(bn, g_blk, group_size)
    # Batched int8 dot over the group axis: (g, bm, gs) x (g, bn, gs)
    # -> (g, bm, bn) int32.  Depth-64 contractions ride the MXU; int32
    # accumulation inside a group is exact.
    part = jax.lax.dot_general(
        xq.swapaxes(0, 1), wq.swapaxes(0, 1),
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.int32)                  # (g_blk, bm, bn)
    xs = xs_ref[...]                                       # (bm, g_blk)
    ws = ws_ref[...]                                       # (bn, g_blk)
    scaled = part.astype(jnp.float32) \
        * xs.T[:, :, None] * ws.T[:, None, :]              # (g_blk, bm, bn)
    o_ref[...] += jnp.sum(scaled, axis=0)


def q8_matmul_pallas(xq: jax.Array, xs: jax.Array, wq: jax.Array,
                     ws: jax.Array, *, group_size: int = 64,
                     block_m: int = 128, block_n: int = 256,
                     block_k: int = 512, interpret: bool = False
                     ) -> jax.Array:
    """out = (xq*xs) @ (wq*ws).T with integer-exact group accumulation.

    xq: (M, K) int8    xs: (M, K/gs) f32
    wq: (N, K) int8    ws: (N, K/gs) f32
    returns (M, N) f32.  M, N, K must divide the block shapes (the ops.py
    wrapper pads); block_k must be a multiple of group_size.
    """
    m, k = xq.shape
    n = wq.shape[0]
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if block_k % group_size:
        raise ValueError(f"block_k {block_k} not a multiple of group {group_size}")
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(f"unpadded dims ({m},{n},{k}) vs blocks "
                         f"({block_m},{block_n},{block_k})")
    gs_blk = block_k // group_size
    n_k_blocks = k // block_k
    grid = (m // block_m, n // block_n, n_k_blocks)

    return pl.pallas_call(
        functools.partial(_kernel, group_size=group_size,
                          n_k_blocks=n_k_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_m, gs_blk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((block_n, gs_blk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel",
                                             "arbitrary")),
        interpret=interpret,
    )(xq, xs, wq, ws)
