"""Fused paged chunk-attention Pallas kernel: rectangular q over paged K/V.

Chunked prefill (serving/scheduler.py) attends each prompt chunk against
the prefix rows its sequence already wrote into the shared block pool.
The jnp oracle (``layers.attention_chunk_merge``) materializes a masked
gather of every row's FULL page-table extent — O(max_prefix) HBM traffic
per chunk, dequantized up front for int8 pools.  This kernel reads the
prefix straight out of the pool instead:

    k_pool / v_pool : (n_blocks, block_size, KVH, hd)   — one layer's pool
    page_table      : (B, max_blocks) int32             — block ids, -1 free
    pfx_lens        : (B,) int32                        — prefix rows (= the
                      chunk's position offset; pool row t = global pos t)
    q               : (B, C, KVH, HQ, hd)               — the chunk queries

It is the PR-1 ``paged_decode_attention`` addressing pattern generalized
to multi-row q (the ``flash_prefill`` rectangle): the page table and the
per-row prefix/chunk lengths ride in via scalar prefetch
(``pltpu.PrefetchScalarGridSpec``), so the BlockSpec index_map
dereferences ``page_table[b, i]`` *before* the DMA is issued — the gather
IS the index_map, and no contiguous copy of the prefix ever exists in
HBM.  Dead tiles cost neither bytes nor FLOPs: KV tiles at or past
``ceil(pfx_len/block_size)`` and whole q tiles past a row's valid chunk
length clamp onto the last live tile in the index_map (Pallas recognizes
the revisit and elides the fetch) and their compute sits under
``@pl.when``.  Prefix keys all sit strictly below every live query
position, so the segment needs no causal diagonal — validity
(``pos < pfx_len``) already implies causality.

The kernel returns the *flash state* of the prefix segment — the
normalized output plus the running (max, denominator) per query — so
``layers.attention_chunk_merge`` can merge it with the chunk's own-segment
attention by its exact softmax-renormalization contract.  An empty prefix
leaves the state at (out=0, m=NEG_INF, l=0), which merges with weight
exactly zero: the whole-prompt single chunk stays bit-identical to
one-shot prefill.  Q8_0 pools dequantize in-kernel via the per-(position,
kv-head) ``ks``/``vs`` scale gathers, same as the paged decode kernel.

``return_tile_counts=True`` adds a per-(batch, kv_head) int32 output
counting tiles whose body ran — the interpret-mode proof that dead tiles
(past the prefix extent or past the chunk length) are skipped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import compiler_params

NEG_INF = -1e30


def _kernel(pt_ref, pfx_ref, qlen_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
            o_ref, m_ref, l_ref, *rest, block_q: int, block_size: int,
            n_blocks_grid: int, kv_int8: bool, count_tiles: bool):
    if count_tiles:
        cnt_ref, m_scr, l_scr, acc_scr = rest
    else:
        (m_scr, l_scr, acc_scr), cnt_ref = rest, None
    bb = pl.program_id(0)
    qi = pl.program_id(2)
    i = pl.program_id(3)                                   # logical block #
    pfx = pfx_ref[bb]
    qlen = qlen_ref[bb]

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    if count_tiles:
        @pl.when((qi == 0) & (i == 0))
        def _init_cnt():
            cnt_ref[0, 0] = 0

    # a tile runs only if it holds live prefix keys AND its q tile holds
    # live chunk rows — both bounds are prefetched data, never compile keys
    run = (i * block_size < pfx) & (qi * block_q < qlen)

    @pl.when(run)
    def _tile():
        q = q_ref[0, :, 0].astype(jnp.float32)             # (bq, hq, d)
        bq, hq, d = q.shape
        q2 = q.reshape(bq * hq, d)                         # rows = (pos, head)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bs, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if kv_int8:
            k = k * ks_ref[0, :, 0][:, None]               # dequant per pos
            v = v * vs_ref[0, :, 0][:, None]

        s = jax.lax.dot_general(
            q2, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # (bq*hq, bs)
        pos = i * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_size), 1)
        valid = pos < pfx                                  # (1, bs)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
        if count_tiles:
            cnt_ref[0, 0] += 1

    @pl.when(i == n_blocks_grid - 1)
    def _finish():
        bq, hq, d = q_ref.shape[1], q_ref.shape[3], q_ref.shape[4]
        l = l_scr[:, :1]
        out = acc_scr[...] / jnp.where(l > 0, l, 1.0)
        o_ref[0, :, 0] = out.reshape(bq, hq, d).astype(o_ref.dtype)
        m_ref[0, :, 0] = m_scr[:, 0].reshape(bq, hq)
        l_ref[0, :, 0] = l_scr[:, 0].reshape(bq, hq)


def paged_prefill_attention_pallas(q: jax.Array, k_pool: jax.Array,
                                   v_pool: jax.Array, page_table: jax.Array,
                                   pfx_lens: jax.Array, q_lens=None,
                                   ks_pool=None, vs_pool=None, *,
                                   block_q: int = 128,
                                   return_tile_counts: bool = False,
                                   interpret: bool = False):
    """q: (B, C, KVH, HQ, D) pre-scaled by 1/sqrt(D); k/v_pool:
    (NB, BS, KVH, D) (int8 when ks/vs_pool (NB, BS, KVH) given);
    page_table: (B, MB) int32 block ids (-1 = unassigned); pfx_lens: (B,)
    int32 prefix lengths (each row attends pool positions < pfx_lens[b]);
    q_lens: (B,) int32 valid chunk rows (default C; q tiles fully past it
    are skipped and their state is (0, NEG_INF, 0) garbage).

    Returns the prefix segment's flash state — out (B, C, KVH, HQ, D) f32,
    m (B, C, KVH, HQ) f32, l (B, C, KVH, HQ) f32 — plus (B, KVH) int32
    live-tile counts when ``return_tile_counts``.
    """
    b, c, kvh, hq, d = q.shape
    nb, bs, kvh_p, d_p = k_pool.shape
    if (kvh_p, d_p) != (kvh, d):
        raise ValueError(f"pool heads/dim {(kvh_p, d_p)} != q {(kvh, d)}")
    block_q = min(block_q, c)
    if c % block_q:
        raise ValueError(f"C={c} not a multiple of block_q={block_q}")
    nq = c // block_q
    mb = page_table.shape[1]
    page_table = page_table.astype(jnp.int32)
    pfx_lens = pfx_lens.reshape(b).astype(jnp.int32)
    q_lens = (jnp.full((b,), c, jnp.int32) if q_lens is None
              else jnp.asarray(q_lens, jnp.int32).reshape(b))
    kv_int8 = ks_pool is not None
    if not kv_int8:
        ks_pool = jnp.ones((nb, bs, kvh), jnp.float32)
        vs_pool = jnp.ones((nb, bs, kvh), jnp.float32)

    def _blk(bb, i, pt_ref, pfx_ref):
        # clamp dead logical blocks onto the last live one (revisit -> no
        # DMA), and -1 entries onto pool block 0: the tile body is skipped
        # for them, the fetch just needs a legal address.
        last = jnp.maximum(pl.cdiv(pfx_ref[bb], bs) - 1, 0)
        return jnp.maximum(pt_ref[bb, jnp.minimum(i, last)], 0)

    def q_map(bb, h, qi, i, pt_ref, pfx_ref, qlen_ref):
        return (bb, qi, h, 0, 0)

    def pool_map(bb, h, qi, i, pt_ref, pfx_ref, qlen_ref):
        return (_blk(bb, i, pt_ref, pfx_ref), 0, h, 0)

    def scale_map(bb, h, qi, i, pt_ref, pfx_ref, qlen_ref):
        return (_blk(bb, i, pt_ref, pfx_ref), 0, h)

    out_shape = [jax.ShapeDtypeStruct((b, c, kvh, hq, d), jnp.float32),
                 jax.ShapeDtypeStruct((b, c, kvh, hq), jnp.float32),
                 jax.ShapeDtypeStruct((b, c, kvh, hq), jnp.float32)]
    out_specs = [pl.BlockSpec((1, block_q, 1, hq, d), q_map),
                 pl.BlockSpec((1, block_q, 1, hq),
                              lambda bb, h, qi, i, pt, pf, ql:
                              (bb, qi, h, 0)),
                 pl.BlockSpec((1, block_q, 1, hq),
                              lambda bb, h, qi, i, pt, pf, ql:
                              (bb, qi, h, 0))]
    if return_tile_counts:
        out_shape.append(jax.ShapeDtypeStruct((b, kvh), jnp.int32))
        out_specs.append(pl.BlockSpec(
            (1, 1), lambda bb, h, qi, i, pt, pf, ql: (bb, h)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, kvh, nq, mb),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hq, d), q_map),
            pl.BlockSpec((1, bs, 1, d), pool_map),
            pl.BlockSpec((1, bs, 1, d), pool_map),
            pl.BlockSpec((1, bs, 1), scale_map),
            pl.BlockSpec((1, bs, 1), scale_map),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((block_q * hq, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q * hq, 128), jnp.float32),   # running sum
            pltpu.VMEM((block_q * hq, d), jnp.float32),     # acc
        ],
    )

    outs = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_size=bs,
                          n_blocks_grid=mb, kv_int8=kv_int8,
                          count_tiles=return_tile_counts),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(page_table, pfx_lens, q_lens, q, k_pool, v_pool, ks_pool, vs_pool)
    if return_tile_counts:
        return outs[0], outs[1], outs[2], outs[3]
    return outs[0], outs[1], outs[2]
