"""Decode GEMV Pallas kernel — the paper's inner loop, TPU-native.

During single-token decode every matmul in the forward pass is a GEMV
(the paper's ``matmul_768_768`` .. ``matmul_768_32000`` modules).  The FPGA
keeps the activation vector on-chip and streams weight rows; we do exactly
that in VMEM terms: the quantized activation block (a few rows — decode
batch per chip) stays resident across the whole grid, while (bn, K) int8
weight tiles stream HBM->VMEM, one per grid step, double-buffered by the
Pallas pipeline.

Distinct from q8_matmul: no K grid dimension — the full contraction happens
inside one grid step, so per-output-tile partials never round-trip to HBM.
This is the right shape when ``M*K`` (activations) fits VMEM but ``N*K``
(weights) does not, i.e. decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import compiler_params


def _kernel(xq_ref, xs_ref, wq_ref, ws_ref, o_ref, *, group_size: int):
    bm, k = xq_ref.shape
    bn = wq_ref.shape[0]
    g = k // group_size
    xq = xq_ref[...].reshape(bm, g, group_size)
    wq = wq_ref[...].reshape(bn, g, group_size)
    part = jax.lax.dot_general(
        xq.swapaxes(0, 1), wq.swapaxes(0, 1),
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.int32)                  # (g, bm, bn)
    xs = xs_ref[...]                                       # (bm, g)
    ws = ws_ref[...]                                       # (bn, g)
    scaled = part.astype(jnp.float32) * xs.T[:, :, None] * ws.T[:, None, :]
    o_ref[...] = jnp.sum(scaled, axis=0)


def q8_matvec_pallas(xq: jax.Array, xs: jax.Array, wq: jax.Array,
                     ws: jax.Array, *, group_size: int = 64,
                     block_n: int = 512, interpret: bool = False
                     ) -> jax.Array:
    """out = (xq*xs) @ (wq*ws).T, activations fully VMEM-resident.

    xq: (M, K) int8 with small M (decode batch)   xs: (M, K/gs) f32
    wq: (N, K) int8                               ws: (N, K/gs) f32
    N must divide block_n (ops.py pads).  VMEM check: block_n*K int8 +
    M*K int8 + partials (g, M, block_n) f32 must fit ~16 MiB; defaults
    cover K<=8192 at block_n=512.
    """
    m, k = xq.shape
    n = wq.shape[0]
    block_n = min(block_n, n)
    if n % block_n:
        raise ValueError(f"N={n} not a multiple of block_n={block_n}")
    if k % group_size:
        raise ValueError(f"K={k} not a multiple of group={group_size}")
    g = k // group_size
    grid = (n // block_n,)

    return pl.pallas_call(
        functools.partial(_kernel, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),     # resident acts
            pl.BlockSpec((m, g), lambda j: (0, 0)),
            pl.BlockSpec((block_n, k), lambda j: (j, 0)),  # streamed weights
            pl.BlockSpec((block_n, g), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(xq, xs, wq, ws)
