"""Pallas TPU kernels for the HLSTransform hot spots.

Each kernel ships three surfaces:
  <name>.py  — the pl.pallas_call with explicit BlockSpec VMEM tiling,
  ops.py     — jit'd padded wrappers (the API models call),
  ref.py     — pure-jnp oracles tests assert against (interpret=True).
"""
