"""Length-aware flash-decode attention Pallas kernel.

The paper's FPGA computes decode attention head-by-head with exact softmax
(its ``forward_Pipeline_iterate/max/exp/sum/norm`` modules are an explicit
streaming softmax).  The TPU-native equivalent is flash-decoding: stream
the KV cache in (block_s, head_dim) tiles, maintain the online-softmax
running (max, sum, acc) in VMEM scratch, and never materialize the (S,)
score vector in HBM.

Two traffic optimizations on top of the plain streaming kernel — decode is
HBM-bandwidth-bound, so these are the whole ballgame:

* **Length pruning** (``prune=True``): per-batch lengths arrive via scalar
  prefetch (``pltpu.PrefetchScalarGridSpec``), so both the kernel body and
  the BlockSpec index_maps can see them *before* any DMA is issued.  KV
  tiles past ``ceil(len/block_s)`` are (a) never fetched — the index_map
  clamps their block index to the last valid tile, and Pallas skips the
  copy when consecutive grid steps map to the same block (revisiting) —
  and (b) never computed — the whole tile body sits under ``pl.when``.
  At 4k ``max_seq`` with ~200-token live sequences this removes ~95% of
  decode-attention HBM traffic.  Pruned and unpruned outputs are
  bit-exact: a fully-masked tile contributes ``p == 0`` and leaves the
  running (max, sum) untouched, which is precisely what skipping does.

* **Quantized KV** (beyond-paper): the cache may be Q8_0 per
  (position, kv_head) — int8 codes + one f32 scale — halving/quartering
  cache traffic.  Scores use f32 q x dequantized k, keeping softmax exact
  (the paper computes exact nonlinearities; we do not approximate).

GQA layout: queries arrive grouped per KV head, q[b, kvh, hq, d], so one
grid step serves all hq queries that share a KV tile.

``return_tile_counts=True`` adds a per-(batch, kv_head) int32 output
counting the tiles whose body actually ran — the interpret-mode proof that
pruning skips exactly ``n_s - ceil(len/block_s)`` tiles (see
tests/test_decode_paths.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import compiler_params

NEG_INF = -1e30


def _n_valid_blocks(length, block_s: int):
    """Number of KV tiles holding live positions; >=1 so index_maps always
    have a legal tile to (re)visit even for len==0 dead slots."""
    return jnp.maximum(pl.cdiv(length, block_s), 1)


# -- streaming-softmax tile primitives --------------------------------------
# Shared by this kernel and kernels/paged_decode_attention.py (which only
# differs in how tiles are *addressed*), so the two can never drift
# numerically — paged vs dense bit-exactness is a test invariant.


def init_softmax_state(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)


def online_softmax_tile(q_ref, k_ref, v_ref, ks_ref, vs_ref, m_scr, l_scr,
                        acc_scr, *, pos0, length, block: int, kv_int8: bool):
    """Fold one (block, d) KV tile starting at position ``pos0`` into the
    running (max, sum, acc); positions >= ``length`` are masked out."""
    q = q_ref[0, 0].astype(jnp.float32)                 # (hq, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bs, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)           # (bs, d)
    if kv_int8:
        k = k * ks_ref[0, :, 0][:, None]                # dequant per pos
        v = v * vs_ref[0, :, 0][:, None]

    pos = pos0 + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    valid = pos < length                                # (1, bs)

    scores = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # (hq, bs)
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_scr[:, :1]                               # (hq, 1)
    l_prev = l_scr[:, :1]
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                         # (hq, bs)
    p = jnp.where(valid, p, 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # (hq, d)

    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
    acc_scr[...] = acc


def finish_softmax(o_ref, l_scr, acc_scr):
    l = l_scr[:, :1]
    o_ref[0, 0] = (acc_scr[...] /
                   jnp.where(l > 0, l, 1.0)).astype(o_ref.dtype)


def _kernel(lens_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref, *rest,
            block_s: int, n_s_blocks: int, kv_int8: bool, prune: bool,
            count_tiles: bool):
    if count_tiles:
        cnt_ref, m_scr, l_scr, acc_scr = rest
    else:
        (m_scr, l_scr, acc_scr), cnt_ref = rest, None
    bb = pl.program_id(0)
    s_idx = pl.program_id(2)
    length = lens_ref[bb]

    @pl.when(s_idx == 0)
    def _init():
        init_softmax_state(m_scr, l_scr, acc_scr)
        if count_tiles:
            cnt_ref[0, 0] = 0

    # tile holds at least one live position?  (always "yes" when pruning is
    # off — the unpruned kernel masks inside the tile instead)
    live = (s_idx * block_s < length) if prune else (s_idx >= 0)

    @pl.when(live)
    def _tile():
        online_softmax_tile(q_ref, k_ref, v_ref, ks_ref, vs_ref, m_scr,
                            l_scr, acc_scr, pos0=s_idx * block_s,
                            length=length, block=block_s, kv_int8=kv_int8)
        if count_tiles:
            cnt_ref[0, 0] += 1

    @pl.when(s_idx == n_s_blocks - 1)
    def _finish():
        finish_softmax(o_ref, l_scr, acc_scr)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            lens: jax.Array, k_scale=None, v_scale=None, *,
                            block_s: int = 512, prune: bool = True,
                            return_tile_counts: bool = False,
                            interpret: bool = False):
    """q: (B, KVH, HQ, D) pre-scaled by 1/sqrt(D); k/v: (B, S, KVH, D)
    (int8 when k_scale/v_scale (B, S, KVH) are given); lens: (B,) int32.
    Returns (B, KVH, HQ, D) f32 — plus (B, KVH) int32 live-tile counts when
    ``return_tile_counts``.
    """
    b, kvh, hq, d = q.shape
    s = k.shape[1]
    block_s = min(block_s, s)
    if s % block_s:
        raise ValueError(f"S={s} not a multiple of block_s={block_s}")
    n_s = s // block_s
    lens = lens.reshape(b).astype(jnp.int32)
    kv_int8 = k_scale is not None
    if not kv_int8:
        # dummy scale operands keep the kernel signature uniform
        k_scale = jnp.ones((b, s, kvh), jnp.float32)
        v_scale = jnp.ones((b, s, kvh), jnp.float32)

    def kv_map(bb, h, ss, lens_ref):
        if prune:
            # clamp dead tiles onto the last live tile: same block index as
            # the previous grid step -> Pallas elides the fetch entirely.
            ss = jnp.minimum(ss, _n_valid_blocks(lens_ref[bb], block_s) - 1)
        return (bb, ss, h, 0)

    def scale_map(bb, h, ss, lens_ref):
        if prune:
            ss = jnp.minimum(ss, _n_valid_blocks(lens_ref[bb], block_s) - 1)
        return (bb, ss, h)

    out_shape = [jax.ShapeDtypeStruct((b, kvh, hq, d), jnp.float32)]
    out_specs = [pl.BlockSpec((1, 1, hq, d), lambda bb, h, ss, lr: (bb, h, 0, 0))]
    if return_tile_counts:
        out_shape.append(jax.ShapeDtypeStruct((b, kvh), jnp.int32))
        out_specs.append(pl.BlockSpec((1, 1), lambda bb, h, ss, lr: (bb, h)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, hq, d), lambda bb, h, ss, lr: (bb, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d), kv_map),
            pl.BlockSpec((1, block_s, 1, d), kv_map),
            pl.BlockSpec((1, block_s, 1), scale_map),
            pl.BlockSpec((1, block_s, 1), scale_map),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((hq, 128), jnp.float32),   # running max (dup lanes)
            pltpu.VMEM((hq, 128), jnp.float32),   # running sum
            pltpu.VMEM((hq, d), jnp.float32),     # acc
        ],
    )

    outs = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, n_s_blocks=n_s,
                          kv_int8=kv_int8, prune=prune,
                          count_tiles=return_tile_counts),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lens, q, k, v, k_scale, v_scale)
    if return_tile_counts:
        return outs[0], outs[1]
    return outs[0]
