"""Flash-decode attention Pallas kernel (single query vs. KV cache).

The paper's FPGA computes decode attention head-by-head with exact softmax
(its ``forward_Pipeline_iterate/max/exp/sum/norm`` modules are an explicit
streaming softmax).  The TPU-native equivalent is flash-decoding: stream
the KV cache in (block_s, head_dim) tiles, maintain the online-softmax
running (max, sum, acc) in VMEM scratch, and never materialize the (S,)
score vector in HBM.

GQA layout: queries arrive grouped per KV head, q[b, kvh, hq, d], so one
grid step serves all hq queries that share a KV tile (the paper's Llama
uses exactly this grouping).

Beyond-paper: the KV cache may be Q8_0-quantized per (position, kv_head)
— int8 codes + one f32 scale — halving/quartering cache traffic, which is
the dominant HBM term at long context.  Scores use f32 q x dequantized k,
keeping softmax exact (the paper computes exact nonlinearities; we do not
approximate).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, len_ref, o_ref,
            m_scr, l_scr, acc_scr, *, block_s: int, n_s_blocks: int,
            kv_int8: bool):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                     # (hq, d)
    k = k_ref[:, :, 0, :][0].astype(jnp.float32)            # (bs, d)
    v = v_ref[:, :, 0, :][0].astype(jnp.float32)            # (bs, d)
    if kv_int8:
        k = k * ks_ref[0, :, 0][:, None]                    # dequant per pos
        v = v * vs_ref[0, :, 0][:, None]

    length = len_ref[0, 0]
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    valid = pos < length                                    # (1, bs)

    scores = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (hq, bs)
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_scr[:, :1]                                   # (hq, 1)
    l_prev = l_scr[:, :1]
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                             # (hq, bs)
    p = jnp.where(valid, p, 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (hq, d)

    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
    acc_scr[...] = acc

    @pl.when(s_idx == n_s_blocks - 1)
    def _finish():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.where(l > 0, l, 1.0)).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            lens: jax.Array, k_scale=None, v_scale=None, *,
                            block_s: int = 512, interpret: bool = False
                            ) -> jax.Array:
    """q: (B, KVH, HQ, D) pre-scaled by 1/sqrt(D); k/v: (B, S, KVH, D)
    (int8 when k_scale/v_scale (B, S, KVH) are given); lens: (B, 1) int32.
    Returns (B, KVH, HQ, D) f32.
    """
    b, kvh, hq, d = q.shape
    s = k.shape[1]
    block_s = min(block_s, s)
    if s % block_s:
        raise ValueError(f"S={s} not a multiple of block_s={block_s}")
    n_s = s // block_s
    kv_int8 = k_scale is not None
    if not kv_int8:
        # dummy scale operands keep the kernel signature uniform
        k_scale = jnp.ones((b, s, kvh), jnp.float32)
        v_scale = jnp.ones((b, s, kvh), jnp.float32)

    grid = (b, kvh, n_s)
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, n_s_blocks=n_s,
                          kv_int8=kv_int8),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hq, d), lambda bb, h, ss: (bb, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bb, h, ss: (bb, ss, h, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bb, h, ss: (bb, ss, h, 0)),
            pl.BlockSpec((1, block_s, 1), lambda bb, h, ss: (bb, ss, h)),
            pl.BlockSpec((1, block_s, 1), lambda bb, h, ss: (bb, ss, h)),
            pl.BlockSpec((1, 1), lambda bb, h, ss: (bb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hq, d), lambda bb, h, ss: (bb, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, hq, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((hq, 128), jnp.float32),   # running max (dup lanes)
            pltpu.VMEM((hq, 128), jnp.float32),   # running sum
            pltpu.VMEM((hq, d), jnp.float32),     # acc
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel",
                                             "arbitrary")),
        interpret=interpret,
    )(q, k, v, k_scale, v_scale, lens)
