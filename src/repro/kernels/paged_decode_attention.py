"""Paged flash-decode attention Pallas kernel (vLLM-style page table).

The dense decode kernel (decode_attention.py) assumes each sequence owns a
contiguous ``(max_seq, KVH, hd)`` reservation.  The paged variant reads K/V
straight out of the shared block pool of ``serving/paged_cache.py``:

    k_pool / v_pool : (n_blocks, block_size, KVH, hd)   — one layer's pool
    page_table      : (B, max_blocks) int32             — block ids, -1 free
    lens            : (B,) int32                        — live lengths

Both the page table and the lengths ride in via scalar prefetch
(``pltpu.PrefetchScalarGridSpec``), so the BlockSpec index_map dereferences
``page_table[b, i]`` *before* the DMA is issued — the kernel streams exactly
the blocks a sequence owns, in order, and never materializes a contiguous
gathered copy in HBM (the gather IS the index_map).  Tiles past
``ceil(len/block_size)`` clamp onto the last live block, which Pallas
recognizes as a revisit and elides the fetch — the same length-pruning
trick as the dense kernel, so short sequences in a long-context pool cost
only their own bytes.

Q8_0 pools are supported with per-(position, kv_head) f32 scales, same as
the dense cache.  Outputs match ``ref.ref_paged_decode_attention`` (a
gather + dense softmax oracle) bit-for-bit in f32.

GQA layout matches decode_attention.py: q[b, kvh, hq, d]; one grid step
serves the hq query heads sharing a KV block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention import (_n_valid_blocks, finish_softmax,
                                            init_softmax_state,
                                            online_softmax_tile)
from repro.kernels.tpu_compat import compiler_params


def _kernel(pt_ref, lens_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
            *rest, block_size: int, n_blocks_grid: int, kv_int8: bool,
            count_tiles: bool):
    if count_tiles:
        cnt_ref, m_scr, l_scr, acc_scr = rest
    else:
        (m_scr, l_scr, acc_scr), cnt_ref = rest, None
    bb = pl.program_id(0)
    i = pl.program_id(2)                                    # logical block #
    length = lens_ref[bb]

    @pl.when(i == 0)
    def _init():
        init_softmax_state(m_scr, l_scr, acc_scr)
        if count_tiles:
            cnt_ref[0, 0] = 0

    @pl.when(i * block_size < length)
    def _tile():
        # the tile math is the dense kernel's — only the addressing (the
        # page-table index_map below) differs
        online_softmax_tile(q_ref, k_ref, v_ref, ks_ref, vs_ref, m_scr,
                            l_scr, acc_scr, pos0=i * block_size,
                            length=length, block=block_size,
                            kv_int8=kv_int8)
        if count_tiles:
            cnt_ref[0, 0] += 1

    @pl.when(i == n_blocks_grid - 1)
    def _finish():
        finish_softmax(o_ref, l_scr, acc_scr)


def paged_decode_attention_pallas(q: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array, page_table: jax.Array,
                                  lens: jax.Array, ks_pool=None, vs_pool=None,
                                  *, return_tile_counts: bool = False,
                                  interpret: bool = False):
    """q: (B, KVH, HQ, D) pre-scaled by 1/sqrt(D);
    k/v_pool: (NB, BS, KVH, D) (int8 when ks/vs_pool (NB, BS, KVH) given);
    page_table: (B, MB) int32 block ids (-1 = unassigned); lens: (B,) int32.
    Returns (B, KVH, HQ, D) f32 — plus (B, KVH) int32 live-block counts when
    ``return_tile_counts``.
    """
    b, kvh, hq, d = q.shape
    nb, bs, kvh_p, d_p = k_pool.shape
    if (kvh_p, d_p) != (kvh, d):
        raise ValueError(f"pool heads/dim {(kvh_p, d_p)} != q {(kvh, d)}")
    mb = page_table.shape[1]
    page_table = page_table.astype(jnp.int32)
    lens = lens.reshape(b).astype(jnp.int32)
    kv_int8 = ks_pool is not None
    if not kv_int8:
        ks_pool = jnp.ones((nb, bs, kvh), jnp.float32)
        vs_pool = jnp.ones((nb, bs, kvh), jnp.float32)

    def _blk(bb, i, pt_ref, lens_ref):
        # clamp dead logical blocks onto the last live one (revisit -> no
        # DMA), and -1 entries (released slots) onto pool block 0: the tile
        # body is skipped for them, the fetch just needs a legal address.
        i_c = jnp.minimum(i, _n_valid_blocks(lens_ref[bb], bs) - 1)
        return jnp.maximum(pt_ref[bb, i_c], 0)

    def pool_map(bb, h, i, pt_ref, lens_ref):
        return (_blk(bb, i, pt_ref, lens_ref), 0, h, 0)

    def scale_map(bb, h, i, pt_ref, lens_ref):
        return (_blk(bb, i, pt_ref, lens_ref), 0, h)

    out_shape = [jax.ShapeDtypeStruct((b, kvh, hq, d), jnp.float32)]
    out_specs = [pl.BlockSpec((1, 1, hq, d),
                              lambda bb, h, i, pt, lr: (bb, h, 0, 0))]
    if return_tile_counts:
        out_shape.append(jax.ShapeDtypeStruct((b, kvh), jnp.int32))
        out_specs.append(pl.BlockSpec((1, 1), lambda bb, h, i, pt, lr: (bb, h)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, mb),
        in_specs=[
            pl.BlockSpec((1, 1, hq, d), lambda bb, h, i, pt, lr: (bb, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), pool_map),
            pl.BlockSpec((1, bs, 1, d), pool_map),
            pl.BlockSpec((1, bs, 1), scale_map),
            pl.BlockSpec((1, bs, 1), scale_map),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((hq, 128), jnp.float32),
            pltpu.VMEM((hq, 128), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
        ],
    )

    outs = pl.pallas_call(
        functools.partial(_kernel, block_size=bs, n_blocks_grid=mb,
                          kv_int8=kv_int8, count_tiles=return_tile_counts),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, lens, q, k_pool, v_pool, ks_pool, vs_pool)
    if return_tile_counts:
        return outs[0], outs[1]
    return outs[0]
