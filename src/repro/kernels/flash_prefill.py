"""Flash-attention forward Pallas kernel (prefill / training).

The decode path has its own kernel (decode_attention.py); this one covers
the other half of the paper's workload when scaled up: full-sequence
attention during prefill, where the (S, S) score matrix must never touch
HBM.  Standard flash structure on TPU:

  grid = (batch*heads, q_blocks, k_blocks)   k innermost (sequential)
  VMEM scratch: running max m, running sum l, accumulator acc
  causal masking prunes fully-masked k-blocks via @pl.when

Chunked prefill (serving/scheduler.py) attends a chunk of S_q queries at
global positions ``q_offset .. q_offset + S_q - 1`` against S_k >= S_q
keys (the already-written prefix plus the chunk itself), so the kernel
supports rectangular q/k extents with the causal diagonal shifted by
``q_offset``.  **Shape stability:** the offset and the per-row valid
extents (``q_lens``/``k_lens``) ride in via scalar prefetch
(``pltpu.PrefetchScalarGridSpec``, the same pattern the paged decode
kernel uses for per-row lengths) rather than as static kernel arguments
— serving traffic that churns chunk lengths and position offsets reuses
ONE compiled executable per padded extent, matching the
``models/transformer.prefill_chunk_batch`` contract (its jnp oracle is
``layers.attention_chunk_merge``).  Tiles past a row's valid extent, or
entirely above its shifted causal diagonal, are skipped: the compute is
``@pl.when``-guarded on the prefetched scalars and the BlockSpec
index_map clamps dead tiles onto the last live one, which Pallas
recognizes as a revisit and elides the DMA — padding costs neither
bytes nor FLOPs.

The jnp oracle is layers.attention_scores_blockwise (same math, scan
form); tests sweep shapes (including per-row offsets/lengths) and assert
allclose in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import compiler_params

NEG_INF = -1e30


def _kernel(off_ref, qlen_ref, klen_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, block_q: int, block_k: int,
            n_k_blocks: int, causal: bool, scale: float):
    b = pl.program_id(0)
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    off = off_ref[b]
    qlen = qlen_ref[b]
    klen = klen_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip tiles past the valid extents, and — causal — tiles entirely
    # above the (offset-shifted) diagonal; all three bounds are data
    run = (ki * block_k < klen) & (qi * block_q < qlen)
    if causal:
        run &= ki * block_k <= off + (qi + 1) * block_q - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < klen
        if causal:
            qpos = off + qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            mask &= kpos <= qpos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        l = l_scr[:, :1]
        o_ref[0] = (acc_scr[...] /
                    jnp.where(l > 0, l, 1.0)).astype(o_ref.dtype)


def flash_prefill_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, q_offset=0,
                         q_lens=None, k_lens=None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False) -> jax.Array:
    """q: (BH, S_q, D); k/v: (BH, S_k, D) flat batch*heads (wrapper
    repeats GQA KV heads).  Returns (BH, S_q, D) f32; q is scaled by
    1/sqrt(D) inside.

    ``q_offset`` — an int or a per-row (BH,) int32 array — gives the
    global position of each row's first query for chunked prefill: query
    row i attends keys ``<= q_offset + i``.  ``q_lens``/``k_lens``
    (optional (BH,) arrays, default = the full extents) mark each row's
    valid rectangle; rows/keys past them are skipped (their output is
    garbage the caller discards).  All three are *data* — scalar
    prefetch, not compile keys — so one executable serves every offset /
    length mix at a given padded shape.  The one-shot case is
    ``S_q == S_k`` with everything defaulted."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    if (causal and isinstance(q_offset, int) and q_lens is None
            and k_lens is None and q_offset + sq > sk):
        raise ValueError(f"q_offset {q_offset} + S_q {sq} exceeds S_k {sk}")
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError(f"S_q={sq}/S_k={sk} must divide blocks "
                         f"({block_q},{block_k})")
    nq, nk = sq // block_q, sk // block_k
    scale = d ** -0.5

    offs = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (bh,))
    qlens = (jnp.full((bh,), sq, jnp.int32) if q_lens is None
             else jnp.asarray(q_lens, jnp.int32).reshape(bh))
    klens = (jnp.full((bh,), sk, jnp.int32) if k_lens is None
             else jnp.asarray(k_lens, jnp.int32).reshape(bh))

    def kv_map(b, i, j, off_ref, qlen_ref, klen_ref):
        # clamp dead tiles onto the last live one (revisit -> no DMA):
        # a row needs keys below its valid length and — causal — at or
        # below its q block's shifted diagonal
        limit = klen_ref[b]
        if causal:
            limit = jnp.minimum(limit, off_ref[b] + (i + 1) * block_q)
        last = jnp.maximum(pl.cdiv(limit, block_k) - 1, 0)
        return (b, jnp.minimum(j, last), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d),
                         lambda b, i, j, off, ql, kl: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda b, i, j, off, ql, kl: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )

    return pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          n_k_blocks=nk, causal=causal, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), jnp.float32),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(offs, qlens, klens, q, k, v)
