"""Jit'd public wrappers around the Pallas kernels.

Handles padding to block multiples, activation quantization, layout
massaging (the kernels want flat 2-D operands), and the interpret flag
(True on this CPU container; False when targeting real TPUs).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import QuantizedTensor, quantize
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.paged_decode_attention import paged_decode_attention_pallas
from repro.kernels.paged_prefill_attention import paged_prefill_attention_pallas
from repro.kernels.flash_prefill import flash_prefill_pallas
from repro.kernels.q4_matmul import q4_matvec_pallas
from repro.kernels.q8_matmul import q8_matmul_pallas
from repro.kernels.q8_matvec import q8_matvec_pallas
from repro.kernels.rmsnorm_quant import rmsnorm_quant_pallas
from repro.kernels.rope import rope_pallas

# decode-vs-prefill dispatch threshold: below this many rows per shard the
# GEMV kernel (activations resident, no K grid) wins.
MATVEC_MAX_ROWS = 32


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("interpret", "block_m", "block_n",
                                   "block_k"))
def q8_matmul(x: jax.Array, w: QuantizedTensor, *, interpret: bool = False,
              block_m: int = 128, block_n: int = 256, block_k: int = 512
              ) -> jax.Array:
    """x (…, K) f32  @  wq (N, K).T  with paper-exact integer semantics.

    Quantizes activations Q8_0 on the fly, dispatches GEMV/GEMM on row
    count, pads every dim to block multiples and slices the result back.
    """
    if w.bits not in (4, 8):
        raise ValueError(f"bits={w.bits}")
    gs = w.group_size
    *lead, k = x.shape
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, k)
    xq_t = quantize(x2, group_size=gs, bits=8)
    xq, xs = xq_t.q, xq_t.scale
    wq, ws = w.q, w.scale
    n = wq.shape[0]

    if w.bits == 4:
        bn = min(512, n) if n % 512 == 0 else _largest_block(n, 512)
        out = q4_matvec_pallas(xq, xs, wq, ws, group_size=gs, block_n=bn,
                               interpret=interpret)
    elif m <= MATVEC_MAX_ROWS:
        bn = _largest_block(n, 512)
        out = q8_matvec_pallas(xq, xs, wq, ws, group_size=gs, block_n=bn,
                               interpret=interpret)
    else:
        bm = _largest_block(m, block_m)
        bn_ = _largest_block(n, block_n)
        bk = _largest_block(k, block_k, mult=gs)
        out = q8_matmul_pallas(xq, xs, wq, ws, group_size=gs, block_m=bm,
                               block_n=bn_, block_k=bk, interpret=interpret)
    return out.reshape(*lead, n)


def _largest_block(dim: int, preferred: int, mult: int = 1) -> int:
    """Largest divisor of ``dim`` <= preferred that is a multiple of mult."""
    b = min(preferred, dim)
    while b > 1 and (dim % b or b % mult):
        b -= 1
    return max(b, 1)


@partial(jax.jit, static_argnames=("eps", "group_size", "interpret"))
def rmsnorm_quant(x: jax.Array, gamma: jax.Array, *, eps: float = 1e-5,
                  group_size: int = 64, interpret: bool = False):
    """Fused RMSNorm + Q8_0: (…, K) f32 -> ((…, K) i8, (…, K/gs) f32)."""
    *lead, k = x.shape
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, k)
    bm = _largest_block(m, 256)
    q, s = rmsnorm_quant_pallas(x2, gamma, eps=eps, group_size=group_size,
                                block_m=bm, interpret=interpret)
    return q.reshape(*lead, k), s.reshape(*lead, k // group_size)


@partial(jax.jit, static_argnames=("interpret",))
def rope(x: jax.Array, cos: jax.Array, sin: jax.Array, *,
         interpret: bool = False) -> jax.Array:
    """x: (B, H, D); cos/sin: (B, D) (full-width, already duplicated halves).

    The angle tables stay (B, D) in HBM — the kernel broadcasts them
    across H via its index_map instead of ``jnp.repeat``-ing them to
    (B*H, D) first."""
    return rope_pallas(x, cos, sin, interpret=interpret)


@partial(jax.jit, static_argnames=("block_s", "prune", "return_tile_counts",
                                   "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lens: jax.Array, k_scale: Optional[jax.Array] = None,
                     v_scale: Optional[jax.Array] = None, *,
                     block_s: int = 512, prune: bool = True,
                     return_tile_counts: bool = False,
                     interpret: bool = False):
    """Single-token attention vs. a (possibly int8) KV cache.

    q: (B, H, D) already scaled by 1/sqrt(D); k/v: (B, S, KVH, D);
    lens: (B,) int32 valid lengths.  Returns (B, H, D) f32.

    ``prune=True`` (default) skips fetching/computing KV tiles past each
    row's length — bit-exact with the full scan.  ``return_tile_counts``
    additionally returns (B, KVH) int32 counts of tiles whose body ran.
    """
    b, h, d = q.shape
    kvh = k.shape[2]
    hq = h // kvh
    qg = q.reshape(b, kvh, hq, d)
    s = k.shape[1]
    bs = _largest_block(s, block_s)
    out = decode_attention_pallas(qg, k, v, lens.reshape(b),
                                  k_scale, v_scale, block_s=bs, prune=prune,
                                  return_tile_counts=return_tile_counts,
                                  interpret=interpret)
    if return_tile_counts:
        return out[0].reshape(b, h, d), out[1]
    return out.reshape(b, h, d)


@partial(jax.jit, static_argnames=("return_tile_counts", "interpret"))
def paged_decode_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           page_table: jax.Array, lens: jax.Array,
                           ks_pool: Optional[jax.Array] = None,
                           vs_pool: Optional[jax.Array] = None, *,
                           return_tile_counts: bool = False,
                           interpret: bool = False):
    """Single-token attention reading K/V through a vLLM-style page table.

    q: (B, H, D) already scaled by 1/sqrt(D); k/v_pool: (NB, BS, KVH, D)
    (int8 when ks/vs_pool (NB, BS, KVH) are given); page_table: (B, MB)
    int32; lens: (B,) int32.  Returns (B, H, D) f32.
    """
    b, h, d = q.shape
    kvh = k_pool.shape[2]
    hq = h // kvh
    qg = q.reshape(b, kvh, hq, d)
    out = paged_decode_attention_pallas(
        qg, k_pool, v_pool, page_table, lens, ks_pool, vs_pool,
        return_tile_counts=return_tile_counts, interpret=interpret)
    if return_tile_counts:
        return out[0].reshape(b, h, d), out[1]
    return out.reshape(b, h, d)


@partial(jax.jit, static_argnames=("block_q", "return_tile_counts",
                                   "interpret"))
def paged_prefill_attention(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, page_table: jax.Array,
                            pfx_lens: jax.Array, q_lens=None,
                            ks_pool: Optional[jax.Array] = None,
                            vs_pool: Optional[jax.Array] = None, *,
                            block_q: int = 128,
                            return_tile_counts: bool = False,
                            interpret: bool = False):
    """Rectangular-q attention over the paged prefix of a prefill chunk.

    q: (B, C, H, D) already scaled by 1/sqrt(D); k/v_pool:
    (NB, BS, KVH, D) (int8 when ks/vs_pool (NB, BS, KVH) are given);
    page_table: (B, MB) int32; pfx_lens/q_lens: (B,) int32 traced data
    (prefix rows each chunk row attends / valid chunk rows).  Returns the
    prefix segment's flash state in `layers.attention_chunk_merge`'s
    ``pfx_state`` layout — out (B, C, H, D) f32, m (B, H, C, 1) f32,
    l (B, H, C, 1) f32 — plus (B, KVH) live-tile counts when
    ``return_tile_counts``.  An empty prefix row is exactly
    (out=0, m=-1e30, l=0), which the merge weights at exactly zero.
    """
    b, c, h, d = q.shape
    kvh = k_pool.shape[2]
    hq = h // kvh
    qg = q.reshape(b, c, kvh, hq, d)
    bq = _largest_block(c, block_q)
    outs = paged_prefill_attention_pallas(
        qg, k_pool, v_pool, page_table, pfx_lens, q_lens, ks_pool, vs_pool,
        block_q=bq, return_tile_counts=return_tile_counts,
        interpret=interpret)
    out = outs[0].reshape(b, c, h, d)
    m = jnp.moveaxis(outs[1].reshape(b, c, h), 1, 2)[..., None]
    l = jnp.moveaxis(outs[2].reshape(b, c, h), 1, 2)[..., None]
    if return_tile_counts:
        return out, m, l, outs[3]
    return out, m, l


@partial(jax.jit, static_argnames=("causal", "interpret",
                                   "block_q", "block_k"))
def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, q_offset=0,
                  q_lens=None, k_lens=None,
                  block_q: int = 128, block_k: int = 128,
                  interpret: bool = False) -> jax.Array:
    """Full-sequence attention: q (B,Sq,H,D); k/v (B,Sk,KVH,D) ->
    (B,Sq,H,D).

    GQA KV heads are repeated to H (XLA keeps it a gather) and the head
    axis folds into the grid's batch dim; blocks pad via the wrapper.
    ``Sk > Sq`` with a ``q_offset`` is the chunked-prefill form: query
    row i of batch row b sits at global position ``q_offset[b] + i`` and
    attends the prefix keys plus its own chunk causally.  ``q_offset``
    (int or (B,)) and the optional per-row valid extents ``q_lens`` /
    ``k_lens`` (B,) are *traced data* carried into the kernel by scalar
    prefetch — NOT static arguments — so serving traffic with churning
    chunk lengths and offsets shares one compiled executable per padded
    shape (the shape-stability contract of
    models/transformer.prefill_chunk_batch)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    hq = h // kvh
    kr = jnp.repeat(k, hq, axis=2)
    vr = jnp.repeat(v, hq, axis=2)
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, d)
    kf = jnp.moveaxis(kr, 2, 1).reshape(b * h, sk, d)
    vf = jnp.moveaxis(vr, 2, 1).reshape(b * h, sk, d)
    bq = _largest_block(sq, block_q)
    bk = _largest_block(sk, block_k)

    def per_bh(x, fill):
        """Per-batch scalar/array -> per-(batch*head) rows (b-major)."""
        if x is None:
            return jnp.full((b * h,), fill, jnp.int32)
        x = jnp.broadcast_to(jnp.asarray(x, jnp.int32), (b,))
        return jnp.repeat(x, h)

    out = flash_prefill_pallas(
        qf, kf, vf, causal=causal, q_offset=per_bh(q_offset, 0),
        q_lens=per_bh(q_lens, sq), k_lens=per_bh(k_lens, sk),
        block_q=bq, block_k=bk, interpret=interpret)
    return jnp.moveaxis(out.reshape(b, h, sq, d), 1, 2)
