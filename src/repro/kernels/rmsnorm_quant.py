"""Fused RMSNorm + dynamic activation quantization Pallas kernel.

The paper's forward pass alternates ``rmsnorm -> quantize -> matmul``
(Appendix A.2 lists rmsnorm_768 and quantize_768 as separate pipelined
modules).  On TPU we fuse the two stages into one VMEM pass: normalize a
row block with fp32 gamma (the paper keeps RMSNorm params in fp32) and
emit Q8_0 codes + per-group scales directly, so the normalized fp32
activations never travel back to HBM.

    y        = x / sqrt(mean(x^2) + eps) * gamma
    q[g]     = round(127 * y[g] / max|y[g]|)   (int8)
    scale[g] = max|y[g]| / 127
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import compiler_params


def _kernel(x_ref, gamma_ref, q_ref, s_ref, *, eps: float, group_size: int):
    x = x_ref[...].astype(jnp.float32)            # (bm, K)
    bm, k = x.shape
    g = k // group_size
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * gamma_ref[...].astype(jnp.float32)
    yg = y.reshape(bm, g, group_size)
    absmax = jnp.max(jnp.abs(yg), axis=-1, keepdims=True)
    inv = jnp.where(absmax > 0, 127.0 / absmax, 0.0)
    q = jnp.clip(jnp.round(yg * inv), -127, 127).astype(jnp.int8)
    q_ref[...] = q.reshape(bm, k)
    s_ref[...] = (absmax / 127.0).reshape(bm, g)


def rmsnorm_quant_pallas(x: jax.Array, gamma: jax.Array, *,
                         eps: float = 1e-5, group_size: int = 64,
                         block_m: int = 256, interpret: bool = False):
    """Returns (q int8 (M, K), scale f32 (M, K/gs)).

    Rows are independent, so the grid tiles M only; each step holds one
    (block_m, K) slab in VMEM — K<=16k rows of f32 fit comfortably.
    """
    m, k = x.shape
    if k % group_size:
        raise ValueError(f"K={k} not a multiple of group={group_size}")
    block_m = min(block_m, m)
    if m % block_m:
        raise ValueError(f"M={m} not a multiple of block_m={block_m}")
    g = k // group_size
    grid = (m // block_m,)

    return pl.pallas_call(
        functools.partial(_kernel, eps=eps, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),
            pl.BlockSpec((block_m, g), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, g), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, gamma)
