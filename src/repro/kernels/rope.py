"""Rotary position embedding Pallas kernel.

The paper singles RoPE out as a subroutine that is awkward on GPUs and
pipelines it on the FPGA (``forward_Pipeline_rotation1``).  On TPU it is a
pure VPU (8x128 vector unit) elementwise pass; the kernel exists so the
decode path can run it fused and VMEM-resident instead of as several XLA
ops.  Llama/neox convention: rotate halves.

    out = x * cos  +  rotate_half(x) * sin,   rotate_half(x) = [-x2, x1]

The wrapper pre-broadcasts cos/sin to the flattened (rows, D) layout so the
kernel is a clean 2-D elementwise grid (lane dim = head_dim).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, cos_ref, sin_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    d = x.shape[-1]
    x1 = x[..., : d // 2]
    x2 = x[..., d // 2:]
    rot = jnp.concatenate([-x2, x1], axis=-1)
    o_ref[...] = (x * cos_ref[...] + rot * sin_ref[...]).astype(o_ref.dtype)


def rope_pallas(x: jax.Array, cos: jax.Array, sin: jax.Array, *,
                block_m: int = 256, interpret: bool = False) -> jax.Array:
    """x: (M, D) rows=(batch*heads[*seq]); cos/sin: (M, D) pre-broadcast."""
    m, d = x.shape
    block_m = min(block_m, m)
    if m % block_m:
        raise ValueError(f"M={m} not a multiple of block_m={block_m}")
    grid = (m // block_m,)
    spec = pl.BlockSpec((block_m, d), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, cos, sin)
