"""Rotary position embedding Pallas kernel.

The paper singles RoPE out as a subroutine that is awkward on GPUs and
pipelines it on the FPGA (``forward_Pipeline_rotation1``).  On TPU it is a
pure VPU (8x128 vector unit) elementwise pass; the kernel exists so the
decode path can run it fused and VMEM-resident instead of as several XLA
ops.  Llama/neox convention: rotate halves.

    out = x * cos  +  rotate_half(x) * sin,   rotate_half(x) = [-x2, x1]

cos/sin are per-batch-row angle tables shared by every head: the grid
walks the batch dim and each step fetches one (1, D) angle row alongside
its (1, H, D) head block — the head broadcast happens on VMEM-resident
data inside the kernel.  (An earlier version ``jnp.repeat``-ed the tables
to (B*H, D) in HBM first: an H-fold duplication of pure angle bytes on the
decode hot path.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tpu_compat import compiler_params


def _kernel(x_ref, cos_ref, sin_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                  # (1, H, D)
    d = x.shape[-1]
    x1 = x[..., : d // 2]
    x2 = x[..., d // 2:]
    rot = jnp.concatenate([-x2, x1], axis=-1)
    cos = cos_ref[...][:, None, :]                      # (1, 1, D): bcast H
    sin = sin_ref[...][:, None, :]
    o_ref[...] = (x * cos + rot * sin).astype(o_ref.dtype)


def rope_pallas(x: jax.Array, cos: jax.Array, sin: jax.Array, *,
                interpret: bool = False) -> jax.Array:
    """x: (B, H, D); cos/sin: (B, D) full-width (duplicated halves), one
    row per batch element — broadcast across H inside the kernel."""
    b, h, d = x.shape
    if cos.shape != (b, d):
        raise ValueError(f"cos/sin must be (B, D)=({b}, {d}), got {cos.shape}")
    xspec = pl.BlockSpec((1, h, d), lambda i: (i, 0, 0))
    aspec = pl.BlockSpec((1, d), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[xspec, aspec, aspec],
        out_specs=xspec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), x.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, cos, sin)
