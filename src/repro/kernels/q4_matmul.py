"""Packed 4-bit (Q4_0) matvec Pallas kernel — the paper's §5.1 future work.

Same dataflow as q8_matvec, but weight tiles arrive as packed nibbles
(two codes per byte), halving HBM traffic again.  Unpacking happens in
VMEM with two arithmetic shifts — the TPU analogue of the FPGA widening
trick (more codes per burst word).

Packing convention (matches core.quantization._pack_nibbles):
byte b holds code[2i] in the low nibble, code[2i+1] in the high nibble,
both sign-extended int4 in [-7, 7].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import compiler_params


def _unpack(w_packed: jax.Array) -> jax.Array:
    """(N, K/2) int8 -> (N, K) int8, interleaved low/high nibbles."""
    lo = (w_packed << 4).astype(jnp.int8) >> 4
    hi = w_packed.astype(jnp.int8) >> 4
    n, kh = w_packed.shape
    return jnp.stack([lo, hi], axis=-1).reshape(n, kh * 2)


def _kernel(xq_ref, xs_ref, wq_ref, ws_ref, o_ref, *, group_size: int):
    bm, k = xq_ref.shape
    wq = _unpack(wq_ref[...])                              # (bn, K)
    bn = wq.shape[0]
    g = k // group_size
    xq = xq_ref[...].reshape(bm, g, group_size)
    wqg = wq.reshape(bn, g, group_size)
    part = jax.lax.dot_general(
        xq.swapaxes(0, 1), wqg.swapaxes(0, 1),
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.int32)                  # (g, bm, bn)
    xs = xs_ref[...]
    ws = ws_ref[...]
    scaled = part.astype(jnp.float32) * xs.T[:, :, None] * ws.T[:, None, :]
    o_ref[...] = jnp.sum(scaled, axis=0)


def q4_matvec_pallas(xq: jax.Array, xs: jax.Array, wq_packed: jax.Array,
                     ws: jax.Array, *, group_size: int = 64,
                     block_n: int = 512, interpret: bool = False
                     ) -> jax.Array:
    """out = (xq*xs) @ (unpack(wq)*ws).T.

    xq: (M, K) int8 activations (Q8_0 — acts stay 8-bit, only weights 4-bit)
    wq_packed: (N, K/2) int8, ws: (N, K/gs) f32.
    """
    m, k = xq.shape
    n = wq_packed.shape[0]
    if wq_packed.shape[1] * 2 != k:
        raise ValueError("packed K mismatch")
    block_n = min(block_n, n)
    if n % block_n or k % group_size:
        raise ValueError(f"bad dims N={n} bn={block_n} K={k} gs={group_size}")
    g = k // group_size
    grid = (n // block_n,)

    return pl.pallas_call(
        functools.partial(_kernel, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((m, g), lambda j: (0, 0)),
            pl.BlockSpec((block_n, k // 2), lambda j: (j, 0)),
            pl.BlockSpec((block_n, g), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(xq, xs, wq_packed, ws)
