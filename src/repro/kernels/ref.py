"""Pure-jnp oracles for every Pallas kernel in this package.

Each function mirrors a kernel's signature on raw arrays and computes the
same math with plain jnp ops.  Tests sweep shapes/dtypes and
``assert_allclose`` kernel-vs-oracle in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_q8_matmul(xq: jax.Array, xs: jax.Array, wq: jax.Array,
                  ws: jax.Array, group_size: int = 64) -> jax.Array:
    """Integer-exact grouped matmul: (M,K)i8,(M,G)f32 x (N,K)i8,(N,G)f32."""
    m, k = xq.shape
    n = wq.shape[0]
    g = k // group_size
    xg = xq.reshape(m, g, group_size).astype(jnp.int32)
    wg = wq.reshape(n, g, group_size).astype(jnp.int32)
    part = jnp.einsum("mgk,ngk->mng", xg, wg).astype(jnp.float32)
    # rescale by activation scale (m, g) and weight scale (n, g), sum groups
    return jnp.sum(part * xs[:, None, :] * ws[None, :, :], axis=-1)


def ref_rmsnorm_quant(x: jax.Array, gamma: jax.Array, eps: float = 1e-5,
                      group_size: int = 64):
    x = x.astype(jnp.float32)
    m, k = x.shape
    g = k // group_size
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * gamma.astype(jnp.float32)
    yg = y.reshape(m, g, group_size)
    absmax = jnp.max(jnp.abs(yg), axis=-1, keepdims=True)
    inv = jnp.where(absmax > 0, 127.0 / absmax, 0.0)
    q = jnp.clip(jnp.round(yg * inv), -127, 127).astype(jnp.int8)
    return q.reshape(m, k), (absmax / 127.0).reshape(m, g)


def ref_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    x32 = x.astype(jnp.float32)
    d = x.shape[-1]
    x1, x2 = x32[..., : d // 2], x32[..., d // 2:]
    rot = jnp.concatenate([-x2, x1], axis=-1)
    return (x32 * cos + rot * sin).astype(x.dtype)


def ref_q4_matvec(xq: jax.Array, xs: jax.Array, wq_packed: jax.Array,
                  ws: jax.Array, group_size: int = 64) -> jax.Array:
    lo = (wq_packed << 4).astype(jnp.int8) >> 4
    hi = wq_packed.astype(jnp.int8) >> 4
    n, kh = wq_packed.shape
    wq = jnp.stack([lo, hi], axis=-1).reshape(n, kh * 2)
    return ref_q8_matmul(xq, xs, wq, ws, group_size)


def ref_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         lens: jax.Array, k_scale=None, v_scale=None
                         ) -> jax.Array:
    """q: (B, KVH, HQ, D) pre-scaled; k/v: (B, S, KVH, D); lens (B, 1)."""
    b, kvh, hq, d = q.shape
    s = k.shape[1]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale[..., None]
        vf = vf * v_scale[..., None]
    scores = jnp.einsum("bhqd,bshd->bhqs", q.astype(jnp.float32), kf)
    pos = jnp.arange(s)[None, None, None, :]
    mask = pos < lens[:, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(mask, p, 0.0)
    return jnp.einsum("bhqs,bshd->bhqd", p, vf)


def ref_paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, page_table: jax.Array,
                               lens: jax.Array, ks_pool=None, vs_pool=None
                               ) -> jax.Array:
    """Gather-then-dense oracle for the paged decode kernel.

    q: (B, KVH, HQ, D) pre-scaled; k/v_pool: (NB, BS, KVH, D);
    page_table: (B, MB) int32 (-1 = unassigned); lens: (B,) int32.
    Materializes each row's contiguous (MB*BS) view through the page table
    and runs the dense reference; unassigned blocks read pool block 0 and
    are masked by ``lens``.
    """
    nb, bs, kvh, d = k_pool.shape
    b, mb = page_table.shape
    safe = jnp.maximum(page_table, 0)
    k = k_pool[safe].reshape(b, mb * bs, kvh, d)
    v = v_pool[safe].reshape(b, mb * bs, kvh, d)
    ks = vs = None
    if ks_pool is not None:
        ks = ks_pool[safe].reshape(b, mb * bs, kvh)
        vs = vs_pool[safe].reshape(b, mb * bs, kvh)
    return ref_decode_attention(q, k, v, lens.reshape(b, 1), ks, vs)


def ref_flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True) -> jax.Array:
    """q (B,S,H,D); k/v (B,S,KVH,D): exact softmax attention oracle."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    kr = jnp.repeat(k, h // kvh, axis=2).astype(jnp.float32)
    vr = jnp.repeat(v, h // kvh, axis=2).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * d ** -0.5,
                        kr)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr)


def ref_paged_prefill_attention(q: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, page_table: jax.Array,
                                pfx_lens: jax.Array, ks_pool=None,
                                vs_pool=None):
    """Gather-then-dense oracle for the paged *prefix* segment of chunked
    prefill (the `paged_prefill_attention` kernel).

    q: (B, C, H, D) pre-scaled by 1/sqrt(D); k/v_pool: (NB, BS, KVH, D);
    page_table: (B, MB) int32 (-1 = unassigned); pfx_lens: (B,) int32 —
    row b attends pool positions < pfx_lens[b].  Returns the segment's
    flash state in the merge layout: out (B, C, H, D), m (B, H, C, 1),
    l (B, H, C, 1), all f32.  Flash convention: masked keys carry zero
    probability mass, so an empty prefix yields exactly
    (out=0, m=-1e30, l=0) — the state that merges with weight zero in
    ``layers.attention_chunk_merge``.
    """
    nb, bs, kvh, d = k_pool.shape
    b, mb = page_table.shape
    h = q.shape[2]
    safe = jnp.maximum(page_table, 0)
    k = k_pool[safe].reshape(b, mb * bs, kvh, d).astype(jnp.float32)
    v = v_pool[safe].reshape(b, mb * bs, kvh, d).astype(jnp.float32)
    if ks_pool is not None:
        k = k * ks_pool[safe].reshape(b, mb * bs, kvh)[..., None]
        v = v * vs_pool[safe].reshape(b, mb * bs, kvh)[..., None]
    kr = jnp.repeat(k, h // kvh, axis=2)
    vr = jnp.repeat(v, h // kvh, axis=2)
    scores = jnp.einsum("bchd,bshd->bhcs", q.astype(jnp.float32), kr)
    valid = jnp.arange(mb * bs)[None] < pfx_lens.reshape(b)[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.where(valid[:, None, None, :], jnp.exp(scores - m), 0.0)
    l = jnp.sum(e, axis=-1, keepdims=True)
    out = jnp.einsum("bhcs,bshd->bchd", e / jnp.where(l > 0, l, 1.0), vr)
    return out, m, l
