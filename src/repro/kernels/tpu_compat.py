"""Version compatibility helpers for the Pallas TPU API.

The TPU compiler-params dataclass was renamed across JAX releases
(``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``); every kernel
routes through :func:`compiler_params` so the package imports on either.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def compiler_params(**kw):
    return _CompilerParams(**kw)
