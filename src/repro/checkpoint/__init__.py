"""Sharded atomic checkpointing with async writes and resume."""
from repro.checkpoint import store
