"""Sharded, atomic, resumable checkpointing (fault-tolerance substrate).

Layout: one directory per step, one ``.npz`` per *host* holding that
host's shards of every leaf, plus a JSON manifest with the pytree
structure, mesh info, step, and data-iterator state.  Writes go to a
``.tmp`` directory that is atomically renamed — a crashed writer can
never corrupt the latest checkpoint (restart-safe by construction).

A background thread does the serialization so the train loop only blocks
for the device->host copy of its own shards (async checkpointing).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core.quantization import QuantizedTensor

_SEP = "|"


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if isinstance(leaf, QuantizedTensor):
            out[key + _SEP + "q"] = leaf.q
            out[key + _SEP + "scale"] = leaf.scale
            out[key + _SEP + "meta"] = np.array(
                [leaf.group_size, leaf.bits, leaf.orig_dim])
        else:
            out[key] = leaf
    return out, treedef


def save(ckpt_dir: str | os.PathLike, step: int, state: Any,
         extra: Optional[dict] = None, host_id: int = 0,
         async_: bool = False) -> threading.Thread | None:
    """Write ``state`` for ``step``.  Returns the writer thread if async."""
    root = Path(ckpt_dir)
    final = root / f"step_{step:08d}"
    tmp = root / f".tmp_step_{step:08d}_{host_id}"

    flat, _ = _flatten(state)
    # device->host copy happens here, synchronously (cheap); the rest of
    # the serialization can run in the background.
    host_arrays = {k: np.asarray(v) for k, v in flat.items()}

    def _write():
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / f"host_{host_id}.npz", **host_arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "host_id": host_id,
            "n_leaves": len(host_arrays),
            "extra": extra or {},
        }
        (tmp / f"manifest_{host_id}.json").write_text(json.dumps(manifest))
        # single-host container: host 0 commits.  Multi-host: the
        # launcher barriers before commit (runtime/elastic.py).
        if host_id == 0:
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            _update_latest(root, step)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _update_latest(root: Path, step: int) -> None:
    (root / "LATEST.tmp").write_text(str(step))
    (root / "LATEST.tmp").rename(root / "LATEST")


def latest_step(ckpt_dir: str | os.PathLike) -> Optional[int]:
    root = Path(ckpt_dir)
    marker = root / "LATEST"
    if marker.exists():
        s = int(marker.read_text().strip())
        if (root / f"step_{s:08d}").exists():
            return s
    # fall back to scanning (marker lost in a crash)
    steps = sorted(int(p.name.split("_")[1]) for p in root.glob("step_*"))
    return steps[-1] if steps else None


def restore(ckpt_dir: str | os.PathLike, state_like: Any,
            step: Optional[int] = None, host_id: int = 0):
    """Restore into the structure of ``state_like`` (arrays or structs).
    Returns (state, step, extra)."""
    root = Path(ckpt_dir)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:08d}"
    data = np.load(d / f"host_{host_id}.npz")
    manifest = json.loads((d / f"manifest_{host_id}.json").read_text())

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        state_like, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    leaves = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if isinstance(leaf, QuantizedTensor):
            meta = data[key + _SEP + "meta"]
            leaves.append(QuantizedTensor(
                q=data[key + _SEP + "q"], scale=data[key + _SEP + "scale"],
                group_size=int(meta[0]), bits=int(meta[1]),
                orig_dim=int(meta[2])))
        else:
            leaves.append(data[key])
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return state, step, manifest.get("extra", {})


def prune(ckpt_dir: str | os.PathLike, keep: int = 3) -> None:
    """Keep the newest ``keep`` checkpoints (bounded disk)."""
    root = Path(ckpt_dir)
    steps = sorted(root.glob("step_*"), key=lambda p: p.name)
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
