"""GGML-compatible Q8_0 block export.

The paper's quantization is literally GGML's "Q8_0" (it cites Gerganov's
library); this module serializes our QuantizedTensor into GGML's on-disk
block layout so quantized checkpoints interoperate with the llama.cpp
ecosystem the paper built on:

    Q8_0 block (GGML block size 32):  [ scale: f16 ][ 32 x int8 ]
    Q4_0 block:                       [ scale: f16 ][ 16 bytes = 32 nibbles ]

Our group size is configurable (default 64 = the paper's burst width);
export re-blocks to GGML's fixed 32 by re-quantizing the dequantized
groups.  When the source group is already 32 the codes are preserved
bit-exactly; otherwise each 64-group splits into two 32-blocks whose
absmax may shrink, so codes re-round — error bounded by half a (smaller)
quantization step plus f16 scale rounding.
"""

from __future__ import annotations

import struct
from typing import BinaryIO

import numpy as np

from repro.core.quantization import QuantizedTensor, quantize

GGML_BLOCK = 32
MAGIC = b"RPQ8"          # repro quantized export, versioned container


def _reblock_q8(t: QuantizedTensor) -> tuple[np.ndarray, np.ndarray]:
    """-> (codes int8 (rows, K), scales f16 (rows, K/32)) in GGML blocks."""
    deq = np.asarray(t.dequantize())
    rows = deq.reshape(-1, deq.shape[-1])
    k = rows.shape[-1]
    if k % GGML_BLOCK:
        raise ValueError(f"K={k} not divisible by GGML block {GGML_BLOCK}")
    g = rows.reshape(rows.shape[0], k // GGML_BLOCK, GGML_BLOCK)
    absmax = np.abs(g).max(axis=-1, keepdims=True)
    scale = (absmax / 127.0).astype(np.float16)
    inv = np.where(absmax > 0, 127.0 / absmax, 0.0)
    codes = np.clip(np.rint(g * inv), -127, 127).astype(np.int8)
    return codes.reshape(rows.shape[0], k), scale[..., 0]


def write_tensor(f: BinaryIO, name: str, t: QuantizedTensor) -> int:
    """Append one tensor; returns bytes written."""
    codes, scales = _reblock_q8(t)
    nb = name.encode()
    shape = t.shape
    header = struct.pack("<I", len(nb)) + nb
    header += struct.pack("<I", len(shape)) + struct.pack(
        f"<{len(shape)}q", *shape)
    payload = scales.tobytes() + codes.tobytes()
    f.write(header)
    f.write(struct.pack("<Q", len(payload)))
    f.write(payload)
    return len(header) + 8 + len(payload)


def export(path: str, params, policy=None) -> dict:
    """Write every QuantizedTensor leaf of ``params`` in GGML Q8_0 blocks.

    Returns {name: bytes} manifest.  Float leaves (norms — the paper
    keeps them fp32) are stored raw f32.
    """
    import jax

    manifest = {}
    with open(path, "wb") as f:
        f.write(MAGIC + struct.pack("<I", 1))
        flat = jax.tree_util.tree_flatten_with_path(
            params, is_leaf=lambda x: isinstance(x, QuantizedTensor))[0]
        f.write(struct.pack("<I", len(flat)))
        for keypath, leaf in flat:
            name = jax.tree_util.keystr(keypath)
            if isinstance(leaf, QuantizedTensor):
                manifest[name] = write_tensor(f, name, leaf)
            else:
                arr = np.asarray(leaf, np.float32)
                nb = name.encode()
                f.write(struct.pack("<I", len(nb)) + nb)
                f.write(struct.pack("<I", len(arr.shape)))
                f.write(struct.pack(f"<{len(arr.shape)}q", *arr.shape))
                payload = b"F32!" + arr.tobytes()
                f.write(struct.pack("<Q", len(payload)))
                f.write(payload)
                manifest[name] = len(payload)
    return manifest


def read_back(path: str) -> dict:
    """Parse the container back into {name: (shape, np.ndarray f32)} —
    dequantized; used by tests to verify round-trip fidelity."""
    out = {}
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == MAGIC, magic
        (_version,) = struct.unpack("<I", f.read(4))
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (ln,) = struct.unpack("<I", f.read(4))
            name = f.read(ln).decode()
            (nd,) = struct.unpack("<I", f.read(4))
            shape = struct.unpack(f"<{nd}q", f.read(8 * nd))
            (plen,) = struct.unpack("<Q", f.read(8))
            payload = f.read(plen)
            if payload[:4] == b"F32!":
                arr = np.frombuffer(payload[4:], np.float32).reshape(shape)
            else:
                k = shape[-1]
                rows = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
                nblk = k // GGML_BLOCK
                scales = np.frombuffer(
                    payload[: rows * nblk * 2], np.float16
                ).reshape(rows, nblk).astype(np.float32)
                codes = np.frombuffer(
                    payload[rows * nblk * 2:], np.int8
                ).reshape(rows, nblk, GGML_BLOCK)
                arr = (codes * scales[..., None]).reshape(shape)
            out[name] = (shape, arr)
    return out
