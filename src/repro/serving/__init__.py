"""Serving: continuous batching engine + sampling (paper A.1 settings)."""
from repro.serving.engine import Engine, Request, sample_logits
from repro.serving.faults import (FaultInjector, FaultPlan, SchedulerStall,
                                  SimClock)
