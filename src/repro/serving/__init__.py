"""Serving: continuous batching engine + sampling (paper A.1 settings)."""
from repro.serving.async_serving import (AsyncServer, OpenLoopReport,
                                         StreamHandle, first_token_latencies,
                                         latency_summary_ms, poisson_arrivals,
                                         run_open_loop, time_per_output_token)
from repro.serving.engine import Engine, Request, sample_logits
from repro.serving.faults import (FaultInjector, FaultPlan, SchedulerStall,
                                  SimClock)
