"""Paged KV cache: block allocator + gather-based attention view.

The fixed-slot engine (engine.py) reserves ``max_seq`` KV rows per slot —
fine at small scale, but at 32k context × 128 slots the reservation is
~100% waste for short requests.  Paged attention (vLLM) fixes this: the
cache is a pool of fixed-size *blocks*; each sequence owns a block list;
attention gathers its blocks through a page table.

Design (jit-friendly — all shapes static):

  pool:        (n_layers, n_blocks, block_size, KVH, hd)  k and v
  page_table:  (max_slots, max_blocks_per_seq) int32 — block ids, -1 free
  lens:        (max_slots,) int32

The allocator is host-side Python (like vLLM's scheduler); device code
only sees dense gathers.  Append of one token touches one (layer, block)
row.  Supports the Q8_0-quantized pool like the contiguous cache
(``quantized=True`` adds per-(position, kv-head) f32 scale pools).

The serving engine (engine.py) runs on this layout by default: it owns a
:class:`BlockAllocator` host-side and a device pool built by
``models.transformer.init_paged_cache``; decode attention reads the pool
through the page table (``kernels/paged_decode_attention.py`` on TPU, the
gather view below as the jnp oracle).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import quantize_rows


class OutOfBlocks(RuntimeError):
    pass


@dataclasses.dataclass
class PagedConfig:
    n_layers: int
    n_kv_heads: int
    head_dim: int
    block_size: int = 64
    n_blocks: int = 256
    max_slots: int = 8
    max_blocks_per_seq: int = 64
    dtype: str = "float32"
    quantized: bool = False     # int8 codes + f32 per-(pos, kv-head) scales


class BlockAllocator:
    """Host-side free-list allocator with per-slot block ownership."""

    def __init__(self, cfg: PagedConfig):
        self.cfg = cfg
        self.free: List[int] = list(range(cfg.n_blocks))[::-1]
        self.owned: List[List[int]] = [[] for _ in range(cfg.max_slots)]

    def blocks_needed(self, length: int) -> int:
        return -(-length // self.cfg.block_size)

    def can_allocate(self, slot: int, length: int) -> bool:
        """True iff :meth:`ensure`\\ (slot, length) would succeed right now.

        The scheduler uses this to decide between admitting a prefill
        chunk, deferring it, and preempting a victim — without ever
        tripping :class:`OutOfBlocks` on the serving path."""
        need = self.blocks_needed(length) - len(self.owned[slot])
        return need <= len(self.free)

    def n_free(self) -> int:
        return len(self.free)

    def ensure(self, slot: int, length: int) -> List[int]:
        """Grow slot's block list to cover ``length`` tokens."""
        need = self.blocks_needed(length)
        cur = self.owned[slot]
        while len(cur) < need:
            if not self.free:
                raise OutOfBlocks(
                    f"pool exhausted ({self.cfg.n_blocks} blocks)")
            cur.append(self.free.pop())
        return cur

    def release(self, slot: int) -> None:
        """Return every block owned by ``slot`` to the free list.

        Used both when a sequence finishes and when the scheduler preempts
        it (the request keeps its generated tokens host-side and its KV is
        recomputed on resume, so no block content needs to survive)."""
        self.free.extend(reversed(self.owned[slot]))
        self.owned[slot] = []

    def utilization(self) -> float:
        used = self.cfg.n_blocks - len(self.free)
        return used / self.cfg.n_blocks

    def page_table(self) -> np.ndarray:
        pt = np.full((self.cfg.max_slots, self.cfg.max_blocks_per_seq),
                     -1, np.int32)
        for s, blocks in enumerate(self.owned):
            pt[s, : len(blocks)] = blocks
        return pt


def init_pool(cfg: PagedConfig):
    shape = (cfg.n_layers, cfg.n_blocks, cfg.block_size, cfg.n_kv_heads,
             cfg.head_dim)
    dt = jnp.int8 if cfg.quantized else jnp.dtype(cfg.dtype)
    pool = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    if cfg.quantized:
        pool["ks"] = jnp.zeros(shape[:-1], jnp.float32)
        pool["vs"] = jnp.zeros_like(pool["ks"])
    return pool


@jax.jit
def append_token(pool, page_table, lens, k_new, v_new):
    """Write one token's K/V for every layer into each slot's current
    block position.  k_new/v_new: (L, B, KVH, hd); page_table (B, MB);
    lens (B,) = current length BEFORE the append.  Quantized pools (with
    "ks"/"vs" scale entries) Q8_0-quantize the new rows on the fly."""
    block_size = pool["k"].shape[2]
    blk_idx = lens // block_size                   # (B,)
    blk_off = lens % block_size
    blk_id = jnp.take_along_axis(page_table, blk_idx[:, None], axis=1)[:, 0]

    def write(buf, new):
        # buf (L, NB, BS, KVH, …); new (L, B, KVH, …)
        def per_slot(b, acc):
            return acc.at[:, blk_id[b], blk_off[b]].set(
                new[:, b].astype(acc.dtype))
        return jax.lax.fori_loop(0, new.shape[1], per_slot, buf)

    out = dict(pool)
    if "ks" in pool:
        kq, ks = quantize_rows(k_new)
        vq, vs = quantize_rows(v_new)
        upd = {"k": kq, "v": vq, "ks": ks, "vs": vs}
    else:
        upd = {"k": k_new, "v": v_new}
    for kk, new in upd.items():
        out[kk] = write(pool[kk], new)
    return out, lens + 1


@jax.jit
def gather_view(pool, page_table, lens):
    """Materialize each slot's (L, B, S_max, KVH, hd) contiguous view via
    the page table (S_max = max_blocks_per_seq * block_size).  Attention
    then runs exactly as on the contiguous cache; masked by ``lens``.
    Quantized pools also return the gathered (L, B, S_max, KVH) scales.

    The production TPU build fuses this gather into the decode-attention
    kernel (kernels/paged_decode_attention.py dereferences the page table
    inside its BlockSpec index_map); the view form keeps the same numerics
    and is what the tests validate against."""
    l, nb, bs, kvh, hd = pool["k"].shape
    b, mbs = page_table.shape
    safe = jnp.maximum(page_table, 0)              # -1 -> 0, masked by lens
    k = pool["k"][:, safe].reshape(l, b, mbs * bs, kvh, hd)
    v = pool["v"][:, safe].reshape(l, b, mbs * bs, kvh, hd)
    if "ks" in pool:
        ks = pool["ks"][:, safe].reshape(l, b, mbs * bs, kvh)
        vs = pool["vs"][:, safe].reshape(l, b, mbs * bs, kvh)
        return k, v, ks, vs
    return k, v


class PagedKVCache:
    """Facade gluing the allocator + pool for the engine."""

    def __init__(self, cfg: PagedConfig):
        self.cfg = cfg
        self.alloc = BlockAllocator(cfg)
        self.pool = init_pool(cfg)
        self.lens = np.zeros(cfg.max_slots, np.int32)

    # -- slot lifecycle ---------------------------------------------------
    def admit(self, slot: int, k_prompt, v_prompt) -> None:
        """k/v_prompt: (L, S_p, KVH, hd) from a prefill (f32; quantized
        pools Q8_0 them on the way in)."""
        s_p = k_prompt.shape[1]
        blocks = self.alloc.ensure(slot, s_p)
        bs = self.cfg.block_size
        if "ks" in self.pool:
            kq, ks = quantize_rows(k_prompt)
            vq, vs = quantize_rows(v_prompt)
            src = {"k": kq, "v": vq, "ks": ks, "vs": vs}
        else:
            src = {"k": k_prompt, "v": v_prompt}
        pool = dict(self.pool)
        for i, blk in enumerate(blocks):
            lo, hi = i * bs, min((i + 1) * bs, s_p)
            if lo >= s_p:
                break
            for kk, full in src.items():
                pool[kk] = pool[kk].at[:, blk, : hi - lo].set(
                    full[:, lo:hi].astype(pool[kk].dtype))
        self.pool = pool
        self.lens[slot] = s_p

    def release(self, slot: int) -> None:
        self.alloc.release(slot)
        self.lens[slot] = 0

    def append(self, k_new, v_new, active: np.ndarray) -> None:
        """k/v_new (L, B, KVH, hd) — appends for every ACTIVE slot."""
        for s in np.nonzero(active)[0]:
            self.alloc.ensure(int(s), int(self.lens[s]) + 1)
        pt = jnp.asarray(self.alloc.page_table())
        lens = jnp.asarray(self.lens)
        self.pool, new_lens = append_token(self.pool, pt, lens, k_new, v_new)
        self.lens = np.where(active, np.asarray(new_lens), self.lens)

    def view(self):
        pt = jnp.asarray(self.alloc.page_table())
        return gather_view(self.pool, pt, jnp.asarray(self.lens))
