"""Paged KV cache: refcounted copy-on-write block pool + prefix index.

A fixed per-slot reservation (the engine's dense fallback) pins
``max_seq`` KV rows per slot — fine at small scale, but at 32k context ×
128 slots the reservation is ~100% waste for short requests.  Paged
attention (vLLM) fixes this: the cache is a pool of fixed-size *blocks*;
each sequence leases a block list; attention gathers its blocks through
a page table.

Design (jit-friendly — all shapes static):

  pool:        (n_layers, n_blocks, block_size, KVH, hd)  k and v
  page_table:  (max_slots, max_blocks_per_seq) int32 — block ids, -1 free
  lens:        (max_slots,) int32

The allocator is host-side Python (like vLLM's scheduler); device code
only sees dense gathers.  Append of one token touches one (layer, block)
row.  Supports the Q8_0-quantized pool like the contiguous cache
(``quantized=True`` adds per-(position, kv-head) f32 scale pools).

Ownership model (this is the part every caller must respect):

  * Blocks are **leased, not owned**.  Each block carries a refcount —
    the number of slot page tables it appears in.  ``ensure`` hands out
    exclusive (ref 1) writable blocks; ``acquire_cached`` and ``fork``
    map existing blocks into another slot read-only (ref++).
  * A **full, immutable** block may be registered in the prefix index
    under a chain hash ``H_j = hash((H_{j-1}, token_ids[block_j]))`` —
    content-addressed by the whole token prefix, so a lookup walks the
    chain and returns the longest cached run of full blocks.  Registered
    blocks are never written again (appends always land past them).
  * ``release`` only **decrements** refcounts.  A zero-ref registered
    block is not freed: it parks on an LRU list, its KV intact, and is
    reclaimable — ``n_free`` counts it, and allocation evicts the LRU
    (dropping its index entry) only after the true free list runs dry.
    Cached blocks are therefore reclaimable, never leaked.
  * Writing into a **shared** block (ref > 1 — only reachable for the
    partial tail block mapped by ``fork``) must copy-on-write first:
    ``copy_on_write`` re-points the writer's page-table entry at a fresh
    exclusive block and reports the (src, dst) pair so the engine can
    copy the device rows before the write lands.

The serving engine (engine.py) runs on this layout by default: it owns a
:class:`BlockAllocator` host-side and a device pool built by
``models.transformer.init_paged_cache``; decode attention reads the pool
through the page table (``kernels/paged_decode_attention.py`` on TPU, the
gather view below as the jnp oracle) — shared blocks need no kernel
changes, the page table indirection already handles many-to-one maps.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import quantize_rows


class OutOfBlocks(RuntimeError):
    pass


@dataclasses.dataclass
class AuditReport:
    """Result of :meth:`BlockAllocator.audit`.

    ``violations`` are human-readable invariant breaks; ``corrupted_blocks``
    are block ids whose *content* can no longer be trusted (wrong
    refcount, multiple ownership states while leased); ``victim_slots``
    are the slots leasing a corrupted block — the engine fails exactly
    those leaseholders.  ``repaired`` flips when the allocator rebuilt
    itself back to a coherent state."""

    violations: List[str] = dataclasses.field(default_factory=list)
    corrupted_blocks: List[int] = dataclasses.field(default_factory=list)
    victim_slots: List[int] = dataclasses.field(default_factory=list)
    repaired: bool = False

    @property
    def clean(self) -> bool:
        return not self.violations


def chain_hash(parent: Optional[int], tokens) -> int:
    """Content hash of one full block given its prefix chain.

    Keyed on (parent hash, token ids) so equal hashes mean equal whole
    prefixes — a block is only reusable together with everything before
    it.  Python's tuple hash is stable within a process, which is the
    allocator's lifetime."""
    return hash((parent, tuple(int(t) for t in tokens)))


def prefix_block_hashes(tokens, block_size: int) -> List[int]:
    """Chain hashes for every *full* block of ``tokens`` (partial tail
    excluded — only immutable, completely-filled blocks are cacheable)."""
    out: List[int] = []
    h: Optional[int] = None
    for j in range(len(tokens) // block_size):
        h = chain_hash(h, tokens[j * block_size:(j + 1) * block_size])
        out.append(h)
    return out


@dataclasses.dataclass
class PagedConfig:
    n_layers: int
    n_kv_heads: int
    head_dim: int
    block_size: int = 64
    n_blocks: int = 256
    max_slots: int = 8
    max_blocks_per_seq: int = 64
    dtype: str = "float32"
    quantized: bool = False     # int8 codes + f32 per-(pos, kv-head) scales


class BlockAllocator:
    """Host-side refcounted allocator with per-slot block *leases*.

    ``owned[slot]`` is the slot's page-table prefix — a list of block ids
    it leases.  The same id may appear in several slots' lists (shared
    prefix / fork); ``refcount[id]`` counts those appearances.  Zero-ref
    blocks live either on ``free`` (content dead) or ``lru`` (registered
    in the prefix index, content intact, reclaimable in LRU order).
    """

    def __init__(self, cfg: PagedConfig, enable_prefix_cache: bool = True):
        self.cfg = cfg
        self.enable_prefix_cache = enable_prefix_cache
        self.free: List[int] = list(range(cfg.n_blocks))[::-1]
        self.owned: List[List[int]] = [[] for _ in range(cfg.max_slots)]
        self.refcount: List[int] = [0] * cfg.n_blocks
        # content hash of a registered full block (None = mutable/partial)
        self.block_hash: List[Optional[int]] = [None] * cfg.n_blocks
        # registered block's actual token ids — lookup verifies these, so
        # a chain_hash collision degrades to a miss, never to serving
        # another prefix's KV
        self.block_tokens: Dict[int, Tuple[int, ...]] = {}
        # chain hash -> canonical block id holding that whole prefix
        self.index: Dict[int, int] = {}
        # zero-ref registered blocks, least-recently-released first
        self.lru: "OrderedDict[int, None]" = OrderedDict()
        self.stats = {"lookups": 0, "hit_blocks": 0, "evictions": 0,
                      "cow_copies": 0}

    def blocks_needed(self, length: int) -> int:
        return -(-length // self.cfg.block_size)

    def can_allocate(self, slot: int, length: int) -> bool:
        """True iff :meth:`ensure`\\ (slot, length) would succeed right now.

        The scheduler uses this to decide between admitting a prefill
        chunk, deferring it, and preempting a victim — without ever
        tripping :class:`OutOfBlocks` on the serving path."""
        need = self.blocks_needed(length) - len(self.owned[slot])
        return need <= self.n_free()

    def n_free(self) -> int:
        """Reclaimable blocks: truly free + zero-ref cached (LRU)."""
        return len(self.free) + len(self.lru)

    def n_cached(self) -> int:
        """Zero-ref blocks currently held for prefix reuse."""
        return len(self.lru)

    def _pop_block(self) -> int:
        """Take a writable block: free list first, then evict the LRU
        zero-ref cached block (dropping its prefix-index entry)."""
        if self.free:
            return self.free.pop()
        if self.lru:
            bid, _ = self.lru.popitem(last=False)
            h = self.block_hash[bid]
            if h is not None and self.index.get(h) == bid:
                del self.index[h]
            self.block_hash[bid] = None
            self.block_tokens.pop(bid, None)
            self.stats["evictions"] += 1
            return bid
        raise OutOfBlocks(f"pool exhausted ({self.cfg.n_blocks} blocks)")

    def ensure(self, slot: int, length: int) -> List[int]:
        """Grow slot's lease list with fresh exclusive blocks to cover
        ``length`` tokens (cached prefix blocks must already have been
        mapped via :meth:`acquire_cached`)."""
        need = self.blocks_needed(length)
        cur = self.owned[slot]
        while len(cur) < need:
            bid = self._pop_block()
            assert self.refcount[bid] == 0
            self.refcount[bid] = 1
            cur.append(bid)
        return cur

    def _deref(self, bid: int) -> None:
        self.refcount[bid] -= 1
        assert self.refcount[bid] >= 0, f"double-free of block {bid}"
        if self.refcount[bid]:
            return
        h = self.block_hash[bid]
        if h is not None and self.index.get(h) == bid:
            self.lru[bid] = None          # newest end; content stays valid
        else:
            self.block_hash[bid] = None
            self.block_tokens.pop(bid, None)
            self.free.append(bid)

    def release(self, slot: int) -> None:
        """Drop every lease ``slot`` holds (finish or preemption).

        This only *decrements* refcounts: blocks shared with other slots
        stay live, and zero-ref registered blocks park on the LRU with
        their KV intact so a later request (or this one resuming after
        preemption) can remap them instead of recomputing."""
        blocks, self.owned[slot] = self.owned[slot], []
        for bid in reversed(blocks):
            self._deref(bid)

    def truncate(self, slot: int, length: int) -> int:
        """Shrink ``slot``'s lease list to cover exactly ``length`` tokens
        — speculative-decode rollback as *truncation*: rejected tail
        tokens are un-appended and their blocks flow back through the
        ordinary release paths (no new reclaim machinery).

        A dropped block that this slot holds exclusively is
        **unregistered** before deref — if the engine registered it while
        its content was still speculative, parking it on the LRU would
        let the prefix index serve rejected KV.  A dropped block with
        other leaseholders is merely deref'd: shared content predates the
        speculation (fork/prefix sharing) and stays valid for its other
        holders.  Returns the number of blocks dropped."""
        keep = self.blocks_needed(length)
        cur = self.owned[slot]
        dropped = 0
        while len(cur) > keep:
            bid = cur.pop()
            if self.refcount[bid] == 1:
                self._unregister(bid)
            self._deref(bid)
            dropped += 1
        return dropped

    # -- prefix cache -----------------------------------------------------
    def prefix_hashes(self, tokens) -> List[int]:
        """Chain hashes of ``tokens``' full blocks, counted as ONE lookup.

        The hashes depend only on the tokens, not on allocator state —
        the scheduler computes them once per sequence and re-walks the
        index for free on every deferred-admission retry."""
        self.stats["lookups"] += 1
        return prefix_block_hashes(tokens, self.cfg.block_size)

    def lookup_prefix(self, tokens, hashes: Optional[List[int]] = None
                      ) -> Tuple[List[int], List[int]]:
        """Longest cached run of full blocks matching ``tokens``.

        Returns (block ids, chain hashes), both possibly empty.  Walks the
        hash chain from the root; the first miss ends the run, so the
        result is always a contiguous prefix whose every block is either
        leased (live) or parked on the LRU (content intact) — eviction
        removes index entries, so presence in the index implies validity.
        Each hit's stored token ids are compared against the query
        (``hash()`` is not collision-free); because the walk verifies
        every block from the root, a match means the whole prefix's
        tokens are identical, never just hash-equal.  Pass precomputed
        ``hashes`` (:meth:`prefix_hashes`) to skip re-hashing the prompt
        on retries."""
        if not self.enable_prefix_cache:
            return [], []
        if hashes is None:
            hashes = self.prefix_hashes(tokens)
        bs = self.cfg.block_size
        bids: List[int] = []
        out: List[int] = []
        for j, h in enumerate(hashes):
            bid = self.index.get(h)
            if bid is None:
                break
            block = tuple(int(t) for t in tokens[j * bs:(j + 1) * bs])
            if self.block_tokens.get(bid) != block:
                break
            bids.append(bid)
            out.append(h)
        return bids, out

    def reusable_free_count(self, bids: Sequence[int]) -> int:
        """``n_free()`` minus the blocks in ``bids`` that are currently
        zero-ref (i.e. would come off the LRU if acquired) — the headroom
        left for *new* allocations after mapping that cached prefix."""
        return self.n_free() - sum(1 for b in set(bids)
                                   if self.refcount[b] == 0)

    def acquire_cached(self, slot: int, bids: Sequence[int]) -> None:
        """Map a looked-up cached prefix into ``slot`` read-only (ref++).

        Must be the slot's first mapping (admission) so the blocks form
        the page-table prefix that positions 0..k*bs-1 read through."""
        assert not self.owned[slot], "cached prefix must be mapped first"
        for bid in bids:
            if self.refcount[bid] == 0:
                self.lru.pop(bid)
            self.refcount[bid] += 1
            self.owned[slot].append(bid)
        self.stats["hit_blocks"] += len(bids)

    def register_block(self, slot: int, block_index: int, h: int,
                       tokens) -> None:
        """Publish a freshly-filled *full* block into the prefix index.

        The caller (engine) computes ``h`` over ``tokens`` — the block's
        token ids — chained on its parent; the ids are stored so lookups
        can verify them against the query.  If another block already
        canonically holds this prefix the index keeps it (no dedupe of
        duplicate content — this block still records its hash and simply
        frees on zero-ref instead of parking)."""
        if not self.enable_prefix_cache:
            return
        bid = self.owned[slot][block_index]
        if self.block_hash[bid] is not None:
            return                        # already registered (cached hit)
        self.block_hash[bid] = h
        self.block_tokens[bid] = tuple(int(t) for t in tokens)
        self.index.setdefault(h, bid)

    # -- fork / copy-on-write ---------------------------------------------
    def fork(self, src_slot: int, dst_slot: int) -> List[int]:
        """Lease every block of ``src_slot`` to ``dst_slot`` too (ref++).

        Both slots now read the same pool rows; the first append either
        side makes into the shared partial tail must go through
        :meth:`copy_on_write` first."""
        assert not self.owned[dst_slot], "fork target must be empty"
        for bid in self.owned[src_slot]:
            self.refcount[bid] += 1
        self.owned[dst_slot] = list(self.owned[src_slot])
        return self.owned[dst_slot]

    def copy_on_write(self, slot: int,
                      block_index: int) -> Optional[Tuple[int, int]]:
        """Make ``owned[slot][block_index]`` exclusively writable.

        Returns (src, dst) block ids when a copy is needed — the caller
        must copy the device rows src -> dst before writing — or None if
        the block is already exclusive and unregistered (mutable)."""
        bid = self.owned[slot][block_index]
        if self.refcount[bid] == 1 and self.block_hash[bid] is None:
            return None
        new = self._pop_block()
        assert self.refcount[new] == 0
        self.refcount[new] = 1
        self.owned[slot][block_index] = new
        self._deref(bid)
        self.stats["cow_copies"] += 1
        return bid, new

    def fork_cost(self, length: int, n: int) -> int:
        """Fresh blocks the first divergent token of every sibling needs
        after fanning a ``length``-token sequence out into ``n`` forks.

        Fork itself allocates nothing (ref++ only); the cost lands when
        each sibling writes its first own token:

          * ``length`` block-aligned — the shared tail is full (and
            registered, hence immutable), so *every* sibling opens a
            fresh block: ``n``.
          * partial tail — ``n - 1`` copy-on-write blocks (the last
            writer keeps the original once its refcount drops to 1).

        Admission prices a sampling group as ``blocks_needed(prompt) +
        fork_cost`` so the fanout's first decode step never finds the
        pool so tight that every sibling must immediately preempt."""
        if n <= 1:
            return 0
        return n if length % self.cfg.block_size == 0 else n - 1

    def append_cost(self, slot: int, pos: int, n: int = 1) -> int:
        """New blocks an ``n``-row append at ``pos..pos+n-1`` would take:
        the grown blocks (any the extension opens) plus a COW copy (if
        ``pos`` lands in a block this slot cannot write — shared or
        registered; only the *first* position can, every later one lands
        in a freshly grown exclusive block).  ``n > 1`` prices a
        speculative verify step's k+1 rows."""
        need = max(0, self.blocks_needed(pos + n) - len(self.owned[slot]))
        bi = pos // self.cfg.block_size
        if pos % self.cfg.block_size and bi < len(self.owned[slot]):
            bid = self.owned[slot][bi]
            if self.refcount[bid] > 1 or self.block_hash[bid] is not None:
                need += 1
        return need

    def cow_for_append(self, slot: int,
                       pos: int) -> Optional[Tuple[int, int]]:
        """COW (if required) the block a one-row append at ``pos`` will
        write into; None when the write target is already exclusive."""
        if pos % self.cfg.block_size == 0:
            return None                   # lands in a brand-new block
        bi = pos // self.cfg.block_size
        if bi >= len(self.owned[slot]):
            return None
        return self.copy_on_write(slot, bi)

    # -- accounting --------------------------------------------------------
    def utilization(self) -> float:
        """Fraction of the pool pinned by live leases (reclaimable cached
        blocks count as free — they are capacity, not occupancy)."""
        return (self.cfg.n_blocks - self.n_free()) / self.cfg.n_blocks

    def page_table(self) -> np.ndarray:
        pt = np.full((self.cfg.max_slots, self.cfg.max_blocks_per_seq),
                     -1, np.int32)
        for s, blocks in enumerate(self.owned):
            pt[s, : len(blocks)] = blocks
        return pt

    def audit(self, repair: bool = False) -> AuditReport:
        """Check (and with ``repair=True`` restore) the global
        invariants: every block in exactly one of {free, LRU, leased};
        refcounts equal lease multiplicity; prefix-index entries
        coherent.

        Detection never mutates.  Repair treats the page tables
        (``owned``) as the ground truth — they are what the device
        actually reads through — and rebuilds everything else around
        them: corrupted blocks are quarantined (prefix-index entry
        dropped, registration cleared — their KV is never served to a
        future prefix lookup), refcounts are reset to lease
        multiplicity, stale index entries are deleted, and the free
        list / LRU are rebuilt (order-preserving, deduplicated).  The
        caller decides what to do about ``victim_slots`` — the engine
        fails exactly those leaseholders and releases their leases,
        after which the pool is coherent again."""
        rep = AuditReport()
        n = self.cfg.n_blocks
        lease_count = [0] * n
        holders: Dict[int, List[int]] = {}
        for s, blocks in enumerate(self.owned):
            for bid in blocks:
                lease_count[bid] += 1
                holders.setdefault(bid, []).append(s)
        corrupted = set()
        free_set = set()
        for bid in self.free:
            if bid in free_set:
                rep.violations.append(
                    f"block {bid} duplicated on the free list")
            free_set.add(bid)
        for bid in range(n):
            states = (int(bid in free_set) + int(bid in self.lru)
                      + int(lease_count[bid] > 0))
            if states != 1:
                rep.violations.append(
                    f"block {bid} in {states} ownership states "
                    f"(free={bid in free_set}, cached={bid in self.lru}, "
                    f"leases={lease_count[bid]})")
                if lease_count[bid] > 0:
                    corrupted.add(bid)
            if self.refcount[bid] != lease_count[bid]:
                rep.violations.append(
                    f"block {bid}: refcount {self.refcount[bid]} != "
                    f"{lease_count[bid]} leases")
                corrupted.add(bid)
            if bid in free_set and self.block_hash[bid] is not None:
                rep.violations.append(f"free block {bid} still registered")
            if bid in self.lru:
                h = self.block_hash[bid]
                if h is None or self.index.get(h) != bid:
                    rep.violations.append(
                        f"cached block {bid} lost its index entry")
            if (self.block_hash[bid] is not None) != \
                    (bid in self.block_tokens):
                rep.violations.append(
                    f"block {bid}: hash/token-id records out of sync")
        for h, bid in self.index.items():
            if not (0 <= bid < n) or self.block_hash[bid] != h:
                rep.violations.append(
                    f"index entry {h} -> block {bid} is stale")
        rep.corrupted_blocks = sorted(corrupted)
        rep.victim_slots = sorted(
            {s for bid in corrupted for s in holders.get(bid, [])})
        if repair and rep.violations:
            self._repair(lease_count, corrupted)
            rep.repaired = True
        return rep

    def _unregister(self, bid: int) -> None:
        """Drop a block's prefix-index presence and registration."""
        h = self.block_hash[bid]
        if h is not None and self.index.get(h) == bid:
            del self.index[h]
        self.block_hash[bid] = None
        self.block_tokens.pop(bid, None)

    def _repair(self, lease_count: List[int], corrupted) -> None:
        """Rebuild derived state around the page tables (see audit())."""
        n = self.cfg.n_blocks
        for bid in corrupted:
            self._unregister(bid)
        # stale / dangling index entries
        for h, bid in list(self.index.items()):
            if not (0 <= bid < n) or self.block_hash[bid] != h:
                del self.index[h]
        # hash-without-tokens (or the reverse) is unverifiable by
        # lookup_prefix: drop the registration
        for bid in range(n):
            if (self.block_hash[bid] is not None) != \
                    (bid in self.block_tokens):
                self._unregister(bid)
        self.refcount = list(lease_count)

        def parked(bid: int) -> bool:
            h = self.block_hash[bid]
            return (lease_count[bid] == 0 and h is not None
                    and self.index.get(h) == bid)

        # LRU keeps its eviction order for still-valid entries; zero-ref
        # registered blocks found elsewhere (e.g. wrongly freed) park at
        # the newest end instead of losing their cached KV
        new_lru = OrderedDict(
            (bid, None) for bid in self.lru if parked(bid))
        placed = set(new_lru)
        new_free: List[int] = []
        for bid in list(self.free) + list(range(n)):
            if bid in placed or lease_count[bid] > 0:
                continue
            placed.add(bid)
            if parked(bid):
                new_lru[bid] = None
            else:
                self._unregister(bid)
                new_free.append(bid)
        self.lru = new_lru
        self.free = new_free

    def quarantine(self, slot: int, start_block: int = 0) -> None:
        """Mark ``slot``'s leased blocks from ``start_block`` on as
        suspect (e.g. the sequence produced non-finite logits, so the KV
        it wrote cannot be trusted): their prefix-index entries drop and
        their registrations clear, so ``release`` frees them instead of
        parking them on the LRU — poisoned KV never survives to back a
        later prefix hit.  Blocks below ``start_block`` (a mapped cached
        prefix that predates the fault) stay registered."""
        for bid in self.owned[slot][start_block:]:
            self._unregister(bid)

    def debug_check(self) -> None:
        """Assert the global invariants (tests call this after every
        op); the detection half of :meth:`audit`, kept assert-style for
        test ergonomics."""
        rep = self.audit(repair=False)
        assert rep.clean, ("allocator invariants violated: "
                           + "; ".join(rep.violations))


def init_pool(cfg: PagedConfig):
    shape = (cfg.n_layers, cfg.n_blocks, cfg.block_size, cfg.n_kv_heads,
             cfg.head_dim)
    dt = jnp.int8 if cfg.quantized else jnp.dtype(cfg.dtype)
    pool = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    if cfg.quantized:
        pool["ks"] = jnp.zeros(shape[:-1], jnp.float32)
        pool["vs"] = jnp.zeros_like(pool["ks"])
    return pool


@jax.jit
def append_token(pool, page_table, lens, k_new, v_new):
    """Write one token's K/V for every layer into each slot's current
    block position.  k_new/v_new: (L, B, KVH, hd); page_table (B, MB);
    lens (B,) = current length BEFORE the append.  Quantized pools (with
    "ks"/"vs" scale entries) Q8_0-quantize the new rows on the fly."""
    block_size = pool["k"].shape[2]
    blk_idx = lens // block_size                   # (B,)
    blk_off = lens % block_size
    blk_id = jnp.take_along_axis(page_table, blk_idx[:, None], axis=1)[:, 0]

    def write(buf, new):
        # buf (L, NB, BS, KVH, …); new (L, B, KVH, …)
        def per_slot(b, acc):
            return acc.at[:, blk_id[b], blk_off[b]].set(
                new[:, b].astype(acc.dtype))
        return jax.lax.fori_loop(0, new.shape[1], per_slot, buf)

    out = dict(pool)
    if "ks" in pool:
        kq, ks = quantize_rows(k_new)
        vq, vs = quantize_rows(v_new)
        upd = {"k": kq, "v": vq, "ks": ks, "vs": vs}
    else:
        upd = {"k": k_new, "v": v_new}
    for kk, new in upd.items():
        out[kk] = write(pool[kk], new)
    return out, lens + 1


@jax.jit
def gather_view(pool, page_table, lens):
    """Materialize each slot's (L, B, S_max, KVH, hd) contiguous view via
    the page table (S_max = max_blocks_per_seq * block_size).  Attention
    then runs exactly as on the contiguous cache; masked by ``lens``.
    Quantized pools also return the gathered (L, B, S_max, KVH) scales.

    The production TPU build fuses this gather into the decode-attention
    kernel (kernels/paged_decode_attention.py dereferences the page table
    inside its BlockSpec index_map); the view form keeps the same numerics
    and is what the tests validate against."""
    l, nb, bs, kvh, hd = pool["k"].shape
    b, mbs = page_table.shape
    safe = jnp.maximum(page_table, 0)              # -1 -> 0, masked by lens
    k = pool["k"][:, safe].reshape(l, b, mbs * bs, kvh, hd)
    v = pool["v"][:, safe].reshape(l, b, mbs * bs, kvh, hd)
    if "ks" in pool:
        ks = pool["ks"][:, safe].reshape(l, b, mbs * bs, kvh)
        vs = pool["vs"][:, safe].reshape(l, b, mbs * bs, kvh)
        return k, v, ks, vs
    return k, v


class PagedKVCache:
    """Facade gluing the allocator + pool for the engine."""

    def __init__(self, cfg: PagedConfig):
        self.cfg = cfg
        self.alloc = BlockAllocator(cfg)
        self.pool = init_pool(cfg)
        self.lens = np.zeros(cfg.max_slots, np.int32)

    # -- slot lifecycle ---------------------------------------------------
    def admit(self, slot: int, k_prompt, v_prompt) -> None:
        """k/v_prompt: (L, S_p, KVH, hd) from a prefill (f32; quantized
        pools Q8_0 them on the way in)."""
        s_p = k_prompt.shape[1]
        blocks = self.alloc.ensure(slot, s_p)
        bs = self.cfg.block_size
        if "ks" in self.pool:
            kq, ks = quantize_rows(k_prompt)
            vq, vs = quantize_rows(v_prompt)
            src = {"k": kq, "v": vq, "ks": ks, "vs": vs}
        else:
            src = {"k": k_prompt, "v": v_prompt}
        pool = dict(self.pool)
        for i, blk in enumerate(blocks):
            lo, hi = i * bs, min((i + 1) * bs, s_p)
            if lo >= s_p:
                break
            for kk, full in src.items():
                pool[kk] = pool[kk].at[:, blk, : hi - lo].set(
                    full[:, lo:hi].astype(pool[kk].dtype))
        self.pool = pool
        self.lens[slot] = s_p

    def release(self, slot: int) -> None:
        self.alloc.release(slot)
        self.lens[slot] = 0

    def append(self, k_new, v_new, active: np.ndarray) -> None:
        """k/v_new (L, B, KVH, hd) — appends for every ACTIVE slot."""
        for s in np.nonzero(active)[0]:
            self.alloc.ensure(int(s), int(self.lens[s]) + 1)
        pt = jnp.asarray(self.alloc.page_table())
        lens = jnp.asarray(self.lens)
        self.pool, new_lens = append_token(self.pool, pt, lens, k_new, v_new)
        self.lens = np.where(active, np.asarray(new_lens), self.lens)

    def view(self):
        pt = jnp.asarray(self.alloc.page_table())
        return gather_view(self.pool, pt, jnp.asarray(self.lens))
