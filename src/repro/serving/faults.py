"""Deterministic fault injection for the serving stack.

The engine (engine.py) is correct on the happy path; this module exists
to prove it stays *useful* off it.  A :class:`FaultPlan` is a seeded,
replayable schedule of faults — device-step exceptions, NaN/inf logits
on chosen rows, page-table corruption, scheduler stalls, clock-driven
deadline expiry — and a :class:`FaultInjector` fires them at precise
(step, site) points through explicit hook sites the engine calls:

  ``pre_step``        before scheduling: clock advances, page-table
                      corruption (caught by the allocator audit that
                      runs right after, BEFORE any block can be handed
                      out), and stall directives (the scheduler is
                      skipped for the step, simulating an idle plan).
  ``raise_if_armed``  before a device dispatch (``SITE_PREFILL`` /
                      ``SITE_DECODE``): raises :class:`InjectedFault`.
                      Hooks fire *before* the device call on purpose —
                      the decode/chunk steps donate their cache buffers,
                      so only a pre-dispatch failure is safely
                      retryable.
  ``latency``         between the step timestamp and the device call:
                      advances the simulated clock, modeling a slow
                      device step (drives the straggler detector).
  ``corrupt_logits``  after the device call: wipes chosen rows of the
                      logits to NaN, exercising the engine's NaN guard.

Everything is deterministic: fault selection that needs randomness (an
untargeted corruption picking a victim block) draws from
``np.random.default_rng(plan.seed)``, and the injector's ``log`` records
what fired where — no wall-clock anywhere, so a (traffic, plan) pair
replays bit-identically.

The module also owns the serving stack's failure vocabulary: the typed
``Request.error_kind`` constants (``ERR_*``), the
:class:`SchedulerStall` error (an idle plan with work pending, carrying
the queue snapshot), and :class:`SimClock`, the injectable simulated
clock behind per-request deadlines (``Request.deadline_ms``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

# -- Request.error_kind vocabulary (typed failure domains) -----------------
ERR_INVALID = "invalid"       # malformed request (submit-time validation)
ERR_CAPACITY = "capacity"     # could never fit the pool / grew past it
ERR_FAULT = "fault"           # persistent injected/device step failure
ERR_NAN = "nan"               # non-finite logits on the request's row
ERR_DEADLINE = "deadline"     # TTFT or total deadline exceeded
ERR_SHED = "shed"             # load shed under stall / preemption thrash
ERR_AUDIT = "audit"           # leased KV blocks quarantined by audit()

# -- injection sites -------------------------------------------------------
SITE_STEP = "step"            # pre-schedule (clock / corruption / stall)
SITE_PREFILL = "prefill"      # before the batched prefill_chunk dispatch
SITE_DECODE = "decode"        # before the batched decode dispatch


class InjectedFault(RuntimeError):
    """A planned fault fired at a device dispatch site."""

    def __init__(self, site: str, step: int, uid: Optional[int] = None):
        self.site = site
        self.step = step
        self.uid = uid
        who = f"uid={uid}" if uid is not None else "untargeted"
        super().__init__(f"injected {site} fault at step {step} ({who})")


class SchedulerStall(RuntimeError):
    """An idle step plan while work is pending.

    Carries ``snapshot`` (step index, waiting uids, running slot->uid
    map) so a crash report shows *what* wedged.  With the fault layer
    enabled the engine converts stalls into load-shedding and keeps
    serving; without it this raises — the scheduler's contract is
    defer-preempt-or-reject, never idle."""

    def __init__(self, message: str, snapshot: Optional[dict] = None):
        super().__init__(message)
        self.snapshot = snapshot or {}


class SimClock:
    """Deterministic clock for deadline tests and replayable benches.

    Drop-in for the engine's ``clock=`` knob: ``now()`` returns seconds,
    faults (or tests) move time with ``advance``/``advance_ms``.  Also
    callable so it can stand wherever ``time.perf_counter`` did."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    __call__ = now

    def advance(self, seconds: float) -> None:
        self._t += float(seconds)

    def advance_ms(self, ms: float) -> None:
        self._t += float(ms) / 1e3


@dataclasses.dataclass
class Fault:
    """One scheduled fault.  Armed from ``step`` on; fires at most
    ``times`` times (a persistent fault is just ``times`` large)."""

    kind: str                     # exception | nan | corrupt | stall | clock
    step: int                     # armed from this engine step (inclusive)
    site: str = SITE_DECODE
    uid: Optional[int] = None     # target request (None: any / injector rng)
    times: int = 1
    advance_ms: float = 0.0       # clock faults: how far time jumps
    flavor: str = "refcount"      # corrupt: refcount | free_dup | index
    fired: int = 0


class FaultPlan:
    """A seeded, chainable schedule of :class:`Fault`\\ s."""

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.faults: List[Fault] = []

    def add(self, fault: Fault) -> "FaultPlan":
        self.faults.append(fault)
        return self

    def step_exception(self, step: int, uid: Optional[int] = None,
                       site: str = SITE_DECODE,
                       times: int = 1) -> "FaultPlan":
        """Raise :class:`InjectedFault` before the site's device call.
        ``times=1`` is a transient blip (retry succeeds); large ``times``
        with a ``uid`` models a request that poisons every batch it
        joins (retries exhaust, the request is isolated)."""
        return self.add(Fault("exception", step, site=site, uid=uid,
                              times=times))

    def nan_logits(self, step: int, uid: Optional[int] = None,
                   site: str = SITE_DECODE, times: int = 1) -> "FaultPlan":
        """Wipe the target request's logits row to NaN after the device
        call — the engine's NaN guard must fail exactly that request
        (its whole sampling group) and no one else."""
        return self.add(Fault("nan", step, site=site, uid=uid, times=times))

    def corrupt_pages(self, step: int, uid: Optional[int] = None,
                      flavor: str = "refcount") -> "FaultPlan":
        """Break one allocator invariant before scheduling: ``refcount``
        (refcount != lease multiplicity), ``free_dup`` (a leased block
        pushed onto the free list), or ``index`` (a prefix-index entry
        repointed at the wrong block).  With ``uid`` the corruption
        targets that request's exclusive tail block, bounding the blast
        radius to one leaseholder."""
        return self.add(Fault("corrupt", step, uid=uid, flavor=flavor))

    def stall(self, step: int, times: int = 1) -> "FaultPlan":
        """Skip scheduling for the step — the engine sees an idle plan
        with work pending, exercising the stall -> shed path."""
        return self.add(Fault("stall", step, times=times))

    def advance_clock(self, step: int, ms: float, site: str = SITE_STEP,
                      times: int = 1) -> "FaultPlan":
        """Jump the simulated clock by ``ms``.  ``SITE_STEP`` fires
        before scheduling (deadline expiry); ``SITE_DECODE`` fires
        inside the decode timing window (a slow device step, for the
        straggler detector)."""
        return self.add(Fault("clock", step, site=site, advance_ms=ms,
                              times=times))


class FaultInjector:
    """Executes a :class:`FaultPlan` through the engine's hook sites.

    ``bind`` is called by the engine with its clock and allocator; every
    hook is a no-op once the plan's faults are exhausted, so an injector
    with an *empty* plan must leave token streams bit-identical to no
    injector at all (ci/run_ci.sh gates on exactly that)."""

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan or FaultPlan()
        self.rng = np.random.default_rng(self.plan.seed)
        self.clock: Any = None
        self.pager: Any = None
        self.log: List[dict] = []

    def bind(self, clock: Any = None, pager: Any = None) -> None:
        self.clock = clock
        self.pager = pager

    # -- hook sites -------------------------------------------------------
    def pre_step(self, step: int, scheduler: Any) -> bool:
        """Fire step-scoped faults; True => stall the scheduler this
        step.  Corruption that cannot find a target yet (the uid holds
        no blocks) stays armed and retries next step."""
        stalled = False
        for f in self.plan.faults:
            if step < f.step or f.fired >= f.times:
                continue
            if f.kind == "clock" and f.site == SITE_STEP:
                f.fired += 1
                self._advance(f.advance_ms)
                self.log.append({"step": step, "kind": "clock",
                                 "ms": f.advance_ms})
            elif f.kind == "corrupt":
                if self._corrupt(step, f, scheduler):
                    f.fired += 1
            elif f.kind == "stall":
                f.fired += 1
                stalled = True
                self.log.append({"step": step, "kind": "stall"})
        return stalled

    def raise_if_armed(self, site: str, step: int,
                       uids: Sequence[int]) -> None:
        """Raise the first armed exception fault matching (site, batch).
        A ``uid``-targeted fault only fires while its request is in the
        batch — once the engine isolates the request, the fault goes
        quiet and the survivors dispatch cleanly."""
        for f in self.plan.faults:
            if (f.kind == "exception" and f.site == site and step >= f.step
                    and f.fired < f.times
                    and (f.uid is None or f.uid in uids)):
                f.fired += 1
                self.log.append({"step": step, "site": site,
                                 "kind": "exception", "uid": f.uid})
                raise InjectedFault(site, step, uid=f.uid)

    def latency(self, step: int) -> None:
        """Advance the clock inside the device-timing window (a slow
        step, as the straggler detector would see it)."""
        for f in self.plan.faults:
            if (f.kind == "clock" and f.site != SITE_STEP
                    and step >= f.step and f.fired < f.times):
                f.fired += 1
                self._advance(f.advance_ms)
                self.log.append({"step": step, "kind": "latency",
                                 "ms": f.advance_ms})

    def nan_rows(self, site: str, step: int,
                 uids: Sequence[Optional[int]]) -> List[int]:
        """Row indexes whose logits an armed NaN fault wipes this call."""
        rows: List[int] = []
        for f in self.plan.faults:
            if f.kind != "nan" or f.site != site or step < f.step:
                continue
            for i, u in enumerate(uids):
                if f.fired >= f.times:
                    break
                if u is None:
                    continue
                if f.uid is None or u == f.uid:
                    f.fired += 1
                    rows.append(i)
                    self.log.append({"step": step, "site": site,
                                     "kind": "nan", "uid": u, "row": i})
        return sorted(set(rows))

    def corrupt_logits(self, site: str, step: int, logits,
                       uids: Sequence[Optional[int]]):
        """Apply armed NaN faults to ``logits`` (row i belongs to
        ``uids[i]``; None rows are padding and never touched)."""
        for r in self.nan_rows(site, step, uids):
            logits = logits.at[r].set(jnp.nan)
        return logits

    # -- internals --------------------------------------------------------
    def _advance(self, ms: float) -> None:
        if self.clock is None or not hasattr(self.clock, "advance_ms"):
            raise RuntimeError(
                "clock faults need an advanceable clock — construct the "
                "Engine with clock=SimClock()")
        self.clock.advance_ms(ms)

    def _corrupt(self, step: int, fault: Fault, scheduler: Any) -> bool:
        """Mutate allocator state per ``fault.flavor``; False when no
        suitable target exists yet (stays armed)."""
        pager = self.pager
        if pager is None:
            return False
        target: Optional[int] = None
        if fault.uid is not None:
            for slot in sorted(s for s, q in scheduler.running.items()
                               if q.req.uid == fault.uid):
                blocks = pager.owned[slot]
                # prefer the exclusive mutable tail: exactly one
                # leaseholder, so the audit's blast radius is this slot
                for bid in reversed(blocks):
                    if (pager.refcount[bid] == 1
                            and pager.block_hash[bid] is None):
                        target = bid
                        break
                if target is None and blocks:
                    target = blocks[-1]
                if target is not None:
                    break
        else:
            exclusive = [bid for bid in range(pager.cfg.n_blocks)
                         if pager.refcount[bid] == 1]
            if exclusive:
                target = int(self.rng.choice(exclusive))
        if fault.flavor == "refcount":
            if target is None:
                return False
            pager.refcount[target] += 1
        elif fault.flavor == "free_dup":
            if target is None:
                return False
            pager.free.append(target)
        elif fault.flavor == "index":
            if not pager.index:
                return False
            hashes = sorted(pager.index)
            h = hashes[int(self.rng.integers(len(hashes)))]
            target = pager.index[h]
            pager.index[h] = (target + 1) % pager.cfg.n_blocks
        else:
            raise ValueError(f"unknown corruption flavor {fault.flavor!r}")
        self.log.append({"step": step, "kind": "corrupt",
                         "flavor": fault.flavor, "block": target,
                         "uid": fault.uid})
        return True
