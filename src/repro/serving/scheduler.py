"""Continuous-batching scheduler: chunked prefill, mixed steps, preemption.

The engine (engine.py) used to fold queueing, admission, prefill, decode,
sampling and metrics into one class, admitting one *full-prompt* prefill
at a time — a long prompt monopolized the device while every running
decode stalled, and mid-decode growth on an oversubscribed pool raised
``OutOfBlocks``.  This module extracts the policy half of that engine
into an explicit Sarathi/vLLM-style scheduler:

  * **Queues.**  ``waiting`` (FIFO of not-yet-admitted sequences, with
    preempted sequences requeued at the *front*) and ``running`` (slot ->
    :class:`Sequence`).  The engine never touches them directly; it asks
    for a plan.
  * **Step plans.**  :meth:`Scheduler.schedule` emits a :class:`StepPlan`
    carrying (a) every running decode and (b) up to
    ``prefill_chunk_tokens`` of prompt-chunk work, so long prompts are
    prefilled in fixed-size chunks *interleaved* with decode steps
    instead of ahead of them.  The engine executes the plan verbatim:
    chunks via ``model.prefill_chunk`` against the paged pool, decodes as
    one batched step.
  * **Prefix reuse.**  Admission hashes the prompt's full blocks and asks
    the allocator for the longest cached run
    (``BlockAllocator.lookup_prefix``); hit blocks are mapped into the
    slot read-only (ref++) and the first prefill chunk starts at
    ``cached_len`` — the shared prefix executes **zero** prefill tokens.
    At least one prompt token is always re-prefilled (the engine needs
    last-token logits to sample from), so ``cached_len`` is capped at the
    last full block strictly before ``len(tokens)``.  Blocks are *leases*:
    release/preempt decrement refcounts, and capacity checks count
    zero-ref cached blocks as reclaimable.
  * **Preemption.**  When a decode needs to grow into a new block and the
    pool is exhausted, a victim is preempted: its leases are dropped
    (``BlockAllocator.release`` — registered blocks park on the LRU with
    KV intact), the request keeps its generated tokens host-side, and it
    is requeued for recompute-on-resume over ``prompt + output[:-1]``
    (chunked, under the same budget; the resume admission re-runs the
    prefix lookup, so a preempted sequence usually remaps its own still-
    cached blocks instead of recomputing), after which decode resumes by
    re-feeding ``output[-1]``.  ``OutOfBlocks`` can no longer reach the
    serving path: the scheduler only grows through
    ``BlockAllocator.can_allocate`` / ``append_cost``.
  * **Starvation bound.**  Victims are picked newest-first among
    sequences preempted fewer than ``preempt_limit`` times; a sequence
    past the limit is exempt unless *every* running sequence is exempt,
    so repeatedly evicted requests eventually hold their slot and finish.
  * **Copy-on-write.**  A decode append that would land in a shared or
    registered block (only reachable for the partial tail block mapped by
    ``BlockAllocator.fork``) re-points the slot at a fresh block and
    records the (src, dst) pair on ``StepPlan.cows``; the engine copies
    the device rows before executing the step's writes.
  * **Progress guarantee.**  Every plan either does work, preempts, or
    rejects a request with ``.error`` (never-fits prompts, oversized
    ``max_new_tokens``, empty prompts) — the engine raises if a plan
    makes no progress while work remains, instead of spinning.

The dense (non-paged) fallback uses the same scheduler with ``pager=None``:
prompts are planned as one whole-prompt chunk (the contiguous cache has
no block granularity to chunk into) and preemption never triggers.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.paged_cache import BlockAllocator


@dataclasses.dataclass
class Sequence:
    """Scheduler-side state for one request (waiting or running)."""

    req: Any                                 # serving.engine.Request
    prompt: Optional[np.ndarray] = None      # admitted (clamped) prompt
    tokens: Optional[np.ndarray] = None      # rows to prefill this run
    slot: int = -1
    prefilled: int = 0                       # prefill rows already in the pool
    kv_len: int = 0                          # total pool rows (grows in decode)
    order: int = -1                          # admission stamp (victims: newest)
    resuming: bool = False                   # recompute-after-preemption
    cached_len: int = 0                      # prefix rows mapped from cache
    prefix_hashes: Optional[List[int]] = None  # chain hashes of .tokens
    block_hashes: List[int] = dataclasses.field(default_factory=list)
    registered: int = 0                      # full blocks already in the index
    n_preemptions: int = 0                   # starvation-bound counter

    @property
    def prefill_done(self) -> bool:
        return self.tokens is not None and self.prefilled >= len(self.tokens)


@dataclasses.dataclass
class PrefillChunk:
    """One prompt chunk: rows [start, end) of ``seq.tokens``."""

    seq: Sequence
    start: int
    end: int

    @property
    def last(self) -> bool:
        return self.end >= len(self.seq.tokens)


@dataclasses.dataclass
class StepPlan:
    """What the engine must execute this step (then plans are discarded —
    the scheduler already advanced its accounting, so a plan is executed
    exactly once, synchronously)."""

    prefills: List[PrefillChunk] = dataclasses.field(default_factory=list)
    decodes: List[int] = dataclasses.field(default_factory=list)   # slot ids
    decode_uids: List[int] = dataclasses.field(default_factory=list)
    preempted: List[int] = dataclasses.field(default_factory=list)  # uids
    rejected: List[Any] = dataclasses.field(default_factory=list)  # Requests
    # copy-on-write (src, dst) block pairs the engine must copy on-device
    # before executing this step's writes
    cows: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    # (uid, cached_len) for admissions that mapped a cached prefix
    cached: List[Tuple[int, int]] = dataclasses.field(default_factory=list)

    def has_work(self) -> bool:
        return bool(self.prefills or self.decodes)

    def made_progress(self) -> bool:
        return bool(self.prefills or self.decodes or self.preempted
                    or self.rejected)

    def summary(self) -> Dict[str, Any]:
        """Compact, host-only trace entry (engine.plan_log; tests assert
        chunk/decode interleaving and prefix-cache skips on it)."""
        return {
            "prefills": [(c.seq.req.uid, c.start, c.end)
                         for c in self.prefills],
            "decodes": list(self.decode_uids),
            "preempted": list(self.preempted),
            "rejected": [r.uid for r in self.rejected],
            "cows": list(self.cows),
            "cached": list(self.cached),
        }


class Scheduler:
    """Owns admission, chunking, growth and preemption policy.

    ``pager`` is the engine's host-side :class:`BlockAllocator` for the
    paged pool (None for the dense fallback).  The scheduler is the only
    component that allocates/releases blocks; the engine republishes the
    page table once per step and executes plans.
    """

    def __init__(self, max_slots: int, max_seq: int,
                 pager: Optional[BlockAllocator] = None,
                 prefill_chunk_tokens: int = 512,
                 preempt_limit: int = 3):
        if prefill_chunk_tokens < 1:
            raise ValueError("prefill_chunk_tokens must be >= 1")
        if preempt_limit < 1:
            raise ValueError("preempt_limit must be >= 1")
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.pager = pager
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.preempt_limit = preempt_limit
        self.waiting: Deque[Sequence] = deque()
        self.running: Dict[int, Sequence] = {}
        self.n_preempted = 0
        self._order = 0
        # prefix-cache admission stats (allocator keeps block-level ones)
        self.prefix_stats = {"admissions": 0, "hits": 0, "cached_tokens": 0}

    # -- public API ------------------------------------------------------
    def add(self, req: Any) -> None:
        self.waiting.append(Sequence(req=req))

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def device_lens(self) -> np.ndarray:
        """Authoritative per-slot KV lengths (0 for free slots)."""
        lens = np.zeros(self.max_slots, np.int64)
        for slot, seq in self.running.items():
            lens[slot] = seq.kv_len
        return lens

    def finish(self, slot: int) -> None:
        """A sequence completed: release its blocks and free the slot."""
        self.running.pop(slot)
        if self.pager is not None:
            self.pager.release(slot)

    def schedule(self) -> StepPlan:
        """Build this step's plan; mutates allocator + queue state.

        Order matters: decodes first (they may preempt), then prefill
        chunks for already-running sequences, then admissions — all under
        one ``prefill_chunk_tokens`` budget.  Chunk planning never
        preempts; it defers until decodes release blocks.  A final guard
        breaks prefill-vs-prefill block deadlock by preempting the
        newest sequence.
        """
        plan = StepPlan()

        # ---- decodes: every running seq past prefill, oldest first ----
        cands = sorted(self.running.values(), key=lambda s: s.order)
        for seq in cands:
            if self.running.get(seq.slot) is not seq or not seq.prefill_done:
                continue                     # preempted earlier this step
            if not self._grow_for_decode(seq, plan):
                continue                     # seq itself preempted / failed
            plan.decodes.append(seq.slot)
            plan.decode_uids.append(seq.req.uid)
            seq.kv_len += 1                  # the planned step will write it
        if plan.decodes:                     # keep the parallel lists paired
            plan.decodes, plan.decode_uids = map(list, zip(
                *sorted(zip(plan.decodes, plan.decode_uids))))

        # ---- prefill chunks under the token budget --------------------
        budget = self.prefill_chunk_tokens
        for seq in sorted(self.running.values(), key=lambda s: s.order):
            if budget <= 0:
                break
            if self.running.get(seq.slot) is not seq or seq.prefill_done:
                continue
            budget -= self._plan_chunk(seq, budget, plan)

        # ---- admissions (FIFO; head-of-line blocks, preserving order) -
        while (budget > 0 and self.waiting
               and len(self.running) < self.max_slots):
            seq = self.waiting[0]
            err = self._admission_error(seq)
            if err is not None:
                self.waiting.popleft()
                seq.req.error = err
                plan.rejected.append(seq.req)
                continue
            # longest cached prefix of *full* blocks, capped so at least
            # one prompt token is re-prefilled (its logits seed sampling)
            bids: List[int] = []
            hashes: List[int] = []
            cached_len = 0
            if self.pager is not None:
                bs = self.pager.cfg.block_size
                if self.pager.enable_prefix_cache:
                    if seq.prefix_hashes is None:  # once per (re)queued seq
                        seq.prefix_hashes = \
                            self.pager.prefix_hashes(seq.tokens)
                    bids, hashes = self.pager.lookup_prefix(
                        seq.tokens, seq.prefix_hashes)
                    k = min(len(bids), (len(seq.tokens) - 1) // bs)
                    bids, hashes = bids[:k], hashes[:k]
                    cached_len = k * bs
                # headroom for NEW blocks after mapping the cached run
                first = min(len(seq.tokens) - cached_len, budget,
                            self.pager.reusable_free_count(bids) * bs)
            else:
                first = min(len(seq.tokens), budget)
            if first <= 0:
                break          # pool temporarily full: defer until released
            self.waiting.popleft()
            seq.slot = min(set(range(self.max_slots)) - set(self.running))
            seq.order = self._order
            self._order += 1
            self.running[seq.slot] = seq
            self.prefix_stats["admissions"] += 1
            if bids:
                self.pager.acquire_cached(seq.slot, bids)
                seq.block_hashes = list(hashes)
                seq.registered = len(bids)
                seq.cached_len = seq.prefilled = seq.kv_len = cached_len
                self.prefix_stats["hits"] += 1
                self.prefix_stats["cached_tokens"] += cached_len
                plan.cached.append((seq.req.uid, cached_len))
            budget -= self._plan_chunk(seq, budget, plan)

        # ---- deadlock guard: all running mid-prefill, no blocks, no
        # decodes -> evict a victim so the older prefill can proceed ----
        if not plan.has_work() and self.running:
            self._preempt(self._select_victim(), plan)
        return plan

    # -- internals -------------------------------------------------------
    def _admission_error(self, seq: Sequence) -> Optional[str]:
        """Validate (and on first admission, clamp) a sequence; returns an
        error string to reject with, or None."""
        req = seq.req
        if seq.tokens is None:
            keep = self.max_seq - req.max_new_tokens
            if req.max_new_tokens < 1:
                return f"max_new_tokens={req.max_new_tokens} must be >= 1"
            if keep <= 0:
                # the seed engine's `prompt[-max_seq + max_new_tokens:]`
                # silently flipped to a positive-index slice here, keeping
                # almost nothing; clamp and reject instead.
                return (f"max_new_tokens={req.max_new_tokens} leaves no "
                        f"room for any prompt within max_seq={self.max_seq}")
            prompt = np.asarray(req.prompt, np.int32).reshape(-1)
            if prompt.size == 0:
                return "empty prompt"
            if prompt.size > keep:
                prompt = prompt[-keep:]
            seq.prompt = prompt
            seq.tokens = prompt
        if self.pager is not None:
            need = self.pager.blocks_needed(len(seq.tokens))
            if need > self.pager.cfg.n_blocks:
                return (f"sequence needs {need} blocks, pool holds only "
                        f"{self.pager.cfg.n_blocks}")
        return None

    def _select_victim(self) -> Sequence:
        """Newest-first among sequences under the starvation bound.

        A sequence preempted ``preempt_limit`` times is exempt from
        victim selection unless every running sequence is exempt (the
        progress guarantee needs *someone* evictable); within the exempt
        fallback the newest still goes first, so the oldest survivor
        keeps its slot and eventually finishes."""
        cands = list(self.running.values())
        fair = [s for s in cands if s.n_preemptions < self.preempt_limit]
        return max(fair or cands, key=lambda s: s.order)

    def _grow_for_decode(self, seq: Sequence, plan: StepPlan) -> bool:
        """Make room for one more KV row; True iff ``seq`` may decode.

        The append may need a grown block *and* a copy-on-write block
        (when the write position lands in a shared tail —
        ``BlockAllocator.append_cost`` prices both).  Preempts victims
        (``_select_victim``) until the growth fits.  If ``seq`` itself is
        selected, it is preempted (recompute-on-resume) — unless even an
        empty pool could not hold it, in which case it fails with
        ``.error`` (it could never complete)."""
        if self.pager is None:
            return True
        while (self.pager.append_cost(seq.slot, seq.kv_len)
               > self.pager.n_free()):
            victim = self._select_victim()
            if victim is seq:
                whole_pool = self.pager.cfg.n_blocks
                if self.pager.blocks_needed(seq.kv_len + 1) > whole_pool:
                    self.running.pop(seq.slot)
                    self.pager.release(seq.slot)
                    seq.req.error = (
                        f"sequence grew to {seq.kv_len + 1} tokens "
                        f"({self.pager.blocks_needed(seq.kv_len + 1)} "
                        f"blocks) — more than the whole "
                        f"{whole_pool}-block pool")
                    plan.rejected.append(seq.req)
                    return False
                self._preempt(seq, plan)
                return False
            self._preempt(victim, plan)
        cow = self.pager.cow_for_append(seq.slot, seq.kv_len)
        if cow is not None:
            plan.cows.append(cow)
        self.pager.ensure(seq.slot, seq.kv_len + 1)
        return True

    def _plan_chunk(self, seq: Sequence, budget: int, plan: StepPlan) -> int:
        """Plan the next prompt chunk for ``seq`` under ``budget`` tokens;
        returns the number of tokens planned (0 = deferred)."""
        start = seq.prefilled
        end = min(len(seq.tokens), start + budget)
        if self.pager is None:
            # dense fallback: the contiguous cache is filled by one-shot
            # prefill, so the "chunk" is always the whole prompt.
            end = len(seq.tokens)
        elif not self.pager.can_allocate(seq.slot, end):
            fit = (len(self.pager.owned[seq.slot]) + self.pager.n_free()) \
                * self.pager.cfg.block_size
            end = min(end, fit)
        if end <= start:
            return 0
        if self.pager is not None:
            self.pager.ensure(seq.slot, end)
        plan.prefills.append(PrefillChunk(seq=seq, start=start, end=end))
        seq.prefilled = end
        seq.kv_len = end
        return end - start

    def _preempt(self, seq: Sequence, plan: StepPlan) -> None:
        """Evict ``seq``: leases dropped (registered blocks stay cached
        at zero refs), request requeued at the front of ``waiting`` with
        its generated tokens preserved.  On resume its KV is recomputed
        (chunked) over ``prompt + output[:-1]`` — re-admission re-runs
        the prefix lookup, so whatever full blocks survived on the LRU
        are remapped rather than recomputed; the final sampled token has
        no KV yet and is re-fed as the next decode input (``resuming``
        suppresses the duplicate first-token sample)."""
        if self.pager is not None:
            if plan.cows:
                # a COW planned for this victim earlier in the step maps
                # a dst block that release() is about to free (and that
                # may be re-leased within this very plan) — retract it so
                # the engine never copies into a reassigned block.  The
                # dst is ref-1 exclusive, so lease membership identifies
                # the victim's pairs.
                mine = set(self.pager.owned[seq.slot])
                plan.cows[:] = [p for p in plan.cows if p[1] not in mine]
            self.pager.release(seq.slot)
        self.running.pop(seq.slot)
        if seq.slot in plan.decodes:
            # the starvation bound can pick a victim whose decode was
            # already planned this step (an older sequence, when the
            # newer ones are exempt) — retract it so the engine never
            # executes a decode for an evicted slot.
            i = plan.decodes.index(seq.slot)
            plan.decodes.pop(i)
            plan.decode_uids.pop(i)
        out = list(seq.req.output or [])
        if out:
            seq.tokens = np.concatenate(
                [seq.prompt, np.asarray(out[:-1], np.int32)])
            seq.resuming = True
        else:
            seq.tokens = seq.prompt
            seq.resuming = False
        seq.slot = -1
        seq.prefilled = 0
        seq.kv_len = 0
        seq.cached_len = 0
        seq.prefix_hashes = None             # .tokens changed: rehash
        seq.block_hashes = []
        seq.registered = 0
        seq.n_preemptions += 1
        self.n_preempted += 1
        plan.preempted.append(seq.req.uid)
        self.waiting.appendleft(seq)
