"""Continuous-batching scheduler: chunked prefill, mixed steps, preemption.

The engine (engine.py) used to fold queueing, admission, prefill, decode,
sampling and metrics into one class, admitting one *full-prompt* prefill
at a time — a long prompt monopolized the device while every running
decode stalled, and mid-decode growth on an oversubscribed pool raised
``OutOfBlocks``.  This module extracts the policy half of that engine
into an explicit Sarathi/vLLM-style scheduler:

  * **Queues.**  ``waiting`` (FIFO of not-yet-admitted sequences, with
    preempted sequences requeued at the *front*) and ``running`` (slot ->
    :class:`Sequence`).  The engine never touches them directly; it asks
    for a plan.
  * **Step plans.**  :meth:`Scheduler.schedule` emits a :class:`StepPlan`
    carrying (a) every running decode and (b) up to
    ``prefill_chunk_tokens`` of prompt-chunk work, so long prompts are
    prefilled in fixed-size chunks *interleaved* with decode steps
    instead of ahead of them.  The engine executes the plan verbatim:
    chunks via ``model.prefill_chunk`` against the paged pool, decodes as
    one batched step.
  * **Preemption.**  When a decode needs to grow into a new block and the
    pool is exhausted, the newest-admitted sequence is preempted: its
    blocks go back to the pool (``BlockAllocator.release``), the request
    keeps its generated tokens host-side, and it is requeued for
    recompute-on-resume — re-prefilled over ``prompt + output[:-1]``
    (chunked, under the same budget), after which decode resumes by
    re-feeding ``output[-1]``.  ``OutOfBlocks`` can no longer reach the
    serving path: the scheduler only grows through
    ``BlockAllocator.can_allocate``.
  * **Progress guarantee.**  Every plan either does work, preempts, or
    rejects a request with ``.error`` (never-fits prompts, oversized
    ``max_new_tokens``, empty prompts) — the engine raises if a plan
    makes no progress while work remains, instead of spinning.

The dense (non-paged) fallback uses the same scheduler with ``pager=None``:
prompts are planned as one whole-prompt chunk (the contiguous cache has
no block granularity to chunk into) and preemption never triggers.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import numpy as np

from repro.serving.paged_cache import BlockAllocator


@dataclasses.dataclass
class Sequence:
    """Scheduler-side state for one request (waiting or running)."""

    req: Any                                 # serving.engine.Request
    prompt: Optional[np.ndarray] = None      # admitted (clamped) prompt
    tokens: Optional[np.ndarray] = None      # rows to prefill this run
    slot: int = -1
    prefilled: int = 0                       # prefill rows already in the pool
    kv_len: int = 0                          # total pool rows (grows in decode)
    order: int = -1                          # admission stamp (victims: newest)
    resuming: bool = False                   # recompute-after-preemption

    @property
    def prefill_done(self) -> bool:
        return self.tokens is not None and self.prefilled >= len(self.tokens)


@dataclasses.dataclass
class PrefillChunk:
    """One prompt chunk: rows [start, end) of ``seq.tokens``."""

    seq: Sequence
    start: int
    end: int

    @property
    def last(self) -> bool:
        return self.end >= len(self.seq.tokens)


@dataclasses.dataclass
class StepPlan:
    """What the engine must execute this step (then plans are discarded —
    the scheduler already advanced its accounting, so a plan is executed
    exactly once, synchronously)."""

    prefills: List[PrefillChunk] = dataclasses.field(default_factory=list)
    decodes: List[int] = dataclasses.field(default_factory=list)   # slot ids
    decode_uids: List[int] = dataclasses.field(default_factory=list)
    preempted: List[int] = dataclasses.field(default_factory=list)  # uids
    rejected: List[Any] = dataclasses.field(default_factory=list)  # Requests

    def has_work(self) -> bool:
        return bool(self.prefills or self.decodes)

    def made_progress(self) -> bool:
        return bool(self.prefills or self.decodes or self.preempted
                    or self.rejected)

    def summary(self) -> Dict[str, Any]:
        """Compact, host-only trace entry (engine.plan_log; tests assert
        chunk/decode interleaving on it)."""
        return {
            "prefills": [(c.seq.req.uid, c.start, c.end)
                         for c in self.prefills],
            "decodes": list(self.decode_uids),
            "preempted": list(self.preempted),
            "rejected": [r.uid for r in self.rejected],
        }


class Scheduler:
    """Owns admission, chunking, growth and preemption policy.

    ``pager`` is the engine's host-side :class:`BlockAllocator` for the
    paged pool (None for the dense fallback).  The scheduler is the only
    component that allocates/releases blocks; the engine republishes the
    page table once per step and executes plans.
    """

    def __init__(self, max_slots: int, max_seq: int,
                 pager: Optional[BlockAllocator] = None,
                 prefill_chunk_tokens: int = 512):
        if prefill_chunk_tokens < 1:
            raise ValueError("prefill_chunk_tokens must be >= 1")
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.pager = pager
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.waiting: Deque[Sequence] = deque()
        self.running: Dict[int, Sequence] = {}
        self.n_preempted = 0
        self._order = 0

    # -- public API ------------------------------------------------------
    def add(self, req: Any) -> None:
        self.waiting.append(Sequence(req=req))

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def device_lens(self) -> np.ndarray:
        """Authoritative per-slot KV lengths (0 for free slots)."""
        lens = np.zeros(self.max_slots, np.int64)
        for slot, seq in self.running.items():
            lens[slot] = seq.kv_len
        return lens

    def finish(self, slot: int) -> None:
        """A sequence completed: release its blocks and free the slot."""
        self.running.pop(slot)
        if self.pager is not None:
            self.pager.release(slot)

    def schedule(self) -> StepPlan:
        """Build this step's plan; mutates allocator + queue state.

        Order matters: decodes first (they may preempt), then prefill
        chunks for already-running sequences, then admissions — all under
        one ``prefill_chunk_tokens`` budget.  Chunk planning never
        preempts; it defers until decodes release blocks.  A final guard
        breaks prefill-vs-prefill block deadlock by preempting the
        newest sequence.
        """
        plan = StepPlan()

        # ---- decodes: every running seq past prefill, oldest first ----
        cands = sorted(self.running.values(), key=lambda s: s.order)
        for seq in cands:
            if self.running.get(seq.slot) is not seq or not seq.prefill_done:
                continue                     # preempted earlier this step
            if not self._grow_for_decode(seq, plan):
                continue                     # seq itself preempted / failed
            plan.decodes.append(seq.slot)
            plan.decode_uids.append(seq.req.uid)
            seq.kv_len += 1                  # the planned step will write it
        if plan.decodes:                     # keep the parallel lists paired
            plan.decodes, plan.decode_uids = map(list, zip(
                *sorted(zip(plan.decodes, plan.decode_uids))))

        # ---- prefill chunks under the token budget --------------------
        budget = self.prefill_chunk_tokens
        for seq in sorted(self.running.values(), key=lambda s: s.order):
            if budget <= 0:
                break
            if self.running.get(seq.slot) is not seq or seq.prefill_done:
                continue
            budget -= self._plan_chunk(seq, budget, plan)

        # ---- admissions (FIFO; head-of-line blocks, preserving order) -
        while (budget > 0 and self.waiting
               and len(self.running) < self.max_slots):
            seq = self.waiting[0]
            err = self._admission_error(seq)
            if err is not None:
                self.waiting.popleft()
                seq.req.error = err
                plan.rejected.append(seq.req)
                continue
            first = min(len(seq.tokens), budget)
            if self.pager is not None:
                first = min(first,
                            self.pager.n_free() * self.pager.cfg.block_size)
            if first <= 0:
                break          # pool temporarily full: defer until released
            self.waiting.popleft()
            seq.slot = min(set(range(self.max_slots)) - set(self.running))
            seq.order = self._order
            self._order += 1
            self.running[seq.slot] = seq
            budget -= self._plan_chunk(seq, budget, plan)

        # ---- deadlock guard: all running mid-prefill, no blocks, no
        # decodes -> evict the newest so the older prefill can proceed --
        if not plan.has_work() and self.running:
            self._preempt(self._newest_running(), plan)
        return plan

    # -- internals -------------------------------------------------------
    def _admission_error(self, seq: Sequence) -> Optional[str]:
        """Validate (and on first admission, clamp) a sequence; returns an
        error string to reject with, or None."""
        req = seq.req
        if seq.tokens is None:
            keep = self.max_seq - req.max_new_tokens
            if req.max_new_tokens < 1:
                return f"max_new_tokens={req.max_new_tokens} must be >= 1"
            if keep <= 0:
                # the seed engine's `prompt[-max_seq + max_new_tokens:]`
                # silently flipped to a positive-index slice here, keeping
                # almost nothing; clamp and reject instead.
                return (f"max_new_tokens={req.max_new_tokens} leaves no "
                        f"room for any prompt within max_seq={self.max_seq}")
            prompt = np.asarray(req.prompt, np.int32).reshape(-1)
            if prompt.size == 0:
                return "empty prompt"
            if prompt.size > keep:
                prompt = prompt[-keep:]
            seq.prompt = prompt
            seq.tokens = prompt
        if self.pager is not None:
            need = self.pager.blocks_needed(len(seq.tokens))
            if need > self.pager.cfg.n_blocks:
                return (f"sequence needs {need} blocks, pool holds only "
                        f"{self.pager.cfg.n_blocks}")
        return None

    def _newest_running(self) -> Sequence:
        return max(self.running.values(), key=lambda s: s.order)

    def _grow_for_decode(self, seq: Sequence, plan: StepPlan) -> bool:
        """Make room for one more KV row; True iff ``seq`` may decode.

        Preempts newest-first until the growth fits.  If ``seq`` itself is
        the newest, it is preempted (recompute-on-resume) — unless even an
        empty pool could not hold it, in which case it fails with
        ``.error`` (it could never complete)."""
        if self.pager is None:
            return True
        while not self.pager.can_allocate(seq.slot, seq.kv_len + 1):
            victim = self._newest_running()
            if victim is seq:
                whole_pool = self.pager.cfg.n_blocks
                if self.pager.blocks_needed(seq.kv_len + 1) > whole_pool:
                    self.running.pop(seq.slot)
                    self.pager.release(seq.slot)
                    seq.req.error = (
                        f"sequence grew to {seq.kv_len + 1} tokens "
                        f"({self.pager.blocks_needed(seq.kv_len + 1)} "
                        f"blocks) — more than the whole "
                        f"{whole_pool}-block pool")
                    plan.rejected.append(seq.req)
                    return False
                self._preempt(seq, plan)
                return False
            self._preempt(victim, plan)
        self.pager.ensure(seq.slot, seq.kv_len + 1)
        return True

    def _plan_chunk(self, seq: Sequence, budget: int, plan: StepPlan) -> int:
        """Plan the next prompt chunk for ``seq`` under ``budget`` tokens;
        returns the number of tokens planned (0 = deferred)."""
        start = seq.prefilled
        end = min(len(seq.tokens), start + budget)
        if self.pager is None:
            # dense fallback: the contiguous cache is filled by one-shot
            # prefill, so the "chunk" is always the whole prompt.
            end = len(seq.tokens)
        elif not self.pager.can_allocate(seq.slot, end):
            fit = (len(self.pager.owned[seq.slot]) + self.pager.n_free()) \
                * self.pager.cfg.block_size
            end = min(end, fit)
        if end <= start:
            return 0
        if self.pager is not None:
            self.pager.ensure(seq.slot, end)
        plan.prefills.append(PrefillChunk(seq=seq, start=start, end=end))
        seq.prefilled = end
        seq.kv_len = end
        return end - start

    def _preempt(self, seq: Sequence, plan: StepPlan) -> None:
        """Evict ``seq``: blocks back to the pool, request requeued at the
        front of ``waiting`` with its generated tokens preserved.  On
        resume its KV is recomputed (chunked) over ``prompt +
        output[:-1]``; the final sampled token has no KV yet and is
        re-fed as the next decode input (``resuming`` suppresses the
        duplicate first-token sample)."""
        if self.pager is not None:
            self.pager.release(seq.slot)
        self.running.pop(seq.slot)
        out = list(seq.req.output or [])
        if out:
            seq.tokens = np.concatenate(
                [seq.prompt, np.asarray(out[:-1], np.int32)])
            seq.resuming = True
        else:
            seq.tokens = seq.prompt
            seq.resuming = False
        seq.slot = -1
        seq.prefilled = 0
        seq.kv_len = 0
        self.n_preempted += 1
        plan.preempted.append(seq.req.uid)
        self.waiting.appendleft(seq)
