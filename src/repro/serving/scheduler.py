"""Continuous-batching scheduler: chunked prefill, mixed steps, preemption.

The engine (engine.py) used to fold queueing, admission, prefill, decode,
sampling and metrics into one class, admitting one *full-prompt* prefill
at a time — a long prompt monopolized the device while every running
decode stalled, and mid-decode growth on an oversubscribed pool raised
``OutOfBlocks``.  This module extracts the policy half of that engine
into an explicit Sarathi/vLLM-style scheduler:

  * **Queues.**  ``waiting`` (FIFO of not-yet-admitted sequences, with
    preempted sequences requeued at the *front*) and ``running`` (slot ->
    :class:`Sequence`).  The engine never touches them directly; it asks
    for a plan.
  * **Step plans.**  :meth:`Scheduler.schedule` emits a :class:`StepPlan`
    carrying (a) every running decode and (b) up to
    ``prefill_chunk_tokens`` of prompt-chunk work, so long prompts are
    prefilled in fixed-size chunks *interleaved* with decode steps
    instead of ahead of them.  The engine executes the plan verbatim:
    ALL of a step's chunks as one padded ``model.prefill_chunk_batch``
    call against the paged pool (per-row lengths/offsets are data — no
    same-shape grouping, see docs/ARCHITECTURE.md on shape stability),
    decodes as one batched step.
  * **Prefix reuse.**  Admission hashes the prompt's full blocks and asks
    the allocator for the longest cached run
    (``BlockAllocator.lookup_prefix``); hit blocks are mapped into the
    slot read-only (ref++) and the first prefill chunk starts at
    ``cached_len`` — the shared prefix executes **zero** prefill tokens.
    At least one prompt token is always re-prefilled (the engine needs
    last-token logits to sample from), so ``cached_len`` is capped at the
    last full block strictly before ``len(tokens)``.  Blocks are *leases*:
    release/preempt decrement refcounts, and capacity checks count
    zero-ref cached blocks as reclaimable.
  * **Preemption.**  When a decode needs to grow into a new block and the
    pool is exhausted, a victim is preempted: its leases are dropped
    (``BlockAllocator.release`` — registered blocks park on the LRU with
    KV intact), the request keeps its generated tokens host-side, and it
    is requeued for recompute-on-resume over ``prompt + output[:-1]``
    (chunked, under the same budget; the resume admission re-runs the
    prefix lookup, so a preempted sequence usually remaps its own still-
    cached blocks instead of recomputing), after which decode resumes by
    re-feeding ``output[-1]``.  ``OutOfBlocks`` can no longer reach the
    serving path: the scheduler only grows through
    ``BlockAllocator.can_allocate`` / ``append_cost``.
  * **Starvation bound.**  Victims are picked newest-first among
    sequences preempted fewer than ``preempt_limit`` times; a sequence
    past the limit is exempt unless *every* running sequence is exempt,
    so repeatedly evicted requests eventually hold their slot and finish.
  * **Copy-on-write.**  A decode append that would land in a shared or
    registered block (only reachable for the partial tail block mapped by
    ``BlockAllocator.fork``) re-points the slot at a fresh block and
    records the (src, dst) pair on ``StepPlan.cows``; the engine copies
    the device rows before executing the step's writes.
  * **Progress guarantee.**  Every plan either does work, preempts, or
    rejects a request with ``.error`` (never-fits prompts, oversized
    ``max_new_tokens``, empty prompts) — the engine raises if a plan
    makes no progress while work remains, instead of spinning.
  * **Sampling groups.**  A request with ``n_samples = n > 1`` admits
    *once* (one :class:`SamplingGroup`, one prompt prefill) while its
    admission reserves ``n`` slots and prices the pool as
    ``prompt_blocks + fork_cost`` (``BlockAllocator.fork_cost``).  When
    the prompt's last chunk completes, the engine calls
    :meth:`Scheduler.fork_group`: ``n - 1`` sibling sequences are
    created into the reserved slots, each ``fork``-ing the parent's
    block leases (prompt KV shared read-only, refcounted); the siblings'
    diverging tails un-share lazily through the existing COW path on
    their first appends.  Siblings decode/finish independently but are
    **preempted as a unit** when *external* growth pressure victimizes
    any of them (all planned decodes and COW pairs of the group retract
    in the same step), so a half-evicted group never wedges the pool;
    intra-group contention instead sheds one sibling at a time so the
    grower always makes progress.  A preempted sibling resumes like any
    sequence — recompute over ``prompt + output[:-1]``, which remaps the
    still-registered shared prompt blocks from the prefix index instead
    of recomputing them.

The dense (non-paged) fallback uses the same scheduler with ``pager=None``:
prompts are planned as one whole-prompt chunk (the contiguous cache has
no block granularity to chunk into), preemption never triggers, and
``n_samples > 1`` is rejected (fork/COW need the block pool).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.faults import ERR_CAPACITY, ERR_INVALID
from repro.serving.paged_cache import BlockAllocator


def validate_request(req: Any, max_seq: int, max_slots: int,
                     pager: Optional[BlockAllocator]
                     ) -> Optional[Tuple[str, str]]:
    """Static request validation — everything knowable at ``submit()``
    time, before any scheduling: malformed ``n_samples`` /
    ``max_new_tokens``, empty prompt, a sampling group wider than the
    slot table or on the dense cache, and a (clamped) prompt whose
    blocks could never fit the whole pool.  Returns ``(message,
    error_kind)`` or None.  Pure: the prompt is *not* clamped here —
    admission does that.  The scheduler's ``_admission_error`` re-runs
    these checks as the run-time backstop (resumed sequences regrow
    their token lists; direct ``Scheduler.add`` callers skip submit)."""
    n_samples = getattr(req, "n_samples", 1)
    if n_samples < 1:
        return f"n_samples={n_samples} must be >= 1", ERR_INVALID
    if n_samples > 1:
        if pager is None:
            return ("n_samples > 1 requires the paged KV cache "
                    "(fork/copy-on-write)"), ERR_INVALID
        if n_samples > max_slots:
            return (f"n_samples={n_samples} exceeds "
                    f"max_slots={max_slots}"), ERR_INVALID
    if req.max_new_tokens < 1:
        return (f"max_new_tokens={req.max_new_tokens} must be >= 1",
                ERR_INVALID)
    keep = max_seq - req.max_new_tokens
    if keep <= 0:
        return (f"max_new_tokens={req.max_new_tokens} leaves no "
                f"room for any prompt within max_seq={max_seq}"), ERR_INVALID
    prompt = np.asarray(req.prompt, np.int32).reshape(-1)
    if prompt.size == 0:
        return "empty prompt", ERR_INVALID
    if pager is not None:
        plen = min(int(prompt.size), keep)
        need = pager.blocks_needed(plen)
        if n_samples > 1:
            need += pager.fork_cost(plen, n_samples)
        if need > pager.cfg.n_blocks:
            return (f"sequence needs {need} blocks, pool holds only "
                    f"{pager.cfg.n_blocks}"), ERR_CAPACITY
    return None


@dataclasses.dataclass
class SamplingGroup:
    """One ``n_samples > 1`` request's fanout unit.

    Created at :meth:`Scheduler.add`; ``fanned`` flips when the prompt's
    last chunk completes and :meth:`Scheduler.fork_group` materializes
    the siblings.  The request is done when ``finished == n`` (the
    engine tracks that); ``siblings[i].output`` is the request's
    ``outputs[i]``."""

    req: Any
    n: int
    siblings: List["Sequence"] = dataclasses.field(default_factory=list)
    fanned: bool = False
    finished: int = 0


@dataclasses.dataclass
class Sequence:
    """Scheduler-side state for one request (waiting or running)."""

    req: Any                                 # serving.engine.Request
    prompt: Optional[np.ndarray] = None      # admitted (clamped) prompt
    tokens: Optional[np.ndarray] = None      # rows to prefill this run
    slot: int = -1
    prefilled: int = 0                       # prefill rows already in the pool
    kv_len: int = 0                          # total pool rows (grows in decode)
    order: int = -1                          # admission stamp (victims: newest)
    resuming: bool = False                   # recompute-after-preemption
    cached_len: int = 0                      # prefix rows mapped from cache
    prefix_hashes: Optional[List[int]] = None  # chain hashes of .tokens
    block_hashes: List[int] = dataclasses.field(default_factory=list)
    registered: int = 0                      # full blocks already in the index
    n_preemptions: int = 0                   # starvation-bound counter
    # generated tokens of THIS sequence (for a singleton / sampling-group
    # sibling 0 this is the request's ``output`` list itself; other
    # siblings own their entry of ``req.outputs``)
    output: Optional[List[int]] = None
    group: Optional[SamplingGroup] = None    # n_samples > 1 fanout unit
    sibling_index: int = 0                   # 0 = parent / singleton
    sample_key: Any = None                   # engine-lazy per-stream PRNG key

    @property
    def prefill_done(self) -> bool:
        return self.tokens is not None and self.prefilled >= len(self.tokens)


@dataclasses.dataclass
class PrefillChunk:
    """One prompt chunk: rows [start, end) of ``seq.tokens``."""

    seq: Sequence
    start: int
    end: int

    @property
    def last(self) -> bool:
        return self.end >= len(self.seq.tokens)


@dataclasses.dataclass
class SpecVerify:
    """One planned draft-then-verify decode step for ``seq``.

    The engine feeds ``[output[-1], drafts...]`` as a (k+1)-token chunk
    at ``pos_offset = start`` (the sequence's kv_len when planned),
    samples every position from the verified logits with the per-token
    keyed draws non-speculative decode would have used, and rolls the
    rejected tail back by `BlockAllocator.truncate`.  ``start`` is
    recorded because planning advances ``seq.kv_len`` optimistically by
    ``len(drafts) + 1``."""

    seq: Sequence
    drafts: List[int]
    start: int


@dataclasses.dataclass
class StepPlan:
    """What the engine must execute this step (then plans are discarded —
    the scheduler already advanced its accounting, so a plan is executed
    exactly once, synchronously)."""

    prefills: List[PrefillChunk] = dataclasses.field(default_factory=list)
    decodes: List[int] = dataclasses.field(default_factory=list)   # slot ids
    decode_uids: List[int] = dataclasses.field(default_factory=list)
    # speculative verify steps — decode-phase work a plain decode would
    # otherwise cover (a slot appears in decodes OR verifies, never both)
    verifies: List[SpecVerify] = dataclasses.field(default_factory=list)
    preempted: List[int] = dataclasses.field(default_factory=list)  # uids
    rejected: List[Any] = dataclasses.field(default_factory=list)  # Requests
    # copy-on-write (src, dst) block pairs the engine must copy on-device
    # before executing this step's writes
    cows: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    # (uid, cached_len) for admissions that mapped a cached prefix
    cached: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    # (uid, cached_len) for EVERY admission this step (cached_len = 0 on
    # a prefix-cache miss) — per-request cache attribution in metrics
    admitted: List[Tuple[int, int]] = dataclasses.field(default_factory=list)

    def has_work(self) -> bool:
        return bool(self.prefills or self.decodes or self.verifies)

    def made_progress(self) -> bool:
        return bool(self.prefills or self.decodes or self.verifies
                    or self.preempted or self.rejected)

    def summary(self) -> Dict[str, Any]:
        """Compact, host-only trace entry (engine.plan_log; tests assert
        chunk/decode interleaving and prefix-cache skips on it)."""
        return {
            "prefills": [(c.seq.req.uid, c.start, c.end)
                         for c in self.prefills],
            "decodes": list(self.decode_uids),
            "verifies": [(v.seq.req.uid, v.start, len(v.drafts))
                         for v in self.verifies],
            "preempted": list(self.preempted),
            "rejected": [r.uid for r in self.rejected],
            "cows": list(self.cows),
            "cached": list(self.cached),
            "admitted": list(self.admitted),
        }


class Scheduler:
    """Owns admission, chunking, growth and preemption policy.

    ``pager`` is the engine's host-side :class:`BlockAllocator` for the
    paged pool (None for the dense fallback).  The scheduler is the only
    component that allocates/releases blocks; the engine republishes the
    page table once per step and executes plans.
    """

    def __init__(self, max_slots: int, max_seq: int,
                 pager: Optional[BlockAllocator] = None,
                 prefill_chunk_tokens: int = 512,
                 preempt_limit: int = 3,
                 spec_tokens: int = 0,
                 draft_proposer: Any = None):
        if prefill_chunk_tokens < 1:
            raise ValueError("prefill_chunk_tokens must be >= 1")
        if preempt_limit < 1:
            raise ValueError("preempt_limit must be >= 1")
        if spec_tokens < 0:
            raise ValueError("spec_tokens must be >= 0")
        if spec_tokens and pager is None:
            raise ValueError("speculative decoding requires the paged "
                             "pool (rollback is block truncation)")
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.pager = pager
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.preempt_limit = preempt_limit
        # draft-then-verify decode: propose up to spec_tokens drafts per
        # decode-eligible sequence each step (0 / no proposer = off)
        self.spec_tokens = spec_tokens
        self.proposer = draft_proposer
        self.waiting: Deque[Sequence] = deque()
        self.running: Dict[int, Sequence] = {}
        self.n_preempted = 0
        self._order = 0
        # prefix-cache admission stats (allocator keeps block-level ones)
        self.prefix_stats = {"admissions": 0, "hits": 0, "cached_tokens": 0}

    # -- public API ------------------------------------------------------
    def add(self, req: Any) -> None:
        """Enqueue a request for admission.  Legal at ANY point between
        engine steps — continuous-arrival serving calls this mid-flight
        while earlier requests are still decoding; the new arrival is
        considered at the next ``schedule()``'s admission pass.  FIFO by
        arrival except that preempted sequences requeue at the front
        (resume-before-admit keeps the starvation bound meaningful)."""
        if req.output is None:
            req.output = []
        # sibling 0's stream IS req.output, so singleton callers keep
        # reading/mutating the list they always did
        seq = Sequence(req=req, output=req.output)
        n = getattr(req, "n_samples", 1)
        if n > 1:
            seq.group = SamplingGroup(req=req, n=n, siblings=[seq])
        self.waiting.append(seq)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def queue_depth(self) -> int:
        """Sequences admitted to the waiting queue but not yet running —
        the open-loop front-end's backpressure signal.  Preempted
        sequences waiting to resume count too: they hold no blocks
        while queued, so they are demand just like fresh arrivals."""
        return len(self.waiting)

    def request(self, uid: int) -> Optional[Any]:
        """Look up a live request by uid (waiting or running), or None
        once it has finished/failed.  The async front-end holds the
        returned object to stream ``output`` deltas mid-flight."""
        for seq in self.waiting:
            if seq.req.uid == uid:
                return seq.req
        for seq in self.running.values():
            if seq.req.uid == uid:
                return seq.req
        return None

    def device_lens(self) -> np.ndarray:
        """Authoritative per-slot KV lengths (0 for free slots)."""
        lens = np.zeros(self.max_slots, np.int64)
        for slot, seq in self.running.items():
            lens[slot] = seq.kv_len
        return lens

    def finish(self, slot: int) -> None:
        """A sequence completed: release its blocks and free the slot."""
        self.running.pop(slot)
        if self.pager is not None:
            self.pager.release(slot)

    def schedule(self) -> StepPlan:
        """Build this step's plan; mutates allocator + queue state.

        Order matters: decodes first (they may preempt), then prefill
        chunks for already-running sequences, then admissions — all under
        one ``prefill_chunk_tokens`` budget.  Chunk planning never
        preempts; it defers until decodes release blocks.  A final guard
        breaks prefill-vs-prefill block deadlock by preempting the
        newest sequence.
        """
        plan = StepPlan()

        # ---- decodes: every running seq past prefill, oldest first ----
        # (a sequence with a planned verify step skips plain decode — the
        # verify emits its next token(s); failed speculation falls back)
        cands = sorted(self.running.values(), key=lambda s: s.order)
        for seq in cands:
            if self.running.get(seq.slot) is not seq or not seq.prefill_done:
                continue                     # preempted earlier this step
            if self._plan_verify(seq, plan):
                continue                     # spec verify covers this seq
            if not self._grow_for_decode(seq, plan):
                continue                     # seq itself preempted / failed
            plan.decodes.append(seq.slot)
            plan.decode_uids.append(seq.req.uid)
            seq.kv_len += 1                  # the planned step will write it
        if plan.decodes:                     # keep the parallel lists paired
            plan.decodes, plan.decode_uids = map(list, zip(
                *sorted(zip(plan.decodes, plan.decode_uids))))

        # ---- prefill chunks under the token budget --------------------
        budget = self.prefill_chunk_tokens
        for seq in sorted(self.running.values(), key=lambda s: s.order):
            if budget <= 0:
                break
            if self.running.get(seq.slot) is not seq or seq.prefill_done:
                continue
            budget -= self._plan_chunk(seq, budget, plan)

        # ---- admissions (FIFO; head-of-line blocks, preserving order) -
        while budget > 0 and self.waiting:
            seq = self.waiting[0]
            err = self._admission_error(seq)
            if err is not None:
                self.waiting.popleft()
                seq.req.error, seq.req.error_kind = err
                plan.rejected.append(seq.req)
                continue
            # an unfanned sampling group admits once but will need n
            # slots at fanout — reserve its siblings' slots now so the
            # fork can never find the slot table full
            unfanned = seq.group is not None and not seq.group.fanned
            need_slots = seq.group.n if unfanned else 1
            if (len(self.running) + self._slots_reserved()
                    + need_slots > self.max_slots):
                break          # slots busy/reserved: defer, keep order
            # longest cached prefix of *full* blocks, capped so at least
            # one prompt token is re-prefilled (its logits seed sampling)
            bids: List[int] = []
            hashes: List[int] = []
            cached_len = 0
            if self.pager is not None:
                bs = self.pager.cfg.block_size
                if self.pager.enable_prefix_cache:
                    if seq.prefix_hashes is None:  # once per (re)queued seq
                        seq.prefix_hashes = \
                            self.pager.prefix_hashes(seq.tokens)
                    bids, hashes = self.pager.lookup_prefix(
                        seq.tokens, seq.prefix_hashes)
                    k = min(len(bids), (len(seq.tokens) - 1) // bs)
                    bids, hashes = bids[:k], hashes[:k]
                    cached_len = k * bs
                # headroom for NEW blocks after mapping the cached run;
                # a group admission additionally prices the fanout's
                # first divergent appends (fork_cost) so the siblings'
                # COW blocks are there when the fork happens
                extra = (self.pager.fork_cost(len(seq.tokens), seq.group.n)
                         if unfanned else 0)
                first = min(len(seq.tokens) - cached_len, budget,
                            (self.pager.reusable_free_count(bids) - extra)
                            * bs)
            else:
                first = min(len(seq.tokens), budget)
            if first <= 0:
                break          # pool temporarily full: defer until released
            self.waiting.popleft()
            seq.slot = min(set(range(self.max_slots)) - set(self.running))
            seq.order = self._order
            self._order += 1
            self.running[seq.slot] = seq
            self.prefix_stats["admissions"] += 1
            plan.admitted.append((seq.req.uid, cached_len if bids else 0))
            if bids:
                self.pager.acquire_cached(seq.slot, bids)
                seq.block_hashes = list(hashes)
                seq.registered = len(bids)
                seq.cached_len = seq.prefilled = seq.kv_len = cached_len
                self.prefix_stats["hits"] += 1
                self.prefix_stats["cached_tokens"] += cached_len
                plan.cached.append((seq.req.uid, cached_len))
            budget -= self._plan_chunk(seq, budget, plan)

        # ---- deadlock guard: all running mid-prefill, no blocks, no
        # decodes -> evict a victim so the older prefill can proceed ----
        if not plan.has_work() and self.running:
            self._preempt_unit(self._select_victim(), plan)
        return plan

    def fork_group(self, seq: Sequence) -> List[Sequence]:
        """Fan a just-prefilled sampling-group parent out into its
        siblings; returns all ``n`` sequences (parent first).

        Each sibling leases every block the parent holds
        (``BlockAllocator.fork`` — prompt KV shared read-only, refcount
        bumped) and starts fully prefilled at the parent's ``kv_len``;
        the divergent tails un-share through COW on their first appends.
        Slots were reserved at admission, so the fork cannot find the
        slot table full.  The caller (engine) appends each sibling's
        first sampled token and publishes the new page-table rows."""
        group = seq.group
        assert group is not None and not group.fanned and seq.prefill_done
        assert self.pager is not None, "fork needs the paged pool"
        free = sorted(set(range(self.max_slots)) - set(self.running))
        assert len(free) >= group.n - 1, \
            f"fanout of uid={seq.req.uid} found only {len(free)} free " \
            f"slots for {group.n - 1} siblings (reservation broken)"
        group.fanned = True
        group.siblings = [seq]
        for i in range(1, group.n):
            slot = free[i - 1]
            self.pager.fork(seq.slot, slot)
            sib = Sequence(
                req=seq.req, prompt=seq.prompt, tokens=seq.tokens,
                slot=slot, prefilled=seq.prefilled, kv_len=seq.kv_len,
                order=seq.order, cached_len=seq.cached_len,
                block_hashes=list(seq.block_hashes),
                registered=seq.registered,
                n_preemptions=seq.n_preemptions,
                output=[], group=group, sibling_index=i)
            self.running[slot] = sib
            group.siblings.append(sib)
        return group.siblings

    def fail_request(self, req: Any, plan: Optional[StepPlan] = None
                     ) -> None:
        """Tear down *every* sequence of ``req`` — running siblings and
        requeued ones alike (a sampling group fails as a unit, so a
        faulted sibling never leaves the rest racing a dead request).
        Running slots release their block leases; anything ``req`` still
        had planned in ``plan`` (decodes, prefill chunks, COW pairs)
        retracts so the engine never executes work for it.  The caller
        owns setting ``req.error`` / ``req.error_kind``."""
        for slot, seq in list(self.running.items()):
            if seq.req is req and self.running.get(slot) is seq:
                if plan is not None:
                    self._retract_planned(seq, plan)
                self.running.pop(slot)
                if self.pager is not None:
                    self.pager.release(slot)
        self.waiting = deque(s for s in self.waiting if s.req is not req)

    def shed_load(self, k: int = 1) -> List[Any]:
        """Graceful degradation: admission-reject up to ``k`` of the
        lowest-value *waiting* requests (smallest progress first, then
        newest-first) and return them for the engine to mark with a
        typed ``.error``.  Requests with running siblings are exempt —
        shedding targets work not yet holding device state, so dropping
        it frees scheduler pressure without releasing any lease."""
        running_uids = {s.req.uid for s in self.running.values()}
        by_uid: Dict[int, List[Sequence]] = {}
        for s in self.waiting:
            if s.req.uid not in running_uids:
                by_uid.setdefault(s.req.uid, []).append(s)
        if not by_uid:
            return []

        def value(uid: int) -> Tuple[int, int]:
            progress = max(len(s.output or []) for s in by_uid[uid])
            return (progress, -uid)          # least done, then newest

        shed: List[Any] = []
        for uid in sorted(by_uid, key=value)[:k]:
            req = by_uid[uid][0].req
            self.waiting = deque(s for s in self.waiting
                                 if s.req is not req)
            shed.append(req)
        return shed

    # -- internals -------------------------------------------------------
    def _slots_reserved(self) -> int:
        """Slots promised to running-but-unfanned sampling groups."""
        return sum(s.group.n - 1 for s in self.running.values()
                   if s.group is not None and not s.group.fanned)

    def _admission_error(self, seq: Sequence) -> Optional[Tuple[str, str]]:
        """Validate (and on first admission, clamp) a sequence; returns
        ``(error message, error kind)`` to reject with, or None.  Mostly
        a backstop behind submit-time :func:`validate_request` — but the
        pool-fit check re-runs against ``seq.tokens``, which outgrows
        the prompt across preemption/resume cycles."""
        req = seq.req
        if seq.tokens is None:
            # the seed engine's `prompt[-max_seq + max_new_tokens:]`
            # silently flipped to a positive-index slice on oversized
            # max_new_tokens, keeping almost nothing; validate_request
            # rejects that case and we clamp explicitly here.
            err = validate_request(req, self.max_seq, self.max_slots,
                                   self.pager)
            if err is not None:
                return err
            keep = self.max_seq - req.max_new_tokens
            prompt = np.asarray(req.prompt, np.int32).reshape(-1)
            if prompt.size > keep:
                prompt = prompt[-keep:]
            seq.prompt = prompt
            seq.tokens = prompt
        if self.pager is not None:
            need = self.pager.blocks_needed(len(seq.tokens))
            if seq.group is not None and not seq.group.fanned:
                need += self.pager.fork_cost(len(seq.tokens), seq.group.n)
            if need > self.pager.cfg.n_blocks:
                return (f"sequence needs {need} blocks, pool holds only "
                        f"{self.pager.cfg.n_blocks}", ERR_CAPACITY)
        return None

    def _select_victim(self) -> Sequence:
        """Newest-first among sequences under the starvation bound.

        A sequence preempted ``preempt_limit`` times is exempt from
        victim selection unless every running sequence is exempt (the
        progress guarantee needs *someone* evictable); within the exempt
        fallback the newest still goes first, so the oldest survivor
        keeps its slot and eventually finishes."""
        cands = list(self.running.values())
        fair = [s for s in cands if s.n_preemptions < self.preempt_limit]
        # sampling-group siblings share the parent's admission order;
        # the sibling_index tie-break keeps victim choice deterministic
        return max(fair or cands, key=lambda s: (s.order, s.sibling_index))

    def _grow_for_decode(self, seq: Sequence, plan: StepPlan) -> bool:
        """Make room for one more KV row; True iff ``seq`` may decode.

        The append may need a grown block *and* a copy-on-write block
        (when the write position lands in a shared tail —
        ``BlockAllocator.append_cost`` prices both).  Preempts victims
        (``_select_victim``) until the growth fits.  A victim belonging
        to a *different* fanned sampling group takes its whole group
        with it (unit preemption — all of the group's planned decodes
        and COW pairs retract this same step); a victim in ``seq``'s OWN
        group is shed alone, so intra-group contention drains one
        sibling at a time instead of the grower evicting itself.  If
        ``seq`` itself is selected, it is preempted
        (recompute-on-resume) — unless even an empty pool could not hold
        it, in which case it fails with ``.error`` (it could never
        complete)."""
        if self.pager is None:
            return True
        while (self.pager.append_cost(seq.slot, seq.kv_len)
               > self.pager.n_free()):
            victim = self._select_victim()
            if victim is seq:
                whole_pool = self.pager.cfg.n_blocks
                if self.pager.blocks_needed(seq.kv_len + 1) > whole_pool:
                    seq.req.error = (
                        f"sequence grew to {seq.kv_len + 1} tokens "
                        f"({self.pager.blocks_needed(seq.kv_len + 1)} "
                        f"blocks) — more than the whole "
                        f"{whole_pool}-block pool")
                    seq.req.error_kind = ERR_CAPACITY
                    # a group fails as a unit: one sibling that can never
                    # fit dooms the request, so tear every sibling down
                    # (running and requeued alike) instead of leaving the
                    # rest racing a request already rejected
                    doomed = ([seq] if seq.group is None
                              else seq.group.siblings)
                    for s in doomed:
                        if self.running.get(s.slot) is s:
                            self._retract_planned(s, plan)
                            self.running.pop(s.slot)
                            self.pager.release(s.slot)
                    if seq.group is not None:
                        self.waiting = deque(
                            s for s in self.waiting
                            if s.group is not seq.group)
                    plan.rejected.append(seq.req)
                    return False
                self._preempt(seq, plan)
                return False
            if (victim.group is not None and victim.group.fanned
                    and victim.group is seq.group):
                self._preempt(victim, plan)      # shed ONE own sibling
            else:
                self._preempt_unit(victim, plan)
        cow = self.pager.cow_for_append(seq.slot, seq.kv_len)
        if cow is not None:
            plan.cows.append(cow)
        self.pager.ensure(seq.slot, seq.kv_len + 1)
        return True

    def _plan_verify(self, seq: Sequence, plan: StepPlan) -> bool:
        """Plan a draft-then-verify step for ``seq`` if speculation is on
        and a useful draft exists; True iff a verify covers this seq's
        decode this step.

        Speculation is strictly *opportunistic*: it never preempts
        anyone.  Under pool pressure the draft shrinks token by token
        toward zero (the k+1 rows are priced by ``append_cost(..., n)``
        against the free pool) and an empty draft falls back to the
        plain decode path, which owns the preemption policy.  ``k`` is
        further capped by the request's remaining output budget (a
        verify step emits up to k+1 tokens) and by ``max_seq``
        headroom."""
        if self.spec_tokens <= 0 or self.proposer is None \
                or self.pager is None:
            return False
        out = seq.output if seq.output is not None else []
        if not out:
            return False                     # decode re-feeds output[-1]
        k = min(self.spec_tokens,
                seq.req.max_new_tokens - len(out) - 1,
                self.max_seq - 1 - seq.kv_len)
        if k < 1:
            return False
        drafts = [int(t) for t in
                  self.proposer.propose(seq.prompt, out, k)][:k]
        while drafts and (self.pager.append_cost(
                seq.slot, seq.kv_len, len(drafts) + 1)
                > self.pager.n_free()):
            drafts.pop()                     # shrink, never preempt
        if not drafts:
            return False
        start = seq.kv_len
        cow = self.pager.cow_for_append(seq.slot, start)
        if cow is not None:
            plan.cows.append(cow)
        self.pager.ensure(seq.slot, start + len(drafts) + 1)
        plan.verifies.append(SpecVerify(seq=seq, drafts=drafts,
                                        start=start))
        # optimistic: the engine resets kv_len to the accepted length
        # and truncates the slot's lease list after the verify executes
        seq.kv_len = start + len(drafts) + 1
        return True

    def _plan_chunk(self, seq: Sequence, budget: int, plan: StepPlan) -> int:
        """Plan the next prompt chunk for ``seq`` under ``budget`` tokens;
        returns the number of tokens planned (0 = deferred)."""
        start = seq.prefilled
        end = min(len(seq.tokens), start + budget)
        if self.pager is None:
            # dense fallback: the contiguous cache is filled by one-shot
            # prefill, so the "chunk" is always the whole prompt.
            end = len(seq.tokens)
        elif not self.pager.can_allocate(seq.slot, end):
            fit = (len(self.pager.owned[seq.slot]) + self.pager.n_free()) \
                * self.pager.cfg.block_size
            end = min(end, fit)
        if end <= start:
            return 0
        if self.pager is not None:
            self.pager.ensure(seq.slot, end)
        plan.prefills.append(PrefillChunk(seq=seq, start=start, end=end))
        seq.prefilled = end
        seq.kv_len = end
        return end - start

    def _retract_planned(self, seq: Sequence, plan: StepPlan) -> None:
        """Strip everything already planned this step for a sequence
        about to leave ``running``.  A COW planned for it maps a dst
        block that release() is about to free (and that may be re-leased
        within this very plan) — retract it so the engine never copies
        into a reassigned block (the dst is ref-1 exclusive, so lease
        membership identifies the pairs).  Likewise its planned decode:
        the starvation bound (or a group unit-preemption) can evict a
        sequence whose decode was already planned.  Planned prefill
        chunks retract too (the watchdog can fail a mid-prefill request
        after planning)."""
        if self.pager is not None and plan.cows:
            mine = set(self.pager.owned[seq.slot])
            plan.cows[:] = [p for p in plan.cows if p[1] not in mine]
        if seq.slot in plan.decodes:
            i = plan.decodes.index(seq.slot)
            plan.decodes.pop(i)
            plan.decode_uids.pop(i)
        plan.verifies[:] = [v for v in plan.verifies if v.seq is not seq]
        plan.prefills[:] = [c for c in plan.prefills if c.seq is not seq]

    def _preempt_unit(self, seq: Sequence, plan: StepPlan) -> None:
        """Preempt ``seq`` — and, when it belongs to a fanned sampling
        group, every running sibling with it in the same step.  All of
        the group's planned decodes and COW pairs retract together (per
        sibling, in :meth:`_preempt`), so the engine never executes a
        decode or device copy for a half-evicted group.  Siblings are
        requeued lowest-index-first at the waiting front and resume as
        ordinary sequences whose prompt blocks remap from the prefix
        index."""
        group = seq.group
        if group is None or not group.fanned:
            self._preempt(seq, plan)
            return
        members = [s for s in group.siblings
                   if self.running.get(s.slot) is s]
        for s in sorted(members, key=lambda s: s.sibling_index,
                        reverse=True):         # appendleft: sib 0 ends front
            self._preempt(s, plan)

    def _preempt(self, seq: Sequence, plan: StepPlan) -> None:
        """Evict ``seq``: leases dropped (registered blocks stay cached
        at zero refs), request requeued at the front of ``waiting`` with
        its generated tokens preserved.  On resume its KV is recomputed
        (chunked) over ``prompt + output[:-1]`` — re-admission re-runs
        the prefix lookup, so whatever full blocks survived on the LRU
        are remapped rather than recomputed; the final sampled token has
        no KV yet and is re-fed as the next decode input (``resuming``
        suppresses the duplicate first-token sample)."""
        self._retract_planned(seq, plan)
        if self.pager is not None:
            self.pager.release(seq.slot)
        self.running.pop(seq.slot)
        out = list(seq.output if seq.output is not None
                   else (seq.req.output or []))
        if out:
            seq.tokens = np.concatenate(
                [seq.prompt, np.asarray(out[:-1], np.int32)])
            seq.resuming = True
        else:
            seq.tokens = seq.prompt
            seq.resuming = False
        seq.slot = -1
        seq.prefilled = 0
        seq.kv_len = 0
        seq.cached_len = 0
        seq.prefix_hashes = None             # .tokens changed: rehash
        seq.block_hashes = []
        seq.registered = 0
        seq.n_preemptions += 1
        self.n_preempted += 1
        plan.preempted.append(seq.req.uid)
        self.waiting.appendleft(seq)
