"""Communication-avoiding sampling over vocab-sharded logits.

§Perf (glm4 decode, iteration 3) found the residual decode collective is
dominated by gathering the (B, V) logits for sampling — ~25 MB/step at
V=151k, B=128.  These primitives avoid that:

``gumbel_argmax``   temperature sampling via the Gumbel-max trick:
                    argmax_v (logits/T + g_v) — the argmax distributes
                    over vocab shards, so each shard reduces locally and
                    only (B, 1) winners cross the wire (GSPMD turns the
                    sharded argmax into a tiny all-reduce).  EXACT: the
                    per-element Gumbel noise is keyed on the *global*
                    vocab index, so sharded and unsharded sampling draw
                    identical tokens from identical keys.

``topk_candidates`` local-top-k preselect for top-p: each shard surfaces
                    its k best (value, global-index) pairs; the (B, k·16)
                    candidate strip is ~1000x smaller than the logits and
                    contains the global top-k whenever k ≥ global-k, so
                    nucleus sampling on the strip is exact for
                    p-mass covered by k·shards candidates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _gumbel(key, shape) -> jax.Array:
    u = jax.random.uniform(key, shape, minval=1e-20, maxval=1.0)
    return -jnp.log(-jnp.log(u))


def gumbel_argmax(key, logits: jax.Array, temperature: float = 1.0
                  ) -> jax.Array:
    """(B, V) -> (B,) int32 sample ~ softmax(logits / T).

    One categorical draw == argmax over Gumbel-perturbed logits.  The
    noise is generated elementwise from the global index, so the result
    is invariant to how V is sharded.
    """
    b, v = logits.shape
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    g = _gumbel(key, (b, v))
    return jnp.argmax(logits / temperature + g, axis=-1).astype(jnp.int32)


def topk_candidates(logits: jax.Array, k: int = 64):
    """(B, V) -> (values (B, k), indices (B, k)) — the strip nucleus
    sampling runs on.  Under GSPMD with V sharded this lowers to a local
    top-k per shard + a small gather (the compiler splits lax.top_k
    across the sharded axis)."""
    vals, idx = jax.lax.top_k(logits, k)
    return vals, idx.astype(jnp.int32)


def sample_topp_from_candidates(key, vals: jax.Array, idx: jax.Array,
                                temperature: float = 1.0,
                                top_p: float = 1.0) -> jax.Array:
    """Nucleus sampling on a (B, k) candidate strip -> (B,) token ids."""
    if temperature <= 0.0:
        return idx[:, 0]
    logits = vals / temperature
    probs = jax.nn.softmax(logits, axis=-1)         # sorted descending
    csum = jnp.cumsum(probs, axis=-1)
    keep = (csum - probs) < top_p                   # first item always kept
    logits = jnp.where(keep, logits, -jnp.inf)
    choice = jax.random.categorical(key, logits, axis=-1)
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0]


def distributed_sample(key, logits: jax.Array, temperature: float = 1.0,
                       top_p: float = 1.0, k: int = 64) -> jax.Array:
    """Drop-in replacement for full-gather sampling over sharded logits."""
    if top_p >= 1.0:
        return gumbel_argmax(key, logits, temperature)
    vals, idx = topk_candidates(logits, k)
    return sample_topp_from_candidates(key, vals, idx, temperature, top_p)
