"""Continuous-arrival async serving front-end + per-step streaming.

The engine's :meth:`Engine.run` drains a CLOSED batch: everything is
submitted up front and nothing comes back until it finishes.  Production
traffic is an open stream — requests arrive while earlier ones are
decoding, want their tokens as they are produced, and are judged on
latency from their TRUE arrival instant, queueing delay included.  This
module is that front-end, built over the engine's pipelined step split:

  ``Engine.step_async()``   plans the step and DISPATCHES the device
                            work (chunked prefill, batched decode, the
                            per-row-keyed sampling) without blocking on
                            the sampled tokens — JAX async dispatch
                            leaves the device computing;
  (overlap window)          the host ingests newly arrived requests
                            into the scheduler and flushes previously
                            produced tokens to stream consumers — work
                            that cannot depend on this step's tokens;
  ``Engine.finish_step()``  blocks on the tokens and runs the
                            token-dependent bookkeeping (append, block
                            registration, stop detection).

Bit-exactness is preserved by construction: every token-VALUE-dependent
decision still happens after the sync, and sampling is per-row keyed
(``fold_in(seed/stream, position)``), so a request's stream depends only
on its own identity and position — never on who shared the batch or when
anyone else arrived.  ``test_async_serving.py`` pins open-loop streams
bit-identical to the closed ``run()`` path for the same arrival order.

Streaming follows saxml's ``stream_interval_steps`` idiom: token deltas
are flushed to callbacks/generators every N engine steps (and always at
request completion), trading callback overhead against freshness.

The open-loop driver (:func:`run_open_loop`) serves a seeded arrival
schedule (:func:`poisson_arrivals`) and reports goodput and TTFT/TPOT
percentiles measured from true arrival time — the metrics drain-time
benchmarks structurally cannot see.  Latency helpers here are shared by
``launch/serve.py`` and ``benchmarks/engine_bench.py``; they exclude
requests that never produced a first token (``t_first_token == 0.0``
default on errored/rejected requests), whose ``t_first_token -
t_enqueue`` would otherwise contribute a bogus large-negative sample.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.engine import Engine, Request
from repro.serving.faults import ERR_SHED


# -- latency accounting (shared by serve.py and engine_bench.py) -----------
def first_token_latencies(requests) -> np.ndarray:
    """Seconds from TRUE arrival (``t_enqueue``) to the first sampled
    token, one sample per request that actually produced one.  Requests
    that never got a first token (rejected at submit, failed before
    prefill finished) keep the dataclass default ``t_first_token ==
    0.0`` — including them would inject ``-t_enqueue`` (huge negative)
    samples and corrupt every percentile, so they are filtered here."""
    return np.asarray([r.t_first_token - r.t_enqueue for r in requests
                       if r.t_first_token > 0.0], np.float64)


def time_per_output_token(requests) -> np.ndarray:
    """Per-request TPOT in seconds: ``(t_done - t_first_token) /
    (n_tokens - 1)`` over the primary stream, for error-free requests
    that decoded at least one token past the first."""
    out = []
    for r in requests:
        n = len(r.output or [])
        if r.error is None and r.t_first_token > 0.0 and n >= 2:
            out.append((r.t_done - r.t_first_token) / (n - 1))
    return np.asarray(out, np.float64)


def latency_summary_ms(samples_s: np.ndarray) -> Dict[str, float]:
    """{p50, p95, p99, mean} in milliseconds (zeros when empty — the
    bench gates catch the empty case through zero goodput instead of a
    NaN that would not survive JSON)."""
    if len(samples_s) == 0:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0}
    ms = np.asarray(samples_s, np.float64) * 1e3
    return {"p50": float(np.percentile(ms, 50)),
            "p95": float(np.percentile(ms, 95)),
            "p99": float(np.percentile(ms, 99)),
            "mean": float(np.mean(ms))}


def negative_latency_samples(requests) -> int:
    """Count of impossible (negative) latency samples among requests
    that DID produce a first token — the CI regression guard for the
    ``t_first_token == 0.0`` filtering bug: with the filter in place
    this is 0 even when rejected/errored requests share the list."""
    ttft = first_token_latencies(requests)
    tpot = time_per_output_token(requests)
    return int(np.sum(ttft < 0)) + int(np.sum(tpot < 0))


def poisson_arrivals(seed: int, n: int, rate_per_s: float) -> np.ndarray:
    """Seeded Poisson arrival process: ``n`` arrival offsets in seconds
    (cumulative Exp(rate) gaps), replayable for closed-vs-open
    bit-exactness comparisons."""
    if rate_per_s <= 0:
        raise ValueError("arrival rate must be positive")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_per_s, size=n))


class StreamHandle:
    """A submitted (or scheduled-for-arrival) request's streaming
    surface.  Tokens land in ``buffer`` as ``(sibling, token)`` pairs in
    production order; ``on_token(handle, sibling, tokens, done)`` fires
    at each flush with the new tokens for that sibling.  ``req`` is the
    live engine :class:`Request` — ``done``/``error`` become meaningful
    once the engine returns it."""

    def __init__(self, prompt: np.ndarray, kw: Dict[str, Any],
                 on_token: Optional[Callable] = None,
                 t_arrival: Optional[float] = None):
        self.prompt = prompt
        self.kw = kw
        self.on_token = on_token
        self.t_arrival = t_arrival
        self.req: Optional[Request] = None     # set at submission
        self.uid: Optional[int] = None
        self.buffer: Deque[Tuple[int, int]] = deque()
        self.done = False
        self._offsets: List[int] = []

    @property
    def error(self) -> Optional[str]:
        return self.req.error if self.req is not None else None

    @property
    def error_kind(self) -> Optional[str]:
        return self.req.error_kind if self.req is not None else None

    def _streams(self) -> List[List[int]]:
        if self.req is None:
            return []
        if self.req.outputs is not None:
            return self.req.outputs
        return [self.req.output or []]


class AsyncServer:
    """Open-loop serving over one :class:`Engine`.

    ``submit()`` is legal at any moment — including from inside the
    overlap window while a device step is in flight.  ``step()`` runs
    one pipelined engine step: dispatch, then (device busy) release due
    scheduled arrivals and flush stream deltas, then sync.  Deadlines
    keep their engine semantics and are measured against the request's
    true arrival time (``t_arrival`` stamps ``t_enqueue``), so a
    request that queued behind a burst can expire without ever running.

    ``max_queue_depth`` bounds the waiting queue (admission-level
    backpressure): arrivals beyond it are shed immediately with
    ``error_kind=ERR_SHED`` instead of growing the queue without
    bound — an open-loop front-end with an unbounded queue just
    converts overload into unbounded TTFT."""

    def __init__(self, engine: Engine, stream_interval_steps: int = 1,
                 max_queue_depth: Optional[int] = None):
        self.engine = engine
        self.stream_interval_steps = max(1, int(stream_interval_steps))
        self.max_queue_depth = max_queue_depth
        self._active: Dict[int, StreamHandle] = {}
        self._arrivals: List[Tuple[float, int, StreamHandle]] = []  # heap
        self._arrival_seq = 0          # heap tiebreak = arrival order
        self._shed_uid = 0
        self._steps = 0
        self.midflight_submits = 0     # arrivals while work was in flight
        self.peak_queue_depth = 0

    # -- submission ------------------------------------------------------
    def submit(self, prompt: np.ndarray, *,
               on_token: Optional[Callable] = None,
               t_arrival: Optional[float] = None, **kw) -> StreamHandle:
        """Submit now.  ``t_arrival`` stamps the request's true arrival
        instant (defaults to the engine clock's now); latency and
        deadlines are charged from it."""
        handle = StreamHandle(prompt, kw, on_token, t_arrival)
        self._submit_handle(handle)
        return handle

    def schedule_arrival(self, t_arrival: float, prompt: np.ndarray, *,
                         on_token: Optional[Callable] = None,
                         **kw) -> StreamHandle:
        """Register a FUTURE arrival (open-loop workloads): the request
        is submitted once the clock passes ``t_arrival``, with
        ``t_enqueue`` stamped to ``t_arrival`` itself even if release
        happens later (the engine was mid-step) — release jitter must
        show up as queueing delay, not vanish from it."""
        handle = StreamHandle(prompt, kw, on_token, t_arrival)
        heapq.heappush(self._arrivals,
                       (float(t_arrival), self._arrival_seq, handle))
        self._arrival_seq += 1
        return handle

    def _submit_handle(self, handle: StreamHandle) -> None:
        eng = self.engine
        if (self.max_queue_depth is not None
                and eng.scheduler.queue_depth() >= self.max_queue_depth):
            # backpressure shed: never reaches the engine
            self._shed_uid -= 1
            now = eng._now()
            handle.req = Request(
                uid=self._shed_uid, prompt=np.asarray(handle.prompt),
                t_enqueue=(handle.t_arrival if handle.t_arrival is not None
                           else now),
                t_done=now, output=[],
                error=(f"shed at admission: queue depth "
                       f"{eng.scheduler.queue_depth()} >= "
                       f"{self.max_queue_depth}"),
                error_kind=ERR_SHED, **handle.kw)
            eng.metrics["shed_requests"] += 1
            handle.done = True
            if handle.on_token is not None:
                handle.on_token(handle, 0, [], True)
            return
        if eng.scheduler.has_work() or eng._pending is not None:
            self.midflight_submits += 1
        kw = dict(handle.kw)
        if handle.t_arrival is not None:
            kw["t_enqueue"] = handle.t_arrival
        handle.req = eng.submit_request(handle.prompt, **kw)
        handle.uid = handle.req.uid
        self._active[handle.uid] = handle
        self.peak_queue_depth = max(self.peak_queue_depth,
                                    eng.scheduler.queue_depth())

    def poll_arrivals(self) -> int:
        """Release every scheduled arrival whose instant has passed."""
        n = 0
        now = self.engine._now()
        while self._arrivals and self._arrivals[0][0] <= now:
            _, _, handle = heapq.heappop(self._arrivals)
            self._submit_handle(handle)
            n += 1
        return n

    def next_arrival(self) -> Optional[float]:
        return self._arrivals[0][0] if self._arrivals else None

    def has_work(self) -> bool:
        eng = self.engine
        return bool(eng.scheduler.has_work() or eng._pending is not None
                    or eng._rejected or self._arrivals)

    # -- the pipelined step ---------------------------------------------
    def step(self) -> List[Request]:
        """One engine step with the host overlap window in the middle.
        Returns the requests that completed/failed this step (their
        handles are flushed and marked done)."""
        self.poll_arrivals()
        out, pending = self.engine.step_async()
        done: List[Request] = list(out) if out else []
        if out is None and pending is None:
            return done
        self._steps += 1
        # -- overlap window: the device owns this step's decode; do the
        # host work that cannot depend on its tokens --------------------
        self.poll_arrivals()               # mid-flight arrivals
        if self._steps % self.stream_interval_steps == 0:
            self._flush_active()           # stream earlier steps' tokens
        # -- sync: block on the tokens, finish the step -----------------
        done.extend(self.engine.finish_step(pending))
        for req in done:
            handle = self._active.pop(req.uid, None)
            if handle is None:
                continue
            self._flush_handle(handle, final=True)
        return done

    def drain(self, max_steps: int = 1_000_000) -> List[Request]:
        """Serve until every submitted AND scheduled request completes;
        idles (advancing a SimClock, or sleeping on the wall clock) when
        the engine is empty but arrivals are still due."""
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.has_work():
                break
            eng = self.engine
            if (not eng.scheduler.has_work() and not eng._rejected
                    and eng._pending is None and self._arrivals):
                self._wait_for_next_arrival()
                self.poll_arrivals()
                continue
            done.extend(self.step())
        return done

    def stream(self, handle: StreamHandle):
        """Generator surface: yield ``(sibling, token)`` for ``handle``
        as tokens are produced, pumping engine steps in between (other
        requests keep being served by the same pump)."""
        while True:
            while handle.buffer:
                yield handle.buffer.popleft()
            if handle.done:
                return
            if not self.has_work():
                return                      # defensive: orphaned handle
            eng = self.engine
            if (not eng.scheduler.has_work() and not eng._rejected
                    and eng._pending is None and self._arrivals):
                self._wait_for_next_arrival()
                self.poll_arrivals()
                continue
            self.step()

    # -- internals -------------------------------------------------------
    def _wait_for_next_arrival(self) -> None:
        nxt = self.next_arrival()
        if nxt is None:
            return
        now = self.engine._now()
        if nxt <= now:
            return
        clk = self.engine._clock
        if clk is not None and hasattr(clk, "advance"):
            clk.advance(nxt - now)         # simulated time: jump
        else:
            time.sleep(min(nxt - now, 0.05))

    def _flush_active(self) -> None:
        for handle in self._active.values():
            self._flush_handle(handle, final=False)

    def _flush_handle(self, handle: StreamHandle, final: bool) -> None:
        streams = handle._streams()
        while len(handle._offsets) < len(streams):
            handle._offsets.append(0)
        delivered: List[Tuple[int, List[int]]] = []
        for s, out in enumerate(streams):
            new = out[handle._offsets[s]:]
            if new:
                handle._offsets[s] = len(out)
                handle.buffer.extend((s, t) for t in new)
                delivered.append((s, list(new)))
        if final:
            handle.done = True
        if handle.on_token is not None:
            for s, toks in delivered:
                handle.on_token(handle, s, toks,
                                final and s == len(streams) - 1)
            if final and not delivered:
                handle.on_token(handle, 0, [], True)


# -- open-loop driver ------------------------------------------------------
@dataclasses.dataclass
class OpenLoopReport:
    """What an open-loop run measured.  All latencies are charged from
    TRUE arrival time; goodput counts only error-free requests."""

    n_requests: int
    completed_ok: int
    failed: int
    wall_s: float
    arrival_rate_req_s: float
    goodput_tok_s: float          # error-free tokens / wall second
    goodput_req_s: float          # error-free completions / wall second
    ttft_ms: Dict[str, float]     # {p50, p95, p99, mean}
    tpot_ms: Dict[str, float]
    neg_latency_samples: int      # must be 0 (TTFT-filter regression)
    midflight_submits: int        # arrivals while work was in flight
    peak_queue_depth: int

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def run_open_loop(engine: Engine,
                  workload: List[Tuple[float, np.ndarray, Dict[str, Any]]],
                  *, stream_interval_steps: int = 1,
                  max_queue_depth: Optional[int] = None,
                  on_token: Optional[Callable] = None
                  ) -> Tuple[List[StreamHandle], OpenLoopReport]:
    """Serve ``workload`` — ``(arrival_offset_s, prompt, submit_kw)``
    triples, offsets relative to the driver's start — open loop, and
    measure it.  Arrivals are released by the engine's own clock (wall
    by default, a SimClock for deterministic tests)."""
    server = AsyncServer(engine,
                         stream_interval_steps=stream_interval_steps,
                         max_queue_depth=max_queue_depth)
    t0 = engine._now()
    handles = [server.schedule_arrival(t0 + dt, prompt,
                                       on_token=on_token, **kw)
               for dt, prompt, kw in workload]
    server.drain()
    wall = max(engine._now() - t0, 1e-9)

    reqs = [h.req for h in handles if h.req is not None]
    ok = [r for r in reqs if r.error is None]
    ok_tokens = sum(sum(len(s) for s in (r.outputs or [r.output or []]))
                    for r in ok)
    offsets = [dt for dt, _, _ in workload]
    span = max(max(offsets), 1e-9) if offsets else 1e-9
    report = OpenLoopReport(
        n_requests=len(workload),
        completed_ok=len(ok),
        failed=len(reqs) - len(ok),
        wall_s=float(wall),
        arrival_rate_req_s=float(len(workload) / span),
        goodput_tok_s=float(ok_tokens / wall),
        goodput_req_s=float(len(ok) / wall),
        ttft_ms=latency_summary_ms(first_token_latencies(reqs)),
        tpot_ms=latency_summary_ms(time_per_output_token(reqs)),
        neg_latency_samples=negative_latency_samples(reqs),
        midflight_submits=server.midflight_submits,
        peak_queue_depth=server.peak_queue_depth)
    return handles, report
