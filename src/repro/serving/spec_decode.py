"""Draft proposers for speculative decoding (serving/engine.py).

Speculation is draft-then-verify: a cheap proposer guesses up to ``k``
next tokens for a running sequence, the engine verifies all of them in
ONE multi-token device step (`models.transformer.verify_chunk_batch` —
the same fused paged chunk-attention path prefill uses), and rejected
tail tokens are rolled back by block-pool truncation
(`BlockAllocator.truncate`).

Correctness never depends on the draft: the acceptance rule re-samples
every position from the *verified* logits with the same per-position
keyed PRNG draws non-speculative decode would have used, so a perfect
proposer only changes how many tokens land per step — never which
tokens.  A proposer therefore has exactly one obligation: return
plausible token ids cheaply.  ``propose`` must be pure w.r.t. the
engine (no allocator or cache access); all sequence state it may use is
the prompt and the accepted output so far.

Two proposers ship:

* :class:`NgramProposer` — prompt-lookup self-speculation (no second
  model): find the most recent earlier occurrence of the sequence's
  current n-gram suffix in its own prompt + output and propose the
  tokens that followed it.  Free, surprisingly effective on repetitive
  or quote-heavy continuations, and the serving default.
* :class:`DraftModelProposer` — a small draft model (e.g. the reduced
  ``llama2_110m`` config) greedily proposes ``k`` tokens behind the
  same interface.  Stateless per call: it re-prefills the full context
  into a dense scratch cache, so it trades host/device work for draft
  quality — meant for real accelerators, not the CPU test rig.
"""

from __future__ import annotations

from typing import List, Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class DraftProposer(Protocol):
    """Anything with ``propose(prompt, output, k) -> list[int]``.

    ``prompt`` is the request's token ids (np.ndarray), ``output`` the
    accepted generated tokens so far (list of int; never includes
    speculative tokens — rollback happens before the proposer sees the
    sequence again).  Return at most ``k`` draft token ids; fewer (or
    none) is always legal and simply shrinks the verify step toward
    plain decode.
    """

    def propose(self, prompt: np.ndarray, output: List[int],
                k: int) -> List[int]:
        ...


class NgramProposer:
    """Prompt-lookup / n-gram self-speculation.

    Match the longest suffix of the context (prompt + output, length
    ``max_n`` down to ``min_n``) against its most recent earlier
    occurrence and propose the continuation that followed that
    occurrence.  No model, no state, O(context · n) per call on the
    host.
    """

    name = "ngram"

    def __init__(self, max_n: int = 3, min_n: int = 1,
                 max_context: int = 1024):
        if not 1 <= min_n <= max_n:
            raise ValueError(f"need 1 <= min_n <= max_n, got "
                             f"({min_n}, {max_n})")
        self.max_n = max_n
        self.min_n = min_n
        self.max_context = max_context

    def propose(self, prompt: np.ndarray, output: List[int],
                k: int) -> List[int]:
        if k <= 0:
            return []
        ctx = np.concatenate([np.asarray(prompt, np.int64),
                              np.asarray(output or [], np.int64)])
        if len(ctx) > self.max_context:
            ctx = ctx[-self.max_context:]
        n_ctx = len(ctx)
        for n in range(min(self.max_n, n_ctx - 1), self.min_n - 1, -1):
            suffix = ctx[n_ctx - n:]
            # most recent earlier occurrence of the suffix (the match
            # must end before the suffix starts so the continuation is
            # a genuinely earlier context)
            for i in range(n_ctx - n - 1, -1, -1):
                if np.array_equal(ctx[i:i + n], suffix):
                    cont = ctx[i + n:i + n + k]
                    if len(cont):
                        return [int(t) for t in cont]
                    break
        return []


class DraftModelProposer:
    """Greedy k-token proposals from a small draft model.

    Holds a `models.model.Model` bundle + params and, per call,
    prefills the full context into a fresh dense cache then rolls
    ``k`` greedy decode steps.  The draft model's vocabulary must match
    the target's (token ids are compared verbatim by the acceptance
    rule).  Stateless across calls — preemption, rollback and fanout
    need no proposer bookkeeping.
    """

    name = "draft_model"

    def __init__(self, model, params, max_seq: int = 2048):
        self.model = model
        self.params = params
        self.max_seq = max_seq

    def propose(self, prompt: np.ndarray, output: List[int],
                k: int) -> List[int]:
        import jax.numpy as jnp

        ctx = np.concatenate([np.asarray(prompt, np.int32),
                              np.asarray(output or [], np.int32)])
        k = min(k, self.max_seq - len(ctx))
        if k <= 0:
            return []
        logits, cache = self.model.prefill(
            self.params, {"tokens": jnp.asarray(ctx)[None]},
            max_seq=len(ctx) + k)
        drafts: List[int] = []
        for _ in range(k):
            tok = int(np.argmax(np.asarray(logits)[0]))
            drafts.append(tok)
            if len(drafts) == k:
                break
            logits, cache = self.model.decode_step(
                self.params, cache, jnp.asarray([tok], jnp.int32))
        return drafts


def build_proposer(kind: str, **kw) -> DraftProposer:
    """Engine-facing factory: ``"ngram"`` (default) or ``"draft_model"``
    (requires ``model=`` and ``params=`` kwargs)."""
    if kind == "ngram":
        return NgramProposer(**kw)
    if kind == "draft_model":
        return DraftModelProposer(**kw)
    raise ValueError(f"unknown draft proposer {kind!r} "
                     "(expected 'ngram' or 'draft_model')")
