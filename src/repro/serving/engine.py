"""Serving engine: executes the Scheduler's step plans over the paged pool.

The paper serves batch-1 on an FPGA; its §5.2 names batched inference as
future work.  This engine is that future work, split into two layers:

  * :class:`~repro.serving.scheduler.Scheduler` (serving/scheduler.py)
    owns *policy*: waiting/running queues, admission (with prompt
    clamping and never-fits rejection), a per-step plan that carries up
    to ``prefill_chunk_tokens`` of prompt chunks **plus** every running
    decode (Sarathi-style chunked prefill — long prompts no longer stall
    the decode loop), and preemption (newest-first eviction with
    recompute-on-resume) when mid-decode growth finds the block pool
    exhausted.
  * :class:`Engine` (this file) owns *mechanism*: it executes each plan
    verbatim — all prompt chunks of a step via ONE padded
    ``model.prefill_chunk_batch`` call writing straight into the paged
    pool (attending the already-written prefix through the page table),
    running decodes as one batched ``decode_step`` — plus sampling, RNG,
    timing and metrics.

KV memory is **paged** by default (vLLM-style, serving/paged_cache.py):
the device cache is a pool of ``page_size``-token blocks shared by every
slot through a page table; the scheduler *leases* blocks to sequences as
their lengths grow (refcounted — a block may back several slots) and
drops the leases on finish *or preemption*, so an oversubscribed pool
(``n_pages`` below the full reservation) degrades to eviction + recompute
instead of raising ``OutOfBlocks``.  On top of the leases sits **automatic
prefix caching**: after executing a chunk or decode the engine registers
every freshly-filled full block into the allocator's hash-chained prefix
index (token-content addressed), admission maps a request's longest
cached prefix read-only into its page table, and the plan's chunks start
past it — the shared prefix runs zero prefill tokens and, because decode
attention already reads through the page table, needs no kernel changes.
The engine also executes the plan's copy-on-write pairs (device block
copies) before any write into a previously-shared block, and runs ALL of
a step's prefill chunks as ONE **shape-stable** batched
``prefill_chunk_batch`` device call: the batch is padded to a fixed
``(max_slots, prefill_chunk_tokens)`` extent and every row's
``(chunk_len, pos_offset)`` rides along as traced data, so the chunk
step compiles once per pool key instead of once per distinct
``(B, chunk_len, pos_offset)`` triple (``metrics["prefill_compiles"]``
and the per-step ``plan_log`` entries expose the count; see
docs/ARCHITECTURE.md for the shape-stability contract).  Families whose
cache is not a single attention bank (ssm / hybrid / audio /
interleaved-moe) fall back to the dense per-slot reservation, where
prompts are admitted as one whole-prompt chunk and preemption/caching
never trigger.

Sampling matches the paper's evaluation setup: temperature 1.0, top-p 1.0
(A.1) — but each request's ``temperature``/``top_p`` are honored, threaded
through one vectorized sampler call per step (no per-slot Python loops).

**Parallel sampling (the ``Request.n_samples`` contract).**  A request
with ``n_samples = n > 1`` is best-of-n: it admits once, prefills its
prompt once, and at the first sampled token fans out into ``n`` sibling
sequences — ``sample_logits_per_row`` draws ``n`` tokens from the ONE
prompt-logits row, then ``Scheduler.fork_group`` leases the parent's
blocks into ``n - 1`` reserved slots (prompt KV shared read-only,
refcounted; diverging tails un-share lazily through copy-on-write).  On
completion ``Request.outputs`` is a list of ``n`` token lists, one per
sibling, and ``Request.output`` aliases ``outputs[0]``; ``t_first_token``
stamps the fanout (all siblings share it) and the request is done when
its last sibling finishes.  ``outputs`` is populated (as ``[output]``)
for ``n_samples=1`` requests too.  Requires the paged pool —
``cache_kind="dense"`` rejects ``n > 1`` with ``.error``.

**Sampling streams.**  Every request owns a PRNG root: ``PRNGKey(seed)``
when ``Request.seed`` is set, else split off the engine key at submit.
Sibling ``i`` samples from the stream ``fold_in(root, stream + i)``
(``Request.stream`` defaults to 0), and its ``t``-th token uses
``fold_in(stream_key, t)`` — so a sibling's draw depends only on (root,
stream index, position), never on batch composition or scheduling order.
That is what makes fanout *bit-exact*: sibling ``i`` of an
``(seed=s, n_samples=n)`` request produces the identical token stream to
an independent ``(seed=s, stream=i, n_samples=1)`` request — proved in
tests/test_prefix_cache.py, exploited by ``Request.stream`` to shard one
logical best-of-n across engines.  Per-sibling ``stop_tokens`` (on top
of the global ``eos_id``) let siblings in one group retire on different
ids.

**Speculative decoding (``spec_tokens > 0``).**  Draft-then-verify on
the paged pool: a host-side proposer (serving/spec_decode.py — n-gram
prompt-lookup by default, a small draft model behind the same
``propose()`` interface) guesses up to ``spec_tokens`` next tokens per
running sequence, and the scheduler plans a :class:`SpecVerify` instead
of that slot's decode.  The engine verifies ALL drafts in one device
call by treating them as a k+1-token *chunk* — ``verify_chunk_batch``
is the all-positions-logits twin of ``prefill_chunk_batch``, padded to
a fixed ``(max_slots, spec_tokens + 1)`` extent, so it reuses the fused
paged chunk-attention kernel and holds its own one-executable-per-pool-
key bound (``metrics["verify_compiles"]``).  Acceptance re-samples
every position from the *verified* logits with the exact per-position
key non-speculative decode would have used (``fold_in(stream_key, t)``
for output position ``t``), so greedy speculative streams are
bit-identical to non-speculative streams and sampled streams stay
composition-independent however many drafts land; drafts only decide
how many tokens commit per step, never which.  Rejection rollback is
**block-pool truncation**: ``BlockAllocator.truncate`` shrinks the
slot's lease to the accepted length through the normal release path,
and since the engine registers prefix-index blocks only *after*
acceptance, speculative KV is never reachable from the prefix index.
``metrics`` reports ``draft_tokens`` / ``accepted_tokens`` /
``accept_ratio`` / ``steps_per_token`` (per-sequence device steps per
emitted token: 1.0 = plain decode, < 1.0 = speculation paying off).

Knobs: ``prefill_chunk_tokens`` bounds prompt work per step (the
prefill/decode interleaving grain); ``page_size``/``n_pages`` size the
pool; ``prefix_caching`` toggles the block index (on by default);
``spec_tokens``/``draft_proposer`` turn on speculative decoding;
``preempt_limit`` is the scheduler's starvation bound.  ``Engine.plan_log``
keeps the executed step plans (uids, chunk ranges, preemptions, COW
pairs, cached-prefix admissions, fanouts) for inspection — tests assert
chunk/decode interleaving and prefix skips on it, and
benchmarks/engine_bench.py reports preemption counts, prefix-cache hit
rates and fork-sharing block savings from it.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.roofline import step_joules, tree_bytes
from repro.models.model import Model, count_params
from repro.runtime.health import StragglerDetector
from repro.serving.faults import (ERR_AUDIT, ERR_DEADLINE, ERR_FAULT,
                                  ERR_NAN, ERR_SHED, SITE_DECODE,
                                  SITE_PREFILL, FaultInjector,
                                  InjectedFault, SchedulerStall)
from repro.serving.paged_cache import (BlockAllocator, PagedConfig,
                                       chain_hash)
from repro.serving.scheduler import (PrefillChunk, Scheduler, SpecVerify,
                                     StepPlan, validate_request)
from repro.serving.spec_decode import build_proposer


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 64
    temperature: float = 1.0
    top_p: float = 1.0
    n_samples: int = 1            # best-of-n: fork n siblings at token 1
    seed: Optional[int] = None    # PRNG root (None: engine-assigned)
    stream: int = 0               # sampling-stream offset (sibling i
    #                               draws stream ``stream + i``)
    stop_tokens: Optional[Sequence[int]] = None  # per-request stop ids
    #                               honored alongside the global eos_id
    deadline_ms: Optional[float] = None       # total budget since submit;
    #                               the watchdog fails the request (typed
    #                               .error) when it expires mid-flight
    ttft_deadline_ms: Optional[float] = None  # first-token budget
    # filled by the engine:
    output: Optional[List[int]] = None           # == outputs[0]
    outputs: Optional[List[List[int]]] = None    # one stream per sibling
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    error: Optional[str] = None   # set when the engine rejects the request
    error_kind: Optional[str] = None  # typed failure domain (faults.ERR_*)
    rng_key: Any = None           # PRNG root (derived from seed / engine)


def sample_logits(key, logits: jax.Array, temperature=1.0,
                  top_p=1.0) -> jax.Array:
    """Temperature + nucleus sampling; (B, V) -> (B,) int32.

    ``temperature``/``top_p`` may be scalars or per-row (B,) arrays — the
    engine passes one array per batch so heterogeneous requests sample
    correctly in a single vectorized call.  ``temperature <= 0`` rows are
    greedy (argmax)."""
    b = logits.shape[0]
    t = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (b,))
    p = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (b,))
    p = jnp.maximum(p, 1e-6)                   # keep at least the top token

    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(t, 1e-6)[:, None]
    sorted_logits = jnp.sort(scaled, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    csum = jnp.cumsum(probs, axis=-1)
    # smallest k with cumulative prob >= top_p, per row
    keep = csum - probs < p[:, None]
    thresh = jnp.min(jnp.where(keep, sorted_logits, jnp.inf), axis=-1,
                     keepdims=True)
    masked = jnp.where(scaled >= thresh, scaled, -jnp.inf)
    sampled = jax.random.categorical(key, masked, axis=-1).astype(jnp.int32)
    return jnp.where(t <= 0.0, greedy, sampled)


def sample_logits_per_row(keys, logits: jax.Array, temperature=1.0,
                          top_p=1.0) -> jax.Array:
    """Per-row *keyed* temperature + nucleus sampling.

    ``keys`` is a stacked (B, key) array — one PRNG key per row — and
    row ``i``'s draw depends only on ``(keys[i], logits[i],
    temperature[i], top_p[i])``.  That row-independence is the engine's
    bit-exactness lever: a sequence's sampled stream is identical
    whether its row is batched with 0 or B-1 others, so a fork sibling
    replays exactly as an independent request and a preempted sequence
    resumes its stream unchanged.  (``sample_logits`` above draws the
    whole batch from ONE key, which ties each row's outcome to the batch
    composition.)"""
    b = logits.shape[0]
    t = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (b,))
    p = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (b,))
    one = lambda k, l, tt, pp: sample_logits(k, l[None], tt, pp)[0]
    return jax.vmap(one)(keys, logits, t, p)


def legacy_chunk_shape_keys(plan_log) -> set:
    """The ``(B, chunk_len, pos_offset)`` compile keys a per-shape-grouped
    engine would have used for the chunks in ``plan_log`` — the
    counterfactual cost that shape-stable padding avoids.  Consumed by
    the shape_churn benchmark (CI gates on it being larger than the real
    compile count) and tests/test_compile_stability.py."""
    keys = set()
    for plan in plan_log:
        groups: Dict[Any, int] = {}
        for (_, s, e) in plan.get("prefills", []):
            groups[(e - s, s)] = groups.get((e - s, s), 0) + 1
        keys |= {(n, ln, off) for (ln, off), n in groups.items()}
    return keys


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_pool_blocks(attn, src, dst):
    """Copy whole pool blocks src -> dst across every layer (and scale
    pools for int8) — the device half of copy-on-write.  Buffers are
    (L, NB, BS, ...); donation keeps it in place."""
    return {kk: buf.at[:, dst].set(buf[:, src]) for kk, buf in attn.items()}


@dataclasses.dataclass
class _PendingDecode:
    """A dispatched-but-unsynced batched decode step.  ``nxt`` and
    ``finite`` are still device arrays (JAX async dispatch): the host
    has NOT blocked on the sampled tokens yet.  ``_decode_complete``
    converts them and runs all token-dependent bookkeeping."""

    slots: List[int]
    failed: List["Request"]
    nxt: Any = None                 # device array of sampled tokens
    finite: Any = None              # device array, per-row finiteness
    t0: float = 0.0


@dataclasses.dataclass
class _PendingStep:
    """An engine step whose decode host-sync was deferred by
    :meth:`Engine.step_async`.  Everything token-independent (plan,
    prefill chunks, COW copies, device dispatch of decode + sampling)
    already ran; :meth:`Engine.finish_step` blocks on the tokens and
    finishes the step's bookkeeping."""

    decode: _PendingDecode
    plan: Any
    t_step: float


class Engine:
    """Single-host continuous-batching engine (plan executor).

    ``decode_fn(params, cache, tokens) -> (logits, cache)`` and
    ``prefill_fn(params, batch, max_seq) -> (logits, cache)`` come from
    the (possibly jitted/sharded) model; the engine itself is pure
    orchestration and identical whether the steps run on 1 CPU or a pod.

    ``cache_kind="paged"`` (default) serves from the block pool when the
    model family supports it; ``"dense"`` forces the contiguous per-slot
    reservation.  ``n_pages`` sizes the pool (default: full reservation);
    shrinking it oversubscribes, which the scheduler absorbs by deferring
    admission and preempting on mid-decode growth.  Requests that could
    never run (prompt larger than the whole pool, ``max_new_tokens >=
    max_seq``, empty prompt) come back from :meth:`run` with ``.error``
    set instead of raising or spinning.
    """

    def __init__(self, model: Model, params: Any, max_slots: int = 8,
                 max_seq: int = 1024, eos_id: int = 2, seed: int = 0,
                 cache_kind: str = "paged", page_size: int = 64,
                 n_pages: Optional[int] = None,
                 prefill_chunk_tokens: int = 512,
                 prefix_caching: bool = True, preempt_limit: int = 3,
                 faults: Any = None, clock: Any = None,
                 nan_guard: bool = True, retry_limit: int = 2,
                 audit_interval: int = 0,
                 shed_after_preempts: Optional[int] = None,
                 stall_shed_limit: int = 3,
                 spec_tokens: int = 0, draft_proposer: Any = None,
                 mesh: Any = None):
        self.model = model
        self.params = params
        # -- tensor-parallel serving (mesh=None = single-device path) -----
        # Storage-sharded / compute-replicated: the paged pool shards its
        # KV-heads dim and weights are stored sharded, but every
        # cross-device collective the scheme induces is an all-gather, so
        # streams stay bit-identical to the unsharded engine.  The
        # allocator/scheduler never see the mesh — block ids, page
        # tables, leases and StepPlans are device-count-agnostic.
        self.mesh = mesh
        self._rep = None
        if mesh is not None:
            if cache_kind != "paged":
                raise ValueError("mesh serving requires the paged cache")
            self._rep = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.prefill_chunk_tokens = prefill_chunk_tokens
        # -- speculative decoding (module docstring) ----------------------
        # draft_proposer: None/str -> built by name ("ngram" default);
        # anything with .propose(prompt, output, k) is used as-is
        self.spec_tokens = spec_tokens
        if spec_tokens > 0 and (draft_proposer is None
                                or isinstance(draft_proposer, str)):
            draft_proposer = build_proposer(draft_proposer or "ngram")
        self.draft_proposer = draft_proposer
        # -- fault domain (serving/faults.py) ----------------------------
        # clock: None = wall time; else a callable or .now() object (a
        # SimClock makes deadlines and latency faults deterministic)
        if clock is None:
            self._now = time.perf_counter
        elif hasattr(clock, "now"):
            self._now = clock.now
        else:
            self._now = clock
        self._clock = clock
        if faults is not None and not isinstance(faults, FaultInjector):
            faults = FaultInjector(faults)     # accept a bare FaultPlan
        self.faults: Optional[FaultInjector] = faults
        self.nan_guard = nan_guard
        self.retry_limit = retry_limit         # pre-dispatch retries/step
        self.audit_interval = audit_interval   # 0 = no periodic audit
        self.shed_after_preempts = shed_after_preempts
        self.stall_shed_limit = stall_shed_limit
        self.fault_log: List[Dict[str, Any]] = []
        self.straggler = StragglerDetector(n_hosts=1)
        # decode is the hot loop: jit once (cache/params structures are
        # stable).  Donating the cache avoids a copy per token.
        if mesh is not None:
            self._decode = jax.jit(
                functools.partial(model.decode_step, mesh=mesh),
                donate_argnums=(1,))
        else:
            self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self.key = jax.random.PRNGKey(seed)

        self.paged = (cache_kind == "paged"
                      and model.init_paged_cache is not None)
        self.pager: Optional[BlockAllocator] = None
        if self.paged:
            self.page_size = page_size
            mb = -(-max_seq // page_size)
            self.n_pages = n_pages or max_slots * mb
            self.pager = BlockAllocator(PagedConfig(
                n_layers=model.cfg.n_layers,
                n_kv_heads=model.cfg.n_kv_heads, head_dim=model.cfg.hd(),
                block_size=page_size, n_blocks=self.n_pages,
                max_slots=max_slots, max_blocks_per_seq=mb),
                enable_prefix_cache=prefix_caching)
            self.cache = model.init_paged_cache(
                max_slots, block_size=page_size, n_blocks=self.n_pages,
                max_blocks_per_seq=mb)
            if mesh is not None:
                # place the pool sharded (KV-heads over `model`) and the
                # host-authored control state replicated; params follow
                # the serve-mode specs.  Only placement changes — the
                # allocator addresses block ids exactly as before.
                from repro.distribution import sharding as shardlib
                cspecs = shardlib.cache_specs(model.cfg, self.cache, mesh)
                self.cache = jax.device_put(
                    self.cache, shardlib.to_shardings(cspecs, mesh))
                if mesh.shape.get("model", 1) <= 1:
                    # a size-1 `model` axis divides everything, so the
                    # serve specs would keep their axis names — and
                    # GSPMD propagates those (physically replicated but
                    # named) annotations from the weights onto jit
                    # outputs like the int8 scale pools, where they
                    # mismatch the replicated placement above and buy a
                    # second executable per pool key.  Replicate.
                    self.params = jax.device_put(params, self._rep)
                else:
                    pspecs = shardlib.param_specs(model.cfg, params, mesh,
                                                  mode="serve")
                    self.params = jax.device_put(
                        params, shardlib.to_shardings(pspecs, mesh))
        else:
            self.cache = model.init_cache(max_slots, max_seq)
        self.scheduler = Scheduler(
            max_slots=max_slots, max_seq=max_seq, pager=self.pager,
            prefill_chunk_tokens=prefill_chunk_tokens,
            preempt_limit=preempt_limit, spec_tokens=spec_tokens,
            draft_proposer=self.draft_proposer)
        # -- roofline energy model (launch/roofline.step_joules) ----------
        # every device call streams the weights once plus the KV rows it
        # touches; KV traffic is modeled for the paged pool only (dense
        # families fall back to weight streaming, which dominates anyway)
        self._param_bytes = float(tree_bytes(params))
        self._n_params = float(count_params(params))
        if self.paged:
            per_pos = (2 * model.cfg.n_kv_heads * model.cfg.hd()
                       * self.cache["attn"]["k"].dtype.itemsize)
            if "ks" in self.cache["attn"]:
                per_pos += 2 * model.cfg.n_kv_heads * 4   # dequant scales
            self._kv_row_bytes = per_pos * model.cfg.n_layers
        else:
            self._kv_row_bytes = 0
        self.plan_log: List[Dict[str, Any]] = []
        self.metrics = {"tokens_out": 0, "requests_done": 0,
                        "decode_steps": 0, "t_decode": 0.0,
                        "prefill_chunks": 0, "preemptions": 0,
                        "chunk_batch_calls": 0, "cow_copies": 0,
                        "prefix_hits": 0, "prefix_cached_tokens": 0,
                        "prefix_evictions": 0, "fanouts": 0,
                        "blocks_live_peak": 0,
                        "blocks_saved_by_sharing_peak": 0,
                        "prefill_compiles": 0,
                        # roofline accounting: prefix K/V bytes the
                        # chunk-attention step reads — live tiles through
                        # the page table vs the legacy full-extent gather
                        "prefix_attn_bytes": 0,
                        "prefix_attn_bytes_gather": 0,
                        # speculative decoding: drafts proposed/accepted,
                        # verify device calls + their compile bound, and
                        # rejection rollbacks (block-pool truncations).
                        # seq_steps counts per-SEQUENCE device steps, so
                        # steps_per_token = seq_steps / tokens_out is
                        # exactly 1.0 for plain decode and dips below it
                        # only when verification lands >1 token per step
                        "draft_tokens": 0, "accepted_tokens": 0,
                        "verify_steps": 0, "spec_rollbacks": 0,
                        "verify_compiles": 0, "seq_steps": 0,
                        "accept_ratio": 0.0, "steps_per_token": 0.0,
                        # modeled energy (roofline.step_joules) + per-
                        # request prefix-cache attribution
                        # (uid -> {cached_tokens, cache_hit})
                        "energy_joules": 0.0,
                        "requests": {},
                        # fault-domain counters
                        "step_retries": 0, "requests_failed": 0,
                        "requests_rejected": 0, "nan_rows": 0,
                        "deadline_misses": 0, "shed_requests": 0,
                        "stalls": 0, "audit_repairs": 0,
                        "audit_violations": 0, "slow_steps": 0}
        self._host_pt: Optional[np.ndarray] = None
        self._done_at_prefill: List[Request] = []  # first-token stops
        self._uid = 0
        self._step = 0                     # monotonic step index (faults
        #                                    key their schedules on it)
        self._rejected: List[Request] = [] # submit-time rejections, drained
        #                                    into run()'s done list
        self._pending: Optional[_PendingStep] = None  # step_async() in
        #                                    flight, awaiting finish_step()
        self._stall_streak = 0
        self._preempt_streak = 0
        if self.faults is not None:
            self.faults.bind(clock=self._clock, pager=self.pager)

    def _put(self, x, dtype=None):
        """Host -> device upload for step operands (tokens, lens, page
        tables, COW indices).  Under a mesh these must be *committed*
        replicated arrays — an uncommitted ``jnp.asarray`` upload would
        leave placement to jit and wobble the compile key; replication
        matches the engine's host-authored-control-state contract."""
        arr = np.asarray(x, dtype)
        if self.mesh is None:
            return jnp.asarray(arr)
        return jax.device_put(arr, self._rep)

    # -- public API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, **kw) -> int:
        """Enqueue a request; returns its uid.  Malformed requests
        (empty prompt, ``max_new_tokens`` that leaves no prompt room,
        ``n_samples < 1``, groups wider than the slot table or on the
        dense cache, prompts that could never fit the pool) get
        ``.error`` set here and come back from the next :meth:`run`
        without ever entering the scheduler; admission re-checks as the
        run-time backstop.

        Legal at ANY time, including between :meth:`step_async` and
        :meth:`finish_step` while a device step is in flight — the
        arrival enters the waiting queue and is considered at the next
        ``schedule()``.  Pass ``t_enqueue`` to stamp the request's TRUE
        arrival time (open-loop serving releases arrivals between
        steps, possibly after their scheduled instant; queueing delay
        and deadlines must be charged from arrival, not release)."""
        self._uid += 1
        t_enq = kw.pop("t_enqueue", None)
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                      t_enqueue=self._now() if t_enq is None else t_enq,
                      output=[], **kw)
        if req.seed is not None:
            req.rng_key = jax.random.PRNGKey(req.seed)
        else:
            self.key, req.rng_key = jax.random.split(self.key)
        err = validate_request(req, self.max_seq, self.max_slots,
                               self.pager)
        if err is not None:
            req.error, req.error_kind = err
            self._rejected.append(req)
            return req.uid
        self.scheduler.add(req)
        return req.uid

    def submit_request(self, prompt: np.ndarray, **kw) -> Request:
        """:meth:`submit`, but returning the :class:`Request` object
        itself.  The async front-end holds it to stream ``output`` /
        ``outputs`` deltas per step while the request is mid-flight."""
        uid = self.submit(prompt, **kw)
        if self._rejected and self._rejected[-1].uid == uid:
            return self._rejected[-1]
        req = self.scheduler.request(uid)
        assert req is not None, f"submitted uid {uid} vanished"
        return req

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Serve until the scheduler drains.  Rejected requests (clamped
        ``max_new_tokens``, empty prompt, or a sequence the pool could
        never hold) come back in the done list with ``.error`` set — as
        do requests failed mid-flight by the fault layer (persistent
        step faults, NaN rows, deadline expiry, audit quarantine, load
        shedding), each with a typed ``.error_kind`` while the rest of
        the batch keeps serving.

        ``run()`` is the closed-loop surface: a plain loop over
        :meth:`step`.  Continuous-arrival serving drives
        :meth:`step_async` / :meth:`finish_step` instead (see
        serving/async_serving.py)."""
        done: List[Request] = []
        for _ in range(max_steps):
            out = self.step()
            if out is None:
                break
            done.extend(out)
        return done

    def step(self) -> Optional[List[Request]]:
        """Execute ONE scheduler step synchronously: drain submit-time
        rejections, schedule, run the planned chunk/decode/verify work,
        and return the requests that completed or failed during the
        step (possibly an empty list).  Returns ``None`` when the
        engine is idle — no queued rejections and no scheduler work."""
        done, pending = self._step_impl(sync=True)
        assert pending is None
        return done

    def step_async(self):
        """Like :meth:`step`, but WITHOUT blocking on the decode's
        sampled tokens: returns ``(done, pending)`` where ``pending``
        (when not None) holds the dispatched-but-unsynced device work.
        JAX async dispatch means the device is computing the decode and
        the per-row sampling while the host is free — the front-end
        uses that window to ingest new arrivals and flush streamed
        tokens from earlier steps, then calls :meth:`finish_step` to
        block on the tokens and finish the token-dependent bookkeeping
        (append, block registration, stop detection).  Steps whose host
        effects are token-coupled within the step (speculative
        verifies, the fault layer's intra-step isolation) run fully
        synchronously and return ``pending=None``."""
        return self._step_impl(sync=False)

    def finish_step(self, pending: Optional[_PendingStep] = None
                    ) -> List[Request]:
        """Complete a :meth:`step_async` step: block on the sampled
        tokens, append them, register completed blocks, retire stops,
        and close out the step's accounting.  No-op (returns ``[]``)
        when nothing is pending."""
        if pending is None:
            pending = self._pending
        if pending is None:
            return []
        self._pending = None
        done = self._decode_complete(pending.decode)
        self._step_tail(pending.plan, pending.t_step)
        return done

    def _step_impl(self, sync: bool):
        """One scheduler step.  Returns ``(done, pending)``;
        ``done is None`` means the engine was idle.  ``sync=False``
        defers the decode host-sync into ``pending`` when the step
        allows it (see :meth:`step_async`)."""
        if self._pending is not None:
            raise RuntimeError(
                "finish_step() must complete the in-flight step before "
                "the next one is dispatched")
        done: List[Request] = []
        if self._rejected:
            now = self._now()
            for req in self._rejected:
                req.t_done = now
                self.metrics["requests_rejected"] += 1
                done.append(req)
            self._rejected = []
        if not self.scheduler.has_work():
            return (done if done else None), None
        self._step += 1
        stalled = (self.faults is not None
                   and self.faults.pre_step(self._step, self.scheduler))
        if (self.paged and self.audit_interval
                and self._step % self.audit_interval == 0):
            # BEFORE schedule(): a corrupted block must be caught and
            # quarantined before the allocator can hand it out again
            done.extend(self._run_audit())
            if not self.scheduler.has_work():
                return done, None
        # an injected stall skips scheduling — the engine sees the
        # idle plan a wedged scheduler would have produced
        plan = StepPlan() if stalled else self.scheduler.schedule()
        now = self._now()
        for req in plan.rejected:
            req.t_done = now
            self.metrics["requests_rejected"] += 1
            done.append(req)
        expired = self._enforce_deadlines(plan)
        done.extend(expired)
        if not plan.made_progress() and not expired:
            # the scheduler's contract is defer-preempt-or-reject; an
            # idle plan with work pending means that contract broke.
            # Fault layer on: degrade (shed the lowest-value waiter,
            # keep serving) — off: raise the typed stall with the
            # queue snapshot (the seed engine spun here).
            done.extend(self._handle_stall(stalled))
            return done, None
        self._stall_streak = 0
        if plan.preempted and self.shed_after_preempts is not None:
            self._preempt_streak += 1
            if self._preempt_streak >= self.shed_after_preempts:
                # preemption thrash: repeated evict/recompute cycles
                # mean demand exceeds the pool — shed load instead
                done.extend(self._shed(
                    f"{self._preempt_streak} consecutive preempting "
                    "steps (thrash)"))
                self._preempt_streak = 0
        elif not plan.preempted:
            self._preempt_streak = 0
        self.plan_log.append(plan.summary())
        for uid, cached in plan.admitted:
            # first admission wins: a preempt-resume re-admission must
            # not overwrite the request's original cache attribution
            self.metrics["requests"].setdefault(
                uid, {"cached_tokens": int(cached),
                      "cache_hit": cached > 0})
        self.metrics["preemptions"] = self.scheduler.n_preempted
        self.metrics["prefix_hits"] = \
            self.scheduler.prefix_stats["hits"]
        self.metrics["prefix_cached_tokens"] = \
            self.scheduler.prefix_stats["cached_tokens"]
        if self.paged:
            self.metrics["prefix_evictions"] = \
                self.pager.stats["evictions"]
        if self.paged and plan.has_work():
            # one republish per step covers this step's allocations,
            # COW remaps, and any releases (finish/preempt) since the
            # last one; the host copy is kept for chunk addressing so
            # the batched calls never read the table back off-device.
            self._host_pt = self.pager.page_table()
            self.cache["page_table"] = self._put(self._host_pt)
        if self.paged and plan.cows:
            # copy-on-write: duplicate the shared blocks' rows before
            # this step's writes land in the fresh copies.  (Counted
            # here, not from allocator stats — a retracted victim's
            # pair never reaches execution.)
            src = self._put([s for s, _ in plan.cows], np.int32)
            dst = self._put([d for _, d in plan.cows], np.int32)
            self.cache["attn"] = _copy_pool_blocks(
                self.cache["attn"], src, dst)
            self.metrics["cow_copies"] += len(plan.cows)
        t_step = self._now()
        if plan.prefills:
            done.extend(self._run_chunks(plan.prefills))
            # shape-stability probe: the chunk step's distinct-XLA-
            # executable count must stay pinned at one per pool key
            # however traffic churns chunk lengths / offsets / batch
            # width (gated by tests + the shape_churn benchmark)
            self.metrics["prefill_compiles"] = \
                self.prefill_compile_count()
            self.plan_log[-1]["prefill_compiles"] = \
                self.metrics["prefill_compiles"]
        if self._done_at_prefill:
            # sequences whose FIRST sampled token was terminal (stop
            # id / eos / max_new_tokens=1) retired inside the chunk
            done.extend(self._done_at_prefill)
            self._done_at_prefill = []
        if plan.decodes:
            if sync or plan.verifies or self.faults is not None:
                done.extend(self._decode_once(plan.decodes))
            else:
                # pipelined: decode + sampling are dispatched (device
                # busy), the host returns WITHOUT blocking on tokens.
                # Verify steps are excluded — their truncate/register
                # ordering is token-coupled within the step — as is the
                # fault layer, whose intra-step isolation hooks must
                # observe each row's outcome before the step closes.
                self._pending = _PendingStep(
                    decode=self._decode_dispatch(plan.decodes),
                    plan=plan, t_step=t_step)
                return done, self._pending
        if plan.verifies:
            # AFTER decodes: a verify's truncation frees blocks that
            # only re-enter circulation at the next schedule(), so
            # nothing executed this step can observe the rollback
            done.extend(self._run_verifies(plan.verifies))
            self.metrics["verify_compiles"] = \
                self.verify_compile_count()
            self.plan_log[-1]["verify_compiles"] = \
                self.metrics["verify_compiles"]
        self._step_tail(plan, t_step)
        return done, None

    def _step_tail(self, plan: StepPlan, t_step: float) -> None:
        """Per-step accounting that must run after the step's tokens
        have landed (spec ratios read ``tokens_out``; sharing peaks
        read post-release refcounts)."""
        drafted = self.metrics["draft_tokens"]
        self.metrics["accept_ratio"] = (
            self.metrics["accepted_tokens"] / drafted if drafted
            else 0.0)
        self.metrics["steps_per_token"] = (
            self.metrics["seq_steps"]
            / max(1, self.metrics["tokens_out"]))
        if plan.has_work() and self.straggler.record_slow(
                0, self._now() - t_step):
            self.metrics["slow_steps"] += 1
        if self.paged:
            # fork-sharing accounting: each lease beyond a block's
            # first is a block NOT copied (shared prompt KV)
            live = shared = 0
            for rc in self.pager.refcount:
                if rc > 0:
                    live += 1
                    shared += rc - 1
            self.metrics["blocks_live_peak"] = max(
                self.metrics["blocks_live_peak"], live)
            self.metrics["blocks_saved_by_sharing_peak"] = max(
                self.metrics["blocks_saved_by_sharing_peak"], shared)

    def cache_utilization(self) -> float:
        """Fraction of the KV pool in use (slots-occupied for dense)."""
        if self.paged:
            return self.pager.utilization()
        return len(self.scheduler.running) / self.max_slots

    def throughput_tok_s(self) -> float:
        """DECODE-ONLY throughput: ``tokens_out / t_decode``, where
        ``t_decode`` is wall time inside the batched decode step
        (dispatch to token sync) and excludes prefill, scheduling, and
        host bookkeeping.  This is the figure BENCH_engine.json records
        as ``decode_tok_s`` and the CI gates compare; end-to-end
        tokens-per-wall-second is always lower and must be computed by
        the caller (the `[serve]` banner prints both, labeled)."""
        t = self.metrics["t_decode"]
        return self.metrics["tokens_out"] / t if t > 0 else 0.0

    def prefill_compile_count(self) -> int:
        """Distinct XLA compiles of the chunked-prefill step so far in
        this process (shared across engines with the same model config —
        that sharing is the point: one pool key, one executable)."""
        if self.model.prefill_compile_count is None:
            return 0
        return self.model.prefill_compile_count(mesh=self.mesh)

    def verify_compile_count(self) -> int:
        """Distinct XLA compiles of the speculative verify step (the
        all-positions-logits chunk entry) — same one-per-pool-key bar
        as the prefill chunk, probed separately because the two entries
        are distinct executables."""
        if self.model.verify_compile_count is None:
            return 0
        return self.model.verify_compile_count(mesh=self.mesh)

    # -- fault domain ---------------------------------------------------
    def _fail_request(self, req: Request, msg: str, kind: str,
                      plan: Any = None, quarantine: bool = False
                      ) -> Request:
        """Fail ONE request (its whole sampling group) while the rest of
        the batch keeps serving: quarantine its self-written KV blocks
        when their content is suspect (NaN), retract anything it still
        has planned, release every lease, stamp the typed error."""
        if self.paged and quarantine:
            bs = self.page_size
            for slot, seq in list(self.scheduler.running.items()):
                if seq.req is req:
                    self.pager.quarantine(slot, seq.cached_len // bs)
        self.scheduler.fail_request(req, plan)
        req.error = msg
        req.error_kind = kind
        req.t_done = self._now()
        self.metrics["requests_failed"] += 1
        return req

    def _survive_faults(self, site: str, items: List[Any], uid_of,
                        alive) -> tuple:
        """Pre-dispatch fault gate for one device batch.  Injected step
        exceptions fire *before* the (donating) device call, so a retry
        is always clean; a fault that persists past ``retry_limit``
        isolates its target request (``.error`` set, leases released)
        and the surviving rows dispatch without it.  Returns (surviving
        items, failed requests)."""
        failed: List[Request] = []
        attempts = 0
        while items:
            try:
                self.faults.raise_if_armed(
                    site, self._step, [uid_of(x) for x in items])
                break
            except InjectedFault as exc:
                attempts += 1
                self.metrics["step_retries"] += 1
                self.fault_log.append(
                    {"step": self._step, "kind": "retry", "site": site,
                     "uid": exc.uid, "attempt": attempts})
                if attempts <= self.retry_limit:
                    continue
                if exc.uid is None:
                    raise      # untargeted persistent fault: device loss,
                    #            nothing to isolate — propagate
                req = next(s.req for s in
                           self.scheduler.running.values()
                           if s.req.uid == exc.uid)
                failed.append(self._fail_request(
                    req, f"persistent {site}-step fault "
                         f"({attempts} attempts)", ERR_FAULT))
                self.fault_log.append(
                    {"step": self._step, "kind": "isolated", "site": site,
                     "uid": exc.uid, "attempts": attempts})
                items = [x for x in items if alive(x)]
                attempts = 0
        return items, failed

    def _enforce_deadlines(self, plan: Any) -> List[Request]:
        """The per-step watchdog: fail every in-flight request past its
        TTFT or total deadline (work it had planned this step retracts;
        survivors' streams are unaffected — their sampling is per-row
        keyed)."""
        failed: List[Request] = []
        now = self._now()
        reqs: Dict[int, Request] = {}
        for seq in list(self.scheduler.running.values()) \
                + list(self.scheduler.waiting):
            reqs.setdefault(seq.req.uid, seq.req)
        for req in reqs.values():
            if req.error is not None:
                continue
            age_ms = (now - req.t_enqueue) * 1e3
            if (req.ttft_deadline_ms is not None
                    and req.t_first_token == 0.0
                    and age_ms > req.ttft_deadline_ms):
                which, budget = "ttft", req.ttft_deadline_ms
            elif req.deadline_ms is not None and age_ms > req.deadline_ms:
                which, budget = "total", req.deadline_ms
            else:
                continue
            self.metrics["deadline_misses"] += 1
            self.fault_log.append({"step": self._step, "kind": "deadline",
                                   "uid": req.uid, "budget": which})
            failed.append(self._fail_request(
                req, f"{which} deadline of {budget:g} ms exceeded "
                     f"({age_ms:.1f} ms since submit)", ERR_DEADLINE,
                plan=plan))
        return failed

    def _shed(self, reason: str) -> List[Request]:
        """Admission-reject the lowest-value waiter (typed .error)."""
        shed: List[Request] = []
        for req in self.scheduler.shed_load(1):
            req.error = f"load shed: {reason}"
            req.error_kind = ERR_SHED
            req.t_done = self._now()
            self.metrics["shed_requests"] += 1
            self.metrics["requests_failed"] += 1
            self.fault_log.append({"step": self._step, "kind": "shed",
                                   "uid": req.uid})
            shed.append(req)
        return shed

    def _handle_stall(self, injected: bool) -> List[Request]:
        """An idle plan with work pending.  Fault layer off: raise the
        typed :class:`SchedulerStall` (contract violation).  On: shed
        the lowest-value waiter and keep serving — bounded by
        ``stall_shed_limit`` consecutive stalls with nothing sheddable,
        after which the stall is genuine wedge and raises anyway."""
        self.metrics["stalls"] += 1
        self._stall_streak += 1
        waiting, running = (len(self.scheduler.waiting),
                            len(self.scheduler.running))
        snapshot = {
            "step": self._step, "injected": injected,
            "waiting": [s.req.uid for s in self.scheduler.waiting],
            "running": {slot: seq.req.uid for slot, seq
                        in sorted(self.scheduler.running.items())}}
        if self.faults is None:
            raise SchedulerStall(
                "scheduler made no progress with work pending "
                f"(waiting={waiting}, running={running})", snapshot)
        shed = self._shed("scheduler stall with work pending")
        self.fault_log.append({"step": self._step, "kind": "stall",
                               "injected": injected,
                               "shed": [r.uid for r in shed]})
        if not shed and self._stall_streak > self.stall_shed_limit:
            raise SchedulerStall(
                f"scheduler stalled {self._stall_streak} consecutive "
                f"steps with nothing left to shed (waiting={waiting}, "
                f"running={running})", snapshot)
        return shed

    def _run_audit(self) -> List[Request]:
        """Periodic allocator self-audit (every ``audit_interval``
        steps, before scheduling).  A dirty report repairs in place —
        corrupted blocks quarantined, free list/LRU/refcounts rebuilt —
        and fails exactly the requests leasing corrupted blocks; the
        pool is coherent again before any new block is handed out."""
        report = self.pager.audit(repair=True)
        if report.clean:
            return []
        self.metrics["audit_repairs"] += 1
        self.metrics["audit_violations"] += len(report.violations)
        victims: Dict[int, Request] = {}
        for slot in report.victim_slots:
            seq = self.scheduler.running.get(slot)
            if seq is not None:
                victims.setdefault(seq.req.uid, seq.req)
        self.fault_log.append(
            {"step": self._step, "kind": "audit",
             "violations": list(report.violations),
             "corrupted_blocks": list(report.corrupted_blocks),
             "victims": sorted(victims)})
        return [self._fail_request(
                    req, "KV blocks quarantined by allocator audit "
                         f"({len(report.corrupted_blocks)} corrupted)",
                    ERR_AUDIT)
                for req in victims.values()]

    # -- internals ------------------------------------------------------
    def _account_energy(self, n_tokens: float, attn_pairs: float,
                        kv_rows_read: float) -> None:
        """Accumulate modeled energy for ONE device call
        (``metrics["energy_joules"]``, roofline.step_joules): the call
        streams the weights once plus the touched KV rows
        (``kv_rows_read`` reads + one write per token), and runs
        ``2·P`` FLOPs per token plus ``4·H·hd`` per (query, key)
        attention pair per layer.  benchmarks/engine_bench.py divides
        tokens by the total for the paper's tokens/J metric."""
        if n_tokens <= 0:
            return
        cfg = self.model.cfg
        bytes_moved = (self._param_bytes
                       + (kv_rows_read + n_tokens) * self._kv_row_bytes)
        flops = (2.0 * self._n_params * n_tokens
                 + 4.0 * cfg.n_heads * cfg.hd() * cfg.n_layers
                 * attn_pairs)
        self.metrics["energy_joules"] += step_joules(bytes_moved, flops)

    def _account_prefix_bytes(self, offs: np.ndarray,
                              lens: np.ndarray) -> None:
        """Roofline estimate of the prefix K/V traffic one chunk step
        reads, per layer and row: the fused kernel fetches
        ``ceil(prefix/block_size)`` live tiles through the page table
        (dead tiles are index_map-clamped revisits — no DMA), where the
        legacy path gathered every row's full ``max_blocks × block_size``
        extent.  Both go into ``metrics`` so BENCH_engine.json can chart
        bytes actually touched vs the gather baseline."""
        _, _, bs, kvh, hd = self.cache["attn"]["k"].shape
        mb = self.pager.cfg.max_blocks_per_seq
        n_layers = self.model.cfg.n_layers
        per_pos = 2 * kvh * hd * self.cache["attn"]["k"].dtype.itemsize
        if "ks" in self.cache["attn"]:
            per_pos += 2 * kvh * 4               # f32 dequant scales
        live = lens > 0
        live_tiles = int((-(-offs[live] // bs)).sum())
        self.metrics["prefix_attn_bytes"] += (
            live_tiles * bs * per_pos * n_layers)
        self.metrics["prefix_attn_bytes_gather"] += (
            int(live.sum()) * mb * bs * per_pos * n_layers)
        # same per-call numbers feed the energy model: prefix tiles are
        # the KV reads, and each row self-attends causally within its
        # own chunk (len·off cross pairs + len(len+1)/2 within-chunk)
        ln = lens.astype(np.int64)
        pairs = float((ln * offs + ln * (ln + 1) // 2).sum())
        self._account_energy(float(ln.sum()), pairs,
                             float(live_tiles * bs))

    def _run_chunks(self, chunks: List[PrefillChunk]) -> List[Request]:
        """Execute ALL of this step's planned chunks — paged: one
        shape-stable batched ``prefill_chunk_batch`` call, padded to the
        fixed ``(max_slots, prefill_chunk_tokens)`` extent with per-row
        valid lengths/offsets as data (padding rows carry slot -1 and
        write nothing), writing every row's KV straight into its pool
        blocks; dense: per-sequence whole-prompt prefill merged into the
        slot.  Returns the requests the fault layer failed (persistent
        injected prefill faults, non-finite logits rows)."""
        failed: List[Request] = []
        if self.paged:
            if self.faults is not None:
                chunks, failed = self._survive_faults(
                    SITE_PREFILL, list(chunks),
                    uid_of=lambda c: c.seq.req.uid,
                    alive=lambda c:
                        self.scheduler.running.get(c.seq.slot) is c.seq)
                if not chunks:
                    return failed
            nrows, width = self.max_slots, self.prefill_chunk_tokens
            toks = np.zeros((nrows, width), np.int32)
            lens = np.zeros((nrows,), np.int32)
            offs = np.zeros((nrows,), np.int32)
            slots = np.full((nrows,), -1, np.int32)
            for i, c in enumerate(chunks):
                lens[i] = c.end - c.start
                toks[i, :lens[i]] = c.seq.tokens[c.start:c.end]
                offs[i] = c.start
                slots[i] = c.seq.slot
            logits, self.cache = self.model.prefill_chunk_batch(
                self.params, toks, self.cache, slots, offs,
                page_table=self._host_pt, chunk_lens=lens,
                mesh=self.mesh)
            self.metrics["chunk_batch_calls"] += 1
            self._account_prefix_bytes(offs, lens)
            if self.faults is not None:
                row_uids = [c.seq.req.uid for c in chunks]
                logits = self.faults.corrupt_logits(
                    SITE_PREFILL, self._step, logits, row_uids)
            finite = (np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
                      if self.nan_guard else None)
            for i, c in enumerate(chunks):
                seq = c.seq
                if self.scheduler.running.get(seq.slot) is not seq:
                    continue     # torn down by an earlier row's failure
                if finite is not None and not finite[i]:
                    # a non-finite row means the KV this chunk wrote is
                    # poison: quarantine before anything registers, fail
                    # the request (its whole group) — the other rows of
                    # this very batch are unaffected
                    self.metrics["nan_rows"] += 1
                    self.fault_log.append(
                        {"step": self._step, "kind": "nan",
                         "site": SITE_PREFILL, "uid": seq.req.uid})
                    failed.append(self._fail_request(
                        seq.req, "non-finite logits during prefill",
                        ERR_NAN, quarantine=True))
                    continue
                self._register_blocks(seq)
                self._finish_chunk(c, logits[i:i + 1])
        else:
            for c in chunks:
                toks = jnp.asarray(c.seq.tokens[c.start:c.end], jnp.int32)
                logits, pcache = self.model.prefill(
                    self.params, {"tokens": toks[None, :]},
                    max_seq=self.max_seq)
                self._merge_slot_cache(c.seq.slot, pcache, c.end)
                self._finish_chunk(c, logits)
        return failed

    def _stop_hit(self, seq, tok: int) -> bool:
        """The per-token finish predicate — shared by the decode loop
        and the first-token sample so a stop id (or ``max_new_tokens=1``)
        retires a sequence no matter where the token came from."""
        req = seq.req
        return (tok == self.eos_id
                or (req.stop_tokens is not None and tok in req.stop_tokens)
                or len(seq.output) >= req.max_new_tokens
                or seq.kv_len >= self.max_seq - 1)

    def _finish_seq(self, seq) -> Optional[Request]:
        """Retire one sequence; returns the Request when it completed the
        whole request (its group's last sibling, or a singleton)."""
        req = seq.req
        self.scheduler.finish(seq.slot)
        if seq.group is not None:
            seq.group.finished += 1
            if seq.group.finished < seq.group.n:
                return None      # request done only when ALL siblings are
        req.t_done = self._now()
        if req.outputs is None:
            req.outputs = [seq.output]
        self.metrics["requests_done"] += 1
        self._preempt_streak = 0     # completions prove we are not thrashing
        return req

    def _seq_key(self, seq) -> jax.Array:
        """The sequence's sampling-stream root:
        ``fold_in(request_root, stream + sibling_index)`` — position
        ``t`` then draws with ``fold_in(stream_root, t)``."""
        if seq.sample_key is None:
            seq.sample_key = jax.random.fold_in(
                seq.req.rng_key, seq.req.stream + seq.sibling_index)
        return seq.sample_key

    def _finish_chunk(self, chunk: PrefillChunk, logits) -> None:
        """Per-chunk bookkeeping after the device call: count it and, on
        the prompt's last chunk, sample the first output token — for an
        ``n_samples > 1`` request, ``n`` tokens from this ONE logits row,
        then fan the sequence out into its fork siblings."""
        seq, req = chunk.seq, chunk.seq.req
        self.metrics["prefill_chunks"] += 1
        if not chunk.last:
            return
        if seq.resuming:
            # recompute-on-resume: the token after this prefix was
            # already sampled before preemption; decode re-feeds it.
            seq.resuming = False
            return
        n = req.n_samples
        keys = jnp.stack([jax.random.fold_in(self._seq_key(seq), 0)]
                         if n == 1 else
                         [jax.random.fold_in(
                             jax.random.fold_in(req.rng_key,
                                                req.stream + i), 0)
                          for i in range(n)])
        first = np.asarray(sample_logits_per_row(
            keys, jnp.broadcast_to(logits[:1], (n, logits.shape[-1])),
            req.temperature, req.top_p))
        if n == 1:
            sibs = [seq]
            seq.output.append(int(first[0]))
            req.outputs = [seq.output]
        else:
            sibs = self.scheduler.fork_group(seq)
            for i, s in enumerate(sibs):
                s.output.append(int(first[i]))
            req.outputs = [s.output for s in sibs]
            self.metrics["fanouts"] += 1
            self.plan_log[-1].setdefault("forked", []).append((req.uid, n))
            # sibling rows must carry the shared prompt length before
            # their first decode; their page-table rows publish at the
            # next step's republish (decode this step drops them: the
            # device still sees -1 in row 0 and pins the len back to 0,
            # which the post-decode resync overwrites)
            rows = jnp.asarray([s.slot for s in sibs[1:]], jnp.int32)
            self.cache["lens"] = jnp.asarray(self.cache["lens"]) \
                .at[rows].set(seq.kv_len)
        req.t_first_token = self._now()
        for s in sibs:
            # a first token can already be terminal (a stop id, eos, or
            # max_new_tokens=1) — retire the sibling here instead of
            # decoding past its stop
            if self._stop_hit(s, s.output[-1]):
                done = self._finish_seq(s)
                if done is not None:
                    self._done_at_prefill.append(done)

    def _register_blocks(self, seq) -> None:
        """Publish every freshly-filled FULL block of ``seq`` into the
        allocator's prefix index (hash chained on the block's whole token
        prefix).  Rows past ``kv_len`` are untouched garbage, so only
        blocks completely below it qualify; partial tails stay mutable
        and unregistered."""
        if self.pager is None or not self.pager.enable_prefix_cache:
            return
        bs = self.page_size
        full = seq.kv_len // bs
        if full <= seq.registered:
            return
        # token id at pool row i is concat(prompt, output)[i]: prefill
        # rows hold (possibly resumed) prompt tokens, each decode row
        # holds the token fed that step — output[-1] at planning time.
        ids = np.concatenate(
            [seq.prompt, np.asarray(seq.output or [], np.int32)])
        for j in range(seq.registered, full):
            parent = seq.block_hashes[j - 1] if j else None
            block = ids[j * bs:(j + 1) * bs]
            h = chain_hash(parent, block)
            seq.block_hashes.append(h)
            self.pager.register_block(seq.slot, j, h, block)
        seq.registered = full

    def _merge_slot_cache(self, slot: int, pcache: Any, plen: int) -> None:
        """Copy a (1, …) prefill cache into slot ``slot`` of the dense
        batch cache.  Buffer layouts put batch right after the
        layer-stack dims, so we match on dim position by name."""
        def merge(dst, src, path=""):
            if isinstance(dst, dict):
                return {k: merge(dst[k], src[k], path + "/" + k)
                        for k in dst}
            if isinstance(dst, tuple):
                return tuple(merge(d, s, path) for d, s in zip(dst, src))
            if path.endswith("lens"):
                return dst.at[slot].set(jnp.asarray(plen, dst.dtype))
            # find the batch dim: it is where shapes differ (src has 1)
            for ax in range(dst.ndim):
                if src.shape[ax] == 1 and dst.shape[ax] == self.max_slots:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slot
                    return dst.at[tuple(idx)].set(
                        jnp.squeeze(src, ax).astype(dst.dtype))
            return dst
        self.cache = merge(self.cache, pcache)

    def _decode_once(self, slots: List[int]) -> List[Request]:
        """One batched decode step for the planned ``slots``.  The device
        step touches every row; rows outside ``slots`` (free slots, or a
        mid-prefill sequence whose next chunk overwrites the same
        position) are ignored and their lengths re-synced after.
        Sampling is per-row keyed (``sample_logits_per_row``) so each
        sequence draws from its own stream regardless of who shares the
        batch — which is also what makes fault isolation bit-exact: a
        row leaving the batch (failed request) cannot change any
        survivor's draws.

        Implemented as dispatch + complete so :meth:`step_async` can
        return between the two with the device still computing; calling
        them back to back (here) is the synchronous path and is
        bit-identical — the split only moves WHERE the host blocks, not
        what it computes."""
        return self._decode_complete(self._decode_dispatch(slots))

    def _decode_dispatch(self, slots: List[int]) -> _PendingDecode:
        """Token-independent half of the decode step: build the padded
        row inputs, dispatch the device decode and the per-row-keyed
        sampling, and return WITHOUT forcing the results to host.
        ``nxt``/``finite`` in the returned struct are device arrays
        still being computed under JAX async dispatch."""
        failed: List[Request] = []
        if self.faults is not None:
            slots, failed = self._survive_faults(
                SITE_DECODE, list(slots),
                uid_of=lambda s: self.scheduler.running[s].req.uid,
                alive=lambda s: s in self.scheduler.running)
            if not slots:
                return _PendingDecode(slots=[], failed=failed)
        tokens = np.zeros((self.max_slots,), np.int32)
        temps = np.ones((self.max_slots,), np.float32)
        top_ps = np.ones((self.max_slots,), np.float32)
        key_rows: List[Any] = [None] * self.max_slots
        row_uids: List[Optional[int]] = [None] * self.max_slots
        for i in slots:
            seq = self.scheduler.running[i]
            tokens[i] = seq.output[-1]
            temps[i] = seq.req.temperature
            top_ps[i] = seq.req.top_p
            key_rows[i] = jax.random.fold_in(self._seq_key(seq),
                                             len(seq.output))
            row_uids[i] = seq.req.uid
        zero = jax.random.PRNGKey(0)
        keys = jnp.stack([k if k is not None else zero for k in key_rows])

        t0 = self._now()
        if self.faults is not None:
            self.faults.latency(self._step)   # simulated slow device step
        logits, self.cache = self._decode(
            self.params, self.cache, self._put(tokens))
        if self.faults is not None:
            logits = self.faults.corrupt_logits(
                SITE_DECODE, self._step, logits, row_uids)
        finite = (jnp.all(jnp.isfinite(logits), axis=-1)
                  if self.nan_guard else None)
        nxt = sample_logits_per_row(
            keys, logits, jnp.asarray(temps), jnp.asarray(top_ps))
        self.metrics["decode_steps"] += 1
        self.metrics["seq_steps"] += len(slots)
        kv_now = sum(self.scheduler.running[i].kv_len for i in slots
                     if i in self.scheduler.running)
        self._account_energy(float(len(slots)), float(kv_now),
                             float(kv_now))
        return _PendingDecode(slots=slots, failed=failed, nxt=nxt,
                              finite=finite, t0=t0)

    def _decode_complete(self, p: _PendingDecode) -> List[Request]:
        """Token-dependent half: block on the sampled tokens, append
        them, register completed blocks, retire stops, resync lengths.
        ``t_decode`` is charged dispatch→here, so in pipelined serving
        it includes the host's overlap window — wall time the device
        was busy either way."""
        if not p.slots:
            self.cache["lens"] = self._put(
                self.scheduler.device_lens(), np.int32)
            return p.failed
        slots, failed = p.slots, p.failed
        finite = np.asarray(p.finite) if p.finite is not None else None
        nxt = np.asarray(p.nxt)
        self.metrics["t_decode"] += self._now() - p.t0

        finished: List[Request] = []
        for i in slots:
            seq = self.scheduler.running.get(i)
            if seq is None or seq.req.error is not None:
                continue        # torn down by an earlier row this step
            if finite is not None and not finite[i]:
                # NaN/inf logits on this row: its sampled token is
                # garbage and the KV row it just wrote is suspect —
                # quarantine + fail the request (group retires as a
                # unit), everyone else's draws are independent
                self.metrics["nan_rows"] += 1
                self.fault_log.append(
                    {"step": self._step, "kind": "nan",
                     "site": SITE_DECODE, "uid": seq.req.uid})
                failed.append(self._fail_request(
                    seq.req, "non-finite logits during decode", ERR_NAN,
                    quarantine=True))
                continue
            tok = int(nxt[i])
            seq.output.append(tok)
            self.metrics["tokens_out"] += 1
            # the step's KV row is in the pool now; if it completed a
            # block, publish it (before a finish drops the lease).
            self._register_blocks(seq)
            if self._stop_hit(seq, tok):
                done_req = self._finish_seq(seq)
                if done_req is not None:
                    finished.append(done_req)
        finished.extend(failed)
        # the scheduler's lengths are authoritative: decoded rows were
        # advanced at planning time, finished/free rows drop to 0, and a
        # mid-prefill row whose position the batched step bumped gets its
        # prefill progress back (its garbage KV row is overwritten by the
        # next chunk, or dropped when the block isn't allocated yet).
        self.cache["lens"] = self._put(self.scheduler.device_lens(),
                                       np.int32)
        return finished

    def _run_verifies(self, verifies: List[SpecVerify]) -> List[Request]:
        """Execute this step's speculative verify calls — ONE batched
        ``verify_chunk_batch`` padded to the fixed
        ``(max_slots, spec_tokens + 1)`` extent (padding rows carry slot
        -1 and write nothing, same contract as the prefill chunk).

        Each row feeds ``[output[-1], drafts...]`` at positions
        ``start..start+k`` and gets logits for all k+1 positions; every
        position ``j`` is then sampled with the exact key non-speculative
        decode would have used for output position ``m + j`` (``m`` =
        tokens emitted so far), so the emitted stream is independent of
        the drafts — they only decide how many positions commit.  The
        acceptance walk appends emitted tokens while they agree with the
        drafts (the position-``j`` logits conditioned on drafts ``< j``,
        so agreement up to ``j-1`` makes row ``j`` trustworthy), always
        commits the first token (a verify step never emits fewer tokens
        than the plain decode it replaced), and on disagreement or stop
        rolls the slot's lease back to the accepted length via
        ``BlockAllocator.truncate`` — BEFORE ``_register_blocks``, so the
        prefix index can never serve speculative KV."""
        failed: List[Request] = []
        if self.faults is not None:
            verifies, failed = self._survive_faults(
                SITE_DECODE, list(verifies),
                uid_of=lambda v: v.seq.req.uid,
                alive=lambda v:
                    self.scheduler.running.get(v.seq.slot) is v.seq)
            if not verifies:
                self.cache["lens"] = self._put(
                    self.scheduler.device_lens(), np.int32)
                return failed
        nrows, width = self.max_slots, self.spec_tokens + 1
        toks = np.zeros((nrows, width), np.int32)
        lens = np.zeros((nrows,), np.int32)
        offs = np.zeros((nrows,), np.int32)
        slots = np.full((nrows,), -1, np.int32)
        temps = np.ones((nrows,), np.float32)
        top_ps = np.ones((nrows,), np.float32)
        zero = jax.random.PRNGKey(0)
        key_flat: List[Any] = [zero] * (nrows * width)
        row_uids: List[Optional[int]] = [None] * nrows
        for i, v in enumerate(verifies):
            seq = v.seq
            k = len(v.drafts)
            lens[i] = k + 1
            toks[i, 0] = seq.output[-1]
            toks[i, 1:k + 1] = v.drafts
            offs[i] = v.start
            slots[i] = seq.slot
            temps[i] = seq.req.temperature
            top_ps[i] = seq.req.top_p
            row_uids[i] = seq.req.uid
            m = len(seq.output)
            for j in range(k + 1):
                key_flat[i * width + j] = jax.random.fold_in(
                    self._seq_key(seq), m + j)
        keys = jnp.stack(key_flat)

        t0 = self._now()
        if self.faults is not None:
            self.faults.latency(self._step)
        logits, self.cache = self.model.verify_chunk_batch(
            self.params, toks, self.cache, slots, offs,
            page_table=self._host_pt, chunk_lens=lens,
            mesh=self.mesh)
        if self.faults is not None:
            logits = self.faults.corrupt_logits(
                SITE_DECODE, self._step, logits, row_uids)
        finite = (np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
                  if self.nan_guard else None)        # (nrows, width)
        emitted = np.asarray(sample_logits_per_row(
            keys, logits.reshape(nrows * width, logits.shape[-1]),
            jnp.asarray(np.repeat(temps, width)),
            jnp.asarray(np.repeat(top_ps, width)))).reshape(nrows, width)
        self.metrics["verify_steps"] += 1
        self.metrics["seq_steps"] += len(verifies)
        self.metrics["t_decode"] += self._now() - t0
        # the verify reads the prefix through the same paged path as a
        # prefill chunk — account its tile traffic (and energy) the same
        self._account_prefix_bytes(offs, lens)

        finished: List[Request] = []
        for i, v in enumerate(verifies):
            seq = v.seq
            if self.scheduler.running.get(seq.slot) is not seq \
                    or seq.req.error is not None:
                continue         # torn down by an earlier row this step
            k = len(v.drafts)
            if finite is not None and not bool(finite[i, :k + 1].all()):
                # any poisoned position taints the whole row: its KV
                # writes are suspect — quarantine + fail, same rule as
                # the decode path (survivors' draws are independent)
                self.metrics["nan_rows"] += 1
                self.fault_log.append(
                    {"step": self._step, "kind": "nan",
                     "site": SITE_DECODE, "uid": seq.req.uid})
                failed.append(self._fail_request(
                    seq.req, "non-finite logits during verify", ERR_NAN,
                    quarantine=True))
                continue
            appended = 0
            stop = False
            for j in range(k + 1):
                tok = int(emitted[i, j])
                seq.output.append(tok)
                appended += 1
                self.metrics["tokens_out"] += 1
                seq.kv_len = v.start + appended
                stop = self._stop_hit(seq, tok)
                if stop or j >= k or v.drafts[j] != tok:
                    break
            self.metrics["draft_tokens"] += k
            self.metrics["accepted_tokens"] += appended - 1
            if appended <= k:
                self.metrics["spec_rollbacks"] += 1
            # rollback-as-truncation: shrink the lease to the accepted
            # length first, then register — rejected rows can neither
            # stay leased nor reach the prefix index
            self.pager.truncate(seq.slot, seq.kv_len)
            self._register_blocks(seq)
            if stop:
                done_req = self._finish_seq(seq)
                if done_req is not None:
                    finished.append(done_req)
        finished.extend(failed)
        self.cache["lens"] = self._put(self.scheduler.device_lens(),
                                       np.int32)
        return finished
