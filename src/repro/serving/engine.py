"""Serving engine: continuous batching over the decode step.

The paper serves batch-1 on an FPGA; its §5.2 names batched inference as
future work.  This engine is that future work: a fixed-slot batch
(`max_slots`) with continuous batching — finished sequences release their
slot mid-flight and queued requests are prefilling into it — over the
quantized decode step.

Sampling matches the paper's evaluation setup: temperature 1.0, top-p
1.0 (A.1), both configurable.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 64
    temperature: float = 1.0
    top_p: float = 1.0
    # filled by the engine:
    output: Optional[List[int]] = None
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


def sample_logits(key, logits: jax.Array, temperature: float = 1.0,
                  top_p: float = 1.0) -> jax.Array:
    """Temperature + nucleus sampling; (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        csum = jnp.cumsum(probs, axis=-1)
        # smallest k with cumulative prob >= top_p
        keep = csum - probs < top_p
        thresh = jnp.min(jnp.where(keep, sorted_logits, jnp.inf), axis=-1,
                         keepdims=True)
        logits = jnp.where(logits >= thresh, logits, -jnp.inf)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


class Engine:
    """Single-host continuous-batching engine.

    ``decode_fn(params, cache, tokens) -> (logits, cache)`` and
    ``prefill_fn(params, batch, max_seq) -> (logits, cache)`` come from
    the (possibly jitted/sharded) model; the engine itself is pure
    orchestration and identical whether the steps run on 1 CPU or a pod.
    """

    def __init__(self, model: Model, params: Any, max_slots: int = 8,
                 max_seq: int = 1024, eos_id: int = 2, seed: int = 0):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        # decode is the hot loop: jit once (cache/params structures are
        # stable).  Donating the cache avoids a copy per token.
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self.key = jax.random.PRNGKey(seed)
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.cache = model.init_cache(max_slots, max_seq)
        self.metrics = {"tokens_out": 0, "requests_done": 0,
                        "decode_steps": 0, "t_decode": 0.0}
        self._uid = 0

    # -- public API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, **kw) -> int:
        self._uid += 1
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                      t_enqueue=time.perf_counter(), output=[], **kw)
        self.queue.append(req)
        return req.uid

    def run(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            self._admit()
            if not any(self.slots):
                if not self.queue:
                    break
                continue
            done.extend(self._decode_once())
        return done

    # -- internals ------------------------------------------------------
    def _admit(self) -> None:
        """Prefill queued requests into free slots (one at a time keeps
        the example simple; a production build batches the prefills)."""
        for i in range(self.max_slots):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            p = req.prompt[-self.max_seq + req.max_new_tokens:]
            logits, pcache = self.model.prefill(
                self.params, {"tokens": p[None, :]},
                max_seq=self.max_seq)
            self._merge_slot_cache(i, pcache, len(p))
            self.key, sub = jax.random.split(self.key)
            first = sample_logits(sub, logits, req.temperature, req.top_p)
            req.output.append(int(first[0]))
            req.t_first_token = time.perf_counter()
            self.slots[i] = req

    def _merge_slot_cache(self, slot: int, pcache: Any, plen: int) -> None:
        """Copy a (1, …) prefill cache into slot ``slot`` of the batch
        cache.  Buffer layouts put batch right after the layer-stack dims,
        so we match on dim position by name."""
        def merge(dst, src, path=""):
            if isinstance(dst, dict):
                return {k: merge(dst[k], src[k], path + "/" + k)
                        for k in dst}
            if isinstance(dst, tuple):
                return tuple(merge(d, s, path) for d, s in zip(dst, src))
            if path.endswith("lens"):
                return dst.at[slot].set(jnp.asarray(plen, dst.dtype))
            # find the batch dim: it is where shapes differ (src has 1)
            for ax in range(dst.ndim):
                if src.shape[ax] == 1 and dst.shape[ax] == self.max_slots:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slot
                    return dst.at[tuple(idx)].set(
                        jnp.squeeze(src, ax).astype(dst.dtype))
            return dst
        self.cache = merge(self.cache, pcache)

    def _decode_once(self) -> List[Request]:
        tokens = np.zeros((self.max_slots,), np.int32)
        active = np.zeros((self.max_slots,), bool)
        for i, req in enumerate(self.slots):
            if req is not None:
                tokens[i] = req.output[-1]
                active[i] = True

        t0 = time.perf_counter()
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens))
        self.key, sub = jax.random.split(self.key)
        nxt = np.asarray(sample_logits(sub, logits))
        self.metrics["decode_steps"] += 1
        self.metrics["t_decode"] += time.perf_counter() - t0

        finished: List[Request] = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.output.append(tok)
            self.metrics["tokens_out"] += 1
            plen = len(req.prompt) + len(req.output)
            if tok == self.eos_id or len(req.output) >= req.max_new_tokens \
                    or plen >= self.max_seq - 1:
                req.t_done = time.perf_counter()
                finished.append(req)
                self.metrics["requests_done"] += 1
                self.slots[i] = None
                # dead slot: zero its length so attention masks it out
                self.cache["lens"] = self.cache["lens"].at[i].set(0)
        return finished

    def throughput_tok_s(self) -> float:
        t = self.metrics["t_decode"]
        return self.metrics["tokens_out"] / t if t > 0 else 0.0
