"""Serving engine: continuous batching over the paged decode step.

The paper serves batch-1 on an FPGA; its §5.2 names batched inference as
future work.  This engine is that future work: a fixed-slot batch
(`max_slots`) with continuous batching — finished sequences release their
slot mid-flight and queued requests are prefilling into it — over the
quantized decode step.

KV memory is **paged** by default (vLLM-style, serving/paged_cache.py):
the device cache is a pool of ``page_size``-token blocks shared by every
slot through a page table, a host-side :class:`BlockAllocator` hands
blocks to slots as their lengths grow, and decode attention reads K/V
through the table — so a 30-token sequence in a ``max_seq=4096`` engine
costs one block, not a 4096-row reservation, and the attention kernel's
length pruning (kernels/decode_attention.py, paged_decode_attention.py)
streams only the blocks a sequence actually owns.  Families whose cache
is not a single attention bank (ssm / hybrid / audio / interleaved-moe)
fall back to the dense per-slot reservation automatically.

Sampling matches the paper's evaluation setup: temperature 1.0, top-p 1.0
(A.1) — but each request's ``temperature``/``top_p`` are honored, threaded
through one vectorized sampler call per step (no per-slot Python loops).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.paged_cache import BlockAllocator, PagedConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 64
    temperature: float = 1.0
    top_p: float = 1.0
    # filled by the engine:
    output: Optional[List[int]] = None
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    error: Optional[str] = None   # set when the engine rejects the request


def sample_logits(key, logits: jax.Array, temperature=1.0,
                  top_p=1.0) -> jax.Array:
    """Temperature + nucleus sampling; (B, V) -> (B,) int32.

    ``temperature``/``top_p`` may be scalars or per-row (B,) arrays — the
    engine passes one array per batch so heterogeneous requests sample
    correctly in a single vectorized call.  ``temperature <= 0`` rows are
    greedy (argmax)."""
    b = logits.shape[0]
    t = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (b,))
    p = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (b,))
    p = jnp.maximum(p, 1e-6)                   # keep at least the top token

    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(t, 1e-6)[:, None]
    sorted_logits = jnp.sort(scaled, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    csum = jnp.cumsum(probs, axis=-1)
    # smallest k with cumulative prob >= top_p, per row
    keep = csum - probs < p[:, None]
    thresh = jnp.min(jnp.where(keep, sorted_logits, jnp.inf), axis=-1,
                     keepdims=True)
    masked = jnp.where(scaled >= thresh, scaled, -jnp.inf)
    sampled = jax.random.categorical(key, masked, axis=-1).astype(jnp.int32)
    return jnp.where(t <= 0.0, greedy, sampled)


@partial(jax.jit, donate_argnums=(0,))
def _scatter_pool(leaf, src, blk_ids):
    """One-shot admission scatter: leaf (L, NB, BS, …) <- src
    (L, n_blk, BS, …) at pool blocks ``blk_ids``.  Jitted with the pool
    donated so admission updates in place instead of copying the full
    pool once per (block, key)."""
    return leaf.at[:, blk_ids].set(src)


class Engine:
    """Single-host continuous-batching engine.

    ``decode_fn(params, cache, tokens) -> (logits, cache)`` and
    ``prefill_fn(params, batch, max_seq) -> (logits, cache)`` come from
    the (possibly jitted/sharded) model; the engine itself is pure
    orchestration and identical whether the steps run on 1 CPU or a pod.

    ``cache_kind="paged"`` (default) serves from the block pool when the
    model family supports it; ``"dense"`` forces the contiguous per-slot
    reservation.  ``n_pages`` sizes the pool (default: full reservation).
    Shrinking it oversubscribes: admission defers while the pool is
    temporarily full and rejects prompts that could never fit (returned
    from ``run()`` with ``.error`` set); mid-decode growth on an
    exhausted pool still raises ``OutOfBlocks`` — preemption is a
    ROADMAP follow-on.
    """

    def __init__(self, model: Model, params: Any, max_slots: int = 8,
                 max_seq: int = 1024, eos_id: int = 2, seed: int = 0,
                 cache_kind: str = "paged", page_size: int = 64,
                 n_pages: Optional[int] = None):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        # decode is the hot loop: jit once (cache/params structures are
        # stable).  Donating the cache avoids a copy per token.
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self.key = jax.random.PRNGKey(seed)
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_slots
        self._rejected: List[Request] = []

        self.paged = (cache_kind == "paged"
                      and model.init_paged_cache is not None)
        if self.paged:
            self.page_size = page_size
            mb = -(-max_seq // page_size)
            self.n_pages = n_pages or max_slots * mb
            self.pager = BlockAllocator(PagedConfig(
                n_layers=model.cfg.n_layers,
                n_kv_heads=model.cfg.n_kv_heads, head_dim=model.cfg.hd(),
                block_size=page_size, n_blocks=self.n_pages,
                max_slots=max_slots, max_blocks_per_seq=mb))
            self.cache = model.init_paged_cache(
                max_slots, block_size=page_size, n_blocks=self.n_pages,
                max_blocks_per_seq=mb)
            # host mirror of live lengths drives block allocation; device
            # ``cache["lens"]`` stays authoritative for attention masking.
            self._host_lens = np.zeros(max_slots, np.int64)
        else:
            self.cache = model.init_cache(max_slots, max_seq)
        self.metrics = {"tokens_out": 0, "requests_done": 0,
                        "decode_steps": 0, "t_decode": 0.0}
        self._uid = 0

    # -- public API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, **kw) -> int:
        self._uid += 1
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                      t_enqueue=time.perf_counter(), output=[], **kw)
        self.queue.append(req)
        return req.uid

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Serve until queue and slots drain.  Rejected requests (paged
        pool can never fit the prompt) come back in the done list with
        ``.error`` set and no output tokens."""
        done: List[Request] = []
        for _ in range(max_steps):
            self._admit()
            done.extend(self._rejected)
            self._rejected.clear()
            if not any(self.slots):
                if not self.queue:
                    break
                continue
            done.extend(self._decode_once())
        return done

    def cache_utilization(self) -> float:
        """Fraction of the KV pool in use (1.0-slots-full for dense)."""
        if self.paged:
            return self.pager.utilization()
        return sum(r is not None for r in self.slots) / self.max_slots

    # -- internals ------------------------------------------------------
    def _admit(self) -> None:
        """Prefill queued requests into free slots (one at a time keeps
        the example simple; a production build batches the prefills)."""
        for i in range(self.max_slots):
            while self.slots[i] is None and self.queue:
                head = self.queue[0]
                p = head.prompt[-self.max_seq + head.max_new_tokens:]
                if self.paged:
                    need = self.pager.blocks_needed(len(p))
                    if need > self.n_pages:
                        # can never fit: reject it (delivered through
                        # run()'s done list with .error set) rather than
                        # raising and tearing down in-flight requests.
                        req = self.queue.popleft()
                        req.error = (f"prompt needs {need} blocks, pool "
                                     f"holds only {self.n_pages}")
                        req.t_done = time.perf_counter()
                        self._rejected.append(req)
                        continue          # same slot, next queued request
                    if need > len(self.pager.free):
                        # pool temporarily full: defer until running
                        # requests release blocks (they always finish —
                        # max_new_tokens is bounded — so no livelock).
                        return
                req = self.queue.popleft()
                if self.paged:
                    # prefill only needs buffers for the prompt itself —
                    # the pool, not the prefill cache, is the home.
                    logits, pcache = self.model.prefill(
                        self.params, {"tokens": p[None, :]}, max_seq=len(p))
                    self._admit_paged(i, pcache, len(p))
                else:
                    logits, pcache = self.model.prefill(
                        self.params, {"tokens": p[None, :]},
                        max_seq=self.max_seq)
                    self._merge_slot_cache(i, pcache, len(p))
                self.key, sub = jax.random.split(self.key)
                first = sample_logits(sub, logits, req.temperature,
                                      req.top_p)
                req.output.append(int(first[0]))
                req.t_first_token = time.perf_counter()
                self.slots[i] = req

    def _admit_paged(self, slot: int, pcache: Any, plen: int) -> None:
        """Scatter a (1, plen) prefill cache into pool blocks owned by
        ``slot`` and point its page-table row at them.  One jitted
        scatter per pool key; the last block's tail pads with zeros
        (masked by ``lens``, and it scrubs any stale previous owner)."""
        blocks = self.pager.ensure(slot, plen)
        bs = self.page_size
        n_blk = len(blocks)
        blk_ids = jnp.asarray(blocks, jnp.int32)
        attn = dict(self.cache["attn"])
        for kk, full in pcache["attn"].items():
            src = full[:, 0]                 # (L, plen, KVH[, hd])
            widths = [(0, 0), (0, n_blk * bs - plen)] + \
                [(0, 0)] * (src.ndim - 2)
            src = jnp.pad(src, widths).reshape(
                src.shape[0], n_blk, bs, *src.shape[2:])
            attn[kk] = _scatter_pool(attn[kk], src.astype(attn[kk].dtype),
                                     blk_ids)
        self.cache["attn"] = attn
        self.cache["lens"] = self.cache["lens"].at[slot].set(plen)
        self.cache["page_table"] = jnp.asarray(self.pager.page_table())
        self._host_lens[slot] = plen

    def _merge_slot_cache(self, slot: int, pcache: Any, plen: int) -> None:
        """Copy a (1, …) prefill cache into slot ``slot`` of the batch
        cache.  Buffer layouts put batch right after the layer-stack dims,
        so we match on dim position by name."""
        def merge(dst, src, path=""):
            if isinstance(dst, dict):
                return {k: merge(dst[k], src[k], path + "/" + k)
                        for k in dst}
            if isinstance(dst, tuple):
                return tuple(merge(d, s, path) for d, s in zip(dst, src))
            if path.endswith("lens"):
                return dst.at[slot].set(jnp.asarray(plen, dst.dtype))
            # find the batch dim: it is where shapes differ (src has 1)
            for ax in range(dst.ndim):
                if src.shape[ax] == 1 and dst.shape[ax] == self.max_slots:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slot
                    return dst.at[tuple(idx)].set(
                        jnp.squeeze(src, ax).astype(dst.dtype))
            return dst
        self.cache = merge(self.cache, pcache)

    def _decode_once(self) -> List[Request]:
        tokens = np.zeros((self.max_slots,), np.int32)
        active = np.zeros((self.max_slots,), bool)
        temps = np.ones((self.max_slots,), np.float32)
        top_ps = np.ones((self.max_slots,), np.float32)
        for i, req in enumerate(self.slots):
            if req is not None:
                tokens[i] = req.output[-1]
                active[i] = True
                temps[i] = req.temperature
                top_ps[i] = req.top_p

        if self.paged:
            # grow block lists for slots crossing a page boundary, then
            # republish the table (device sees only dense int32 indices).
            for i in np.nonzero(active)[0]:
                self.pager.ensure(int(i), int(self._host_lens[i]) + 1)
            self.cache["page_table"] = jnp.asarray(self.pager.page_table())

        t0 = time.perf_counter()
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens))
        self.key, sub = jax.random.split(self.key)
        nxt = np.asarray(sample_logits(sub, logits, jnp.asarray(temps),
                                       jnp.asarray(top_ps)))
        self.metrics["decode_steps"] += 1
        self.metrics["t_decode"] += time.perf_counter() - t0
        if self.paged:
            self._host_lens[active] += 1

        finished: List[Request] = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.output.append(tok)
            self.metrics["tokens_out"] += 1
            plen = len(req.prompt) + len(req.output)
            if tok == self.eos_id or len(req.output) >= req.max_new_tokens \
                    or plen >= self.max_seq - 1:
                req.t_done = time.perf_counter()
                finished.append(req)
                self.metrics["requests_done"] += 1
                self.slots[i] = None
                # dead slot: zero its length so attention masks it out;
                # paged: hand its blocks back to the pool (the stale
                # page-table row is republished before the next decode,
                # and dead-slot writes scatter out-of-bounds -> dropped).
                self.cache["lens"] = self.cache["lens"].at[i].set(0)
                if self.paged:
                    self.pager.release(i)
                    self._host_lens[i] = 0
        return finished

    def throughput_tok_s(self) -> float:
        t = self.metrics["t_decode"]
        return self.metrics["tokens_out"] / t if t > 0 else 0.0
