"""Quantization policy — which parameters get quantized (paper §3.2).

The paper quantizes the embedding, attention, and feed-forward weights and
keeps the RMSNorm parameters (error-sensitive) in fp32.  We generalize that
to the whole arch pool: every *large matmul operand* is quantized, every
norm/bias/small-state parameter stays in float.

Policy is expressed over pytree paths so it composes with any model in
``repro.models`` without the models knowing about quantization.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

import jax

from repro.core.quantization import (DEFAULT_GROUP_SIZE, QuantizedTensor,
                                     quantize)

# Path fragments that must NEVER be quantized (paper: RMSNorm fp32; we add
# the other error-sensitive / tiny tensors of the broader arch pool).
_FLOAT_PATTERNS = (
    r"norm",          # rms / layer norms (paper-mandated fp32)
    r"\bbias\b",
    r"rope",          # rotary tables
    r"pos",           # learned positional tables (enc_pos / dec_pos)
    r"wdt",           # SSM dt projection — dt is precision-sensitive
    r"conv",          # mamba short conv + whisper conv frontend stubs
    r"A_log", r"\bdt", r"ssm_dt", r"dt_bias",   # SSM dynamics params
    r"D_skip",
    r"router",        # MoE router: tiny and precision-sensitive
    r"gamma", r"beta",
)
_FLOAT_RE = re.compile("|".join(_FLOAT_PATTERNS))


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """What to quantize and how.

    ``bits``/``group_size`` follow the paper defaults (Q8_0, groups of 64).
    ``min_size`` skips tiny tensors where scales would dominate bytes.
    """

    bits: int = 8
    group_size: int = DEFAULT_GROUP_SIZE
    min_size: int = 4096          # don't quantize tensors smaller than this
    quantize_embedding: bool = True   # paper quantizes the embedding
    kv_cache_bits: Optional[int] = None  # beyond-paper: int8 KV cache

    def wants(self, path: str, shape: tuple) -> bool:
        if _FLOAT_RE.search(path):
            return False
        if not self.quantize_embedding and "embed" in path:
            return False
        n = 1
        for d in shape:
            n *= d
        if n < self.min_size:
            return False
        return len(shape) >= 2  # only matmul operands


PAPER_POLICY = QuantPolicy()                       # faithful: Q8_0 / g=64
Q4_POLICY = QuantPolicy(bits=4)                    # beyond-paper (§5.1)
SERVE_POLICY = QuantPolicy(kv_cache_bits=8)        # beyond-paper int8 KV


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def quantize_params(params: Any, policy: QuantPolicy = PAPER_POLICY) -> Any:
    """Post-training quantization of a parameter pytree (paper: PTQ only).

    Weights are stored contraction-last by convention throughout
    ``repro.models`` (shape ``(out, in)`` / ``(..., in)``), so per-group
    scales along the last axis line up with the matmul contraction.
    """

    def _convert(path, leaf):
        ps = _path_str(path)
        if isinstance(leaf, QuantizedTensor):
            return leaf
        if hasattr(leaf, "shape") and policy.wants(ps, tuple(leaf.shape)):
            return quantize(leaf, group_size=policy.group_size, bits=policy.bits)
        return leaf

    return jax.tree_util.tree_map_with_path(_convert, params)


def count_bytes(params: Any) -> dict:
    """Bytes by storage class — drives the memory-roofline term and the
    Table-6 energy model."""
    tally = {"quantized": 0, "float": 0}

    def _visit(leaf):
        if isinstance(leaf, QuantizedTensor):
            tally["quantized"] += leaf.nbytes()
        elif hasattr(leaf, "nbytes"):
            tally["float"] += int(leaf.nbytes)
        return leaf

    jax.tree_util.tree_map(
        _visit, params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    tally["total"] = tally["quantized"] + tally["float"]
    return tally
