"""HLSTransform core: Q8_0/Q4_0 quantization, policy, quantized matmul."""

from repro.core.quantization import (DEFAULT_GROUP_SIZE, QuantizedTensor,
                                     choose_group_size, dequantize,
                                     qmatmul_ref, quantization_error,
                                     quantize, quantize_q4_0, quantize_q8_0)
from repro.core.policy import (PAPER_POLICY, Q4_POLICY, SERVE_POLICY,
                               QuantPolicy, count_bytes, quantize_params)
from repro.core.qlinear import (default_strategy, qdot, set_default_strategy)

__all__ = [
    "DEFAULT_GROUP_SIZE", "QuantizedTensor", "choose_group_size",
    "dequantize", "qmatmul_ref", "quantization_error", "quantize",
    "quantize_q4_0", "quantize_q8_0", "PAPER_POLICY", "Q4_POLICY",
    "SERVE_POLICY", "QuantPolicy", "count_bytes", "quantize_params",
    "default_strategy", "qdot", "set_default_strategy",
]
