"""Quantized linear layers — the paper's integer matmul, three ways.

Every large matmul in the serving path runs against int8 (or packed int4)
weights.  Three execution strategies share identical math (tests assert
mutual agreement):

``integer``   Faithful to the paper/llama2.c: activations are Q8_0-quantized
              on the fly, int8×int8 products accumulate in int32 within each
              group of 64, partial sums are rescaled by ``xs*ws`` and summed
              in f32.  Implemented as a ``lax.scan`` over groups so the
              int32 intermediate never exceeds one group's partials.

``dequant``   Weight-only quantization: int8 weights are dequantized inside
              the matmul (XLA fuses the convert+scale into the dot operand
              on TPU, so HBM still reads int8).  Mathematically identical to
              ``integer`` up to f32 summation order.  This is the strategy
              large-scale serving stacks use; it is also the strategy the
              distributed dry-run lowers, because it needs no custom kernel
              on the host platform.

``pallas``    The TPU kernel (kernels/q8_matmul.py): fused
              quantize→int8-dot→rescale with explicit VMEM BlockSpecs —
              the TPU-native rendering of the paper's pipelined,
              burst-read GEMV engine.

Weights are stored ``(out, in)`` contraction-last; ``qdot(x, w)`` computes
``x @ dequant(w).T`` with whatever strategy is configured.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.quantization import (QuantizedTensor, _unpack_nibbles,
                                     quantize)

Weight = Union[jax.Array, QuantizedTensor]

# Module-level default strategy; models thread an explicit value through, this
# is only the fallback so examples/tests can flip globally.
_DEFAULT_STRATEGY = "dequant"


def set_default_strategy(s: str) -> None:
    global _DEFAULT_STRATEGY
    assert s in ("integer", "dequant", "pallas")
    _DEFAULT_STRATEGY = s


def default_strategy() -> str:
    return _DEFAULT_STRATEGY


def _unpacked_q(w: QuantizedTensor) -> jax.Array:
    return _unpack_nibbles(w.q) if w.bits == 4 else w.q


# model-axis size of the production meshes (launch/mesh.py); used only to
# pick the GSPMD-friendly dequant formulation below.
_MODEL_AXIS = 16


def _dequant_weight(w: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    """int8 codes * per-group scale, shaped back to (out, in).

    Two formulations, chosen statically by group alignment (measured in
    EXPERIMENTS.md §Perf cell 3, iterations 4–5):

    * G % model_axis == 0 (scale shardable): the ``(N, G, gs)`` reshape is
      shard-local and fuses into the consuming dot — use it.
    * G % model_axis != 0 (sharding rules replicate the scale): the
      reshape would force GSPMD to all-gather the ENTIRE K-sharded weight
      (28 MB x n_layers/step on glm4); expanding the replicated scale with
      a gather ``scale[..., k // gs]`` keeps everything elementwise along
      K and shard-local instead (t_coll −19.5x).
    """
    wq = _unpacked_q(w)
    *lead, k = wq.shape
    g = w.scale.shape[-1]
    if g % _MODEL_AXIS == 0:
        wf = wq.reshape(*lead, g, k // g).astype(dtype) \
            * w.scale[..., None].astype(dtype)
        return wf.reshape(*lead, k)
    idx = jnp.arange(k, dtype=jnp.int32) // w.group_size
    scale_full = jnp.take(w.scale, idx, axis=-1).astype(dtype)
    return wq.astype(dtype) * scale_full


def _qdot_dequant(x: jax.Array, w: QuantizedTensor) -> jax.Array:
    wf = _dequant_weight(w, dtype=jnp.float32)
    return jax.lax.dot_general(
        x.astype(jnp.float32), wf,
        dimension_numbers=(((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _qdot_integer(x: jax.Array, w: QuantizedTensor) -> jax.Array:
    """Paper-faithful: dynamic act quant + per-group int32 accumulation."""
    gs = w.group_size
    xq_t = quantize(x, group_size=gs, bits=8)   # activations always Q8_0
    xq, xs = xq_t.q, xq_t.scale
    wq = _unpacked_q(w)
    *bx, k = xq.shape
    n = wq.shape[0]
    g = k // gs
    xg = jnp.moveaxis(xq.reshape(*bx, g, gs), -2, 0)       # (g, *bx, gs)
    xsg = jnp.moveaxis(xs, -1, 0)                          # (g, *bx)
    wg = jnp.moveaxis(wq.reshape(n, g, gs), 1, 0)          # (g, n, gs)
    wsg = jnp.moveaxis(w.scale, -1, 0)                     # (g, n)

    def body(acc, operands):
        xg_i, xsg_i, wg_i, wsg_i = operands
        # int8 x int8 -> int32 dot over one group (exact)
        p = jax.lax.dot_general(
            xg_i, wg_i,
            dimension_numbers=(((xg_i.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)              # (*bx, n)
        acc = acc + p.astype(jnp.float32) * xsg_i[..., None] * wsg_i
        return acc, None

    acc0 = jnp.zeros((*bx, n), jnp.float32)
    acc, _ = lax.scan(body, acc0, (xg, xsg, wg, wsg))
    return acc


def _qdot_pallas(x: jax.Array, w: QuantizedTensor, interpret: bool) -> jax.Array:
    from repro.kernels import ops as kops
    return kops.q8_matmul(x, w, interpret=interpret)


def as_float(w: Weight, dtype=jnp.float32) -> jax.Array:
    """Dequantize if needed — used by einsum-shaped consumers."""
    if isinstance(w, QuantizedTensor):
        return w.dequantize(dtype)
    return w.astype(dtype)


def qeinsum(eq: str, x: jax.Array, w: Weight) -> jax.Array:
    """einsum against a possibly-quantized weight (dequant strategy).

    Used where operands are head-structured (attention QKV/O) — XLA fuses
    the int8->f32 convert+scale into the contraction on TPU, so HBM still
    reads int8.  The paper-exact integer path stays available through
    ``qdot`` / the Pallas kernels for 2-D serving matmuls.
    """
    if isinstance(w, QuantizedTensor):
        return jnp.einsum(eq, x.astype(jnp.float32), as_float(w)).astype(x.dtype)
    return jnp.einsum(eq, x, w.astype(x.dtype))


def qdot(x: jax.Array, w: Weight, strategy: Optional[str] = None,
         interpret: bool = False) -> jax.Array:
    """``x @ w.T`` where ``w`` may be float (training) or quantized (serving)."""
    if not isinstance(w, QuantizedTensor):
        return jax.lax.dot_general(
            x, w.astype(x.dtype),
            dimension_numbers=(((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=x.dtype)
    s = strategy or _DEFAULT_STRATEGY
    if s == "dequant":
        return _qdot_dequant(x, w)
    if s == "integer":
        return _qdot_integer(x, w)
    if s == "pallas":
        return _qdot_pallas(x, w, interpret=interpret)
    raise ValueError(f"unknown strategy {s!r}")
