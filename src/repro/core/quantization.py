"""Q8_0 / Q4_0 symmetric per-group quantization (HLSTransform, §3.2).

The paper quantizes weights with the GGML "Q8_0" scheme: each weight vector
is split into fixed-size groups and every group ``w`` is mapped to

    q = round(127 * w / ||w||_inf)        (int8)
    scale = ||w||_inf / 127               (f32)

so that ``w ≈ q * scale``.  Activations are quantized with the same scheme
on the fly, which makes every matmul an int8×int8→int32 computation with a
per-(group, output) rescale — integer-only arithmetic on the hot path.

This module implements that scheme as a JAX pytree (:class:`QuantizedTensor`)
plus a 4-bit packed variant ("Q4_0", the paper's §5.1 future-work direction).
The group size defaults to 64, the paper's AXI4 burst width (64 int8/cycle);
on TPU the same number keeps scale vectors lane-aligned for 128-wide loads.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_GROUP_SIZE = 64  # the paper's burst width: 64 int8 per cycle over AXI4.


def choose_group_size(dim: int, preferred: int = DEFAULT_GROUP_SIZE) -> int:
    """Largest divisor of ``dim`` that is <= ``preferred``.

    The assigned architectures all have contraction dims divisible by 64;
    this fallback keeps odd dims (e.g. head_dim 96) working with a smaller
    group rather than failing.
    """
    if dim <= 0:
        raise ValueError(f"dim must be positive, got {dim}")
    g = min(preferred, dim)
    while dim % g != 0:
        g -= 1
    return g


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """A symmetric per-group quantized tensor.

    ``q`` holds int8 codes (for 4-bit, two codes packed per byte along the
    grouped axis), ``scale`` holds one f32 per group.  Grouping is always
    along the **last** axis; callers move the contraction axis last before
    quantizing (all weights in this repo are stored contraction-last).

    shape bookkeeping for ``bits=8``:
        q:     (*lead, K)            int8
        scale: (*lead, K // group)   f32
    for ``bits=4`` (packed):
        q:     (*lead, K // 2)       int8 (two nibbles)
        scale: (*lead, K // group)   f32
    """

    q: jax.Array
    scale: jax.Array
    group_size: int
    bits: int = 8
    orig_dim: int = -1  # unpacked size of the last axis

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.q, self.scale), (self.group_size, self.bits, self.orig_dim)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        group_size, bits, orig_dim = aux
        return cls(q=q, scale=scale, group_size=group_size, bits=bits,
                   orig_dim=orig_dim)

    # -- convenience -------------------------------------------------------
    @property
    def shape(self):
        return self.q.shape[:-1] + (self.orig_dim,)

    @property
    def n_groups(self) -> int:
        return self.orig_dim // self.group_size

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return dequantize(self, dtype=dtype)

    def nbytes(self) -> int:
        """Serialized payload size — what HBM traffic is proportional to."""
        qb = int(np.prod(self.q.shape))  # int8 => 1 byte each
        sb = 4 * int(np.prod(self.scale.shape))
        return qb + sb


def _qmax(bits: int) -> int:
    if bits == 8:
        return 127
    if bits == 4:
        return 7
    raise ValueError(f"unsupported bits={bits}")


def _pack_nibbles(q: jax.Array) -> jax.Array:
    """Pack int8 values in [-8, 7] pairwise along the last axis."""
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    return ((hi.astype(jnp.int8) << 4) | (lo.astype(jnp.int8) & 0x0F)).astype(jnp.int8)


def _unpack_nibbles(p: jax.Array) -> jax.Array:
    """Inverse of :func:`_pack_nibbles`; sign-extends each nibble."""
    lo = (p << 4).astype(jnp.int8) >> 4          # arithmetic shift sign-extends
    hi = p.astype(jnp.int8) >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*p.shape[:-1], p.shape[-1] * 2)


@partial(jax.jit, static_argnames=("group_size", "bits"))
def _quantize_impl(x: jax.Array, group_size: int, bits: int):
    qmax = _qmax(bits)
    *lead, k = x.shape
    g = k // group_size
    xg = x.reshape(*lead, g, group_size).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    # Exactly the paper's formula: q = round(qmax * w / ||w||_inf), with the
    # all-zero group special-cased (scale 0 -> dequantizes back to 0).
    scale = absmax / qmax
    inv = jnp.where(absmax > 0, qmax / absmax, 0.0)
    q = jnp.clip(jnp.round(xg * inv), -qmax, qmax).astype(jnp.int8)
    q = q.reshape(*lead, k)
    scale = scale.reshape(*lead, g)
    if bits == 4:
        q = _pack_nibbles(q)
    return q, scale


def quantize(x: jax.Array, group_size: int = DEFAULT_GROUP_SIZE,
             bits: int = 8) -> QuantizedTensor:
    """Symmetric per-group quantization along the last axis (Q8_0 / Q4_0)."""
    k = x.shape[-1]
    group_size = choose_group_size(k, group_size)
    if bits == 4 and (group_size % 2 != 0 or k % 2 != 0):
        raise ValueError("Q4_0 packing needs an even grouped axis")
    q, scale = _quantize_impl(x, group_size, bits)
    return QuantizedTensor(q=q, scale=scale, group_size=group_size, bits=bits,
                           orig_dim=k)


def quantize_q8_0(x: jax.Array, group_size: int = DEFAULT_GROUP_SIZE) -> QuantizedTensor:
    return quantize(x, group_size=group_size, bits=8)


def quantize_rows(vec: jax.Array):
    """Q8_0 with one group per full vector: (..., hd) -> int8 codes
    (..., hd) + f32 scale (...,).  The KV-cache quantizer — both the
    contiguous cache (models/transformer) and the paged pool
    (serving/paged_cache) write through this, so their numerics can
    never drift apart."""
    absmax = jnp.max(jnp.abs(vec.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jnp.where(absmax > 0, 127.0 / absmax, 0.0)
    q = jnp.clip(jnp.round(vec * inv), -127, 127).astype(jnp.int8)
    return q, (absmax[..., 0] / 127.0)


def quantize_q4_0(x: jax.Array, group_size: int = DEFAULT_GROUP_SIZE) -> QuantizedTensor:
    return quantize(x, group_size=group_size, bits=4)


@partial(jax.jit, static_argnames=("group_size", "dtype"))
def _dequantize_q8(q, scale, group_size: int, dtype):
    *lead, k = q.shape
    g = k // group_size
    xg = q.reshape(*lead, g, group_size).astype(jnp.float32)
    out = xg * scale[..., None]
    return out.reshape(*lead, k).astype(dtype)


def dequantize(t: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    q = _unpack_nibbles(t.q) if t.bits == 4 else t.q
    return _dequantize_q8(q, t.scale, t.group_size, dtype=dtype)


# ---------------------------------------------------------------------------
# Structural ops — reshape/concat quantized tensors WITHOUT requantizing.
#
# Decode-path weight fusion (models/transformer.fuse_decode_weights) builds
# one big GEMV operand out of several already-quantized projections.  Codes
# and scales never change, only their shape bookkeeping: groups tile the
# last (contraction) axis contiguously, so any reshape that leaves the last
# axis alone — or folds whole leading axes into it — preserves the exact
# (code, scale) pairing and therefore the exact dequantized values.
# ---------------------------------------------------------------------------


def qt_reshape_lead(t: QuantizedTensor, *new_lead: int) -> QuantizedTensor:
    """Reshape the leading (non-grouped) axes; the grouped last axis and the
    group structure are untouched, so dequantize() is bit-identical."""
    q = t.q.reshape(*new_lead, t.q.shape[-1])
    scale = t.scale.reshape(*new_lead, t.scale.shape[-1])
    return QuantizedTensor(q=q, scale=scale, group_size=t.group_size,
                           bits=t.bits, orig_dim=t.orig_dim)


def qt_fold_lead_into_groups(t: QuantizedTensor) -> QuantizedTensor:
    """Fold the innermost leading axis into the grouped axis:
    (*lead, A, K) -> (*lead, A*K).  Legal because groups tile K contiguously
    — after the fold, groups tile A*K contiguously with the same scales."""
    *lead, a, kq = t.q.shape
    q = t.q.reshape(*lead, a * kq)
    *_, _, g = t.scale.shape
    scale = t.scale.reshape(*lead, a * g)
    return QuantizedTensor(q=q, scale=scale, group_size=t.group_size,
                           bits=t.bits, orig_dim=a * t.orig_dim)


def qt_concat(ts, axis: int) -> QuantizedTensor:
    """Concatenate quantized tensors along a leading (non-grouped) axis."""
    t0 = ts[0]
    if any(t.group_size != t0.group_size or t.bits != t0.bits
           or t.orig_dim != t0.orig_dim for t in ts[1:]):
        raise ValueError("qt_concat needs matching group/bits/orig_dim")
    nd = t0.q.ndim
    ax = axis % nd
    if ax == nd - 1:
        raise ValueError("cannot concat along the grouped axis")
    q = jnp.concatenate([t.q for t in ts], axis=ax)
    scale = jnp.concatenate([t.scale for t in ts], axis=ax)
    return QuantizedTensor(q=q, scale=scale, group_size=t0.group_size,
                           bits=t0.bits, orig_dim=t0.orig_dim)


# ---------------------------------------------------------------------------
# Reference quantized matmul semantics (the oracle the Pallas kernels match).
# ---------------------------------------------------------------------------

def qmatmul_ref(x: QuantizedTensor, w: QuantizedTensor) -> jax.Array:
    """``dequant(x) @ dequant(w)`` computed the *integer-arithmetic* way.

    x: activations, shape (*batch, K), grouped along K.
    w: weights, stored contraction-last as (N, K), grouped along K.
    returns f32 (*batch, N).

    Semantics match the paper/llama2.c: int8×int8 products accumulate in
    int32 *within a group*, each group partial sum is rescaled by
    ``xs[g] * ws[n, g]`` and summed in f32 across groups.
    """
    if x.group_size != w.group_size:
        raise ValueError(f"group size mismatch {x.group_size} vs {w.group_size}")
    gs = x.group_size
    xq = _unpack_nibbles(x.q) if x.bits == 4 else x.q
    wq = _unpack_nibbles(w.q) if w.bits == 4 else w.q
    *bx, k = xq.shape
    n, kw = wq.shape
    if k != kw:
        raise ValueError(f"contraction mismatch {k} vs {kw}")
    g = k // gs
    xg = xq.reshape(*bx, g, gs).astype(jnp.int32)
    wg = wq.reshape(n, g, gs).astype(jnp.int32)
    # int32 partial dot per (batch, n, group)
    part = jnp.einsum("...gk,ngk->...ng", xg, wg).astype(jnp.float32)
    # rescale: activations per (batch, group), weights per (n, group)
    scaled = part * x.scale[..., None, :] * w.scale
    return jnp.sum(scaled, axis=-1)


def quantization_error(x: jax.Array, group_size: int = DEFAULT_GROUP_SIZE,
                       bits: int = 8) -> jax.Array:
    """Max-abs round-trip error — used by tests and the quality benchmark."""
    t = quantize(x, group_size=group_size, bits=bits)
    return jnp.max(jnp.abs(t.dequantize() - x))
